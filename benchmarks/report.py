"""Assemble EXPERIMENTS.md sections (Dry-run, Roofline tables) from the
results JSONs.  Run after dryrun.py + roofline.py:

    PYTHONPATH=src python -m benchmarks.report > results/report.md
"""

from __future__ import annotations

import json


def fmt_bytes(b: float) -> str:
    if b >= 2**30:
        return f"{b/2**30:.2f} GiB"
    if b >= 2**20:
        return f"{b/2**20:.1f} MiB"
    return f"{b/2**10:.0f} KiB"


def dryrun_table(path: str, mesh_name: str) -> str:
    rows = json.load(open(path))
    out = [f"\n### Mesh {mesh_name}\n",
           "| arch | shape | compile (s) | peak HBM/dev | HLO flops/dev "
           "(loop-body) | collectives/dev (per layer-loop body) |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"SKIP: {r['skipped'][:60]}… |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | — | — | "
                       f"{r['error'][:60]} |")
            continue
        coll = ", ".join(f"{k.split('-')[-1]}={fmt_bytes(v)}"
                         for k, v in sorted(r["collective_bytes"].items())
                         if v > 0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']} | "
            f"{fmt_bytes(r['memory']['bytes_per_device_peak'])} | "
            f"{r['flops']:.2e} | {coll} |")
    return "\n".join(out)


def roofline_table(path: str) -> str:
    rows = json.load(open(path))
    out = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
           "dominant | MODEL_FLOPS | MODEL/executed | roofline frac | "
           "peak GiB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
            f"**{r['dominant']}** | {r['model_flops']:.2e} | "
            f"{r['useful_ratio_model_over_executed']:.2f} | "
            f"{r['roofline_fraction']*100:.1f}% | {r['peak_gib']:.1f} |")
    return "\n".join(out)


def bottleneck_notes(path: str) -> str:
    rows = json.load(open(path))
    out = []
    for r in rows:
        out.append(f"- **{r['arch']} × {r['shape']}** ({r['dominant']}-bound):"
                   f" {r['note']}.")
    return "\n".join(out)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-single", default="results/dryrun_singlepod.json")
    ap.add_argument("--dryrun-multi", default="results/dryrun_multipod.json")
    ap.add_argument("--roofline", default="results/roofline.json")
    ap.add_argument("--section", default="all")
    args = ap.parse_args()

    if args.section in ("all", "dryrun"):
        print("## §Dry-run\n")
        print(dryrun_table(args.dryrun_single, "16×16 (single pod)"))
        print(dryrun_table(args.dryrun_multi, "2×16×16 (multi-pod)"))
    if args.section in ("all", "roofline"):
        print("\n## §Roofline (single-pod, 256 chips)\n")
        print(roofline_table(args.roofline))
        print("\n### Per-cell bottleneck notes\n")
        print(bottleneck_notes(args.roofline))


if __name__ == "__main__":
    main()
