"""Paper-table reproductions (Tables 2-3, Fig. 11, search-time claim).

All resource numbers come from the FPGA proxy model (core/resources.py) --
no Vivado in this container; see DESIGN.md Sec 2 for what changed.  The
*relative* claims are what we reproduce: ours vs baseline [33] vs
first-valid Spatial vs the Merlin emulation.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import baselines, problems
from repro.core.solver import SolverOptions


V7_APPS = list(problems.STENCILS)                      # Table 2
F1_APPS = list(problems.STENCILS) + ["sw", "spmv", "sgd"]  # Table 3


def _row(rep):
    b = rep.best
    if b is None:
        # no valid scheme in this system's search space (e.g. spmv needs
        # multidim banking, which flat-only searchers cannot express)
        return {"lut": float("nan"), "ff": float("nan"), "bram": -1,
                "dsp": -1, "banks": 0, "seconds": rep.solve_seconds,
                "scheme": "NO VALID SCHEME"}
    r = b.resources.total
    return {"lut": r.lut, "ff": r.ff, "bram": r.bram, "dsp": r.dsp,
            "banks": b.num_banks, "seconds": rep.solve_seconds,
            "scheme": b.describe().split(" |")[0]}


def run_table(apps: List[str], systems: List[str]) -> Dict:
    out: Dict[str, Dict[str, Dict]] = {}
    for app in apps:
        prog = problems.build(app)
        memname = list(prog.memories)[0]
        out[app] = {}
        for sysname in systems:
            rep = baselines.SYSTEMS[sysname](prog, memname)
            out[app][sysname] = _row(rep)
    return out


def avg_change(table: Dict, ours: str = "ours") -> Dict[str, Dict[str, float]]:
    """Average per-resource % change of `ours` vs each other system
    (paper's 'Avg. Change' rows)."""
    systems = {s for rows in table.values() for s in rows} - {ours}
    out = {}
    for sysname in systems:
        deltas = {k: [] for k in ("lut", "ff", "bram")}
        dsp_base = dsp_ours = 0.0
        for app, rows in table.items():
            if rows[sysname]["banks"] == 0 or rows[ours]["banks"] == 0:
                continue  # a system found no valid scheme: excluded
            for k in deltas:
                base, new = rows[sysname][k], rows[ours][k]
                if base > 0:
                    deltas[k].append((new - base) / base * 100.0)
                elif new == 0:
                    deltas[k].append(0.0)
            dsp_base += rows[sysname]["dsp"]
            dsp_ours += rows[ours]["dsp"]
        out[sysname] = {k: float(np.mean(v)) if v else 0.0
                        for k, v in deltas.items()}
        # paper reports DSP as aggregate elimination (-100%)
        out[sysname]["dsp"] = ((dsp_ours - dsp_base) / dsp_base * 100.0
                               if dsp_base > 0 else 0.0)
    return out


def table2() -> Dict:
    """Virtex-7 comparison: 8 stencils x {baseline, spatial, ours}."""
    return run_table(V7_APPS, ["baseline", "spatial", "ours"])


def table3() -> Dict:
    """AWS F1 comparison: 11 apps x {merlin, spatial, ours}."""
    return run_table(F1_APPS, ["merlin", "spatial", "ours"])


def fig11(n_splits: int = 10, seed: int = 0) -> Dict:
    """Cost-model learning curves: GBT pipeline vs tuned MLP, R^2 over
    10 random 70/30 splits (paper Sec 3.5.2 / Fig. 11)."""
    from repro.core.cost_model import MLPBaseline, ResourcePipeline, r2_score
    from repro.core.dataset import build_dataset

    ds = build_dataset(seed=seed)
    rng = np.random.default_rng(seed)
    out = {"n_samples": int(len(ds.X)), "gbt": {}, "mlp": {}}
    for target in ("lut", "ff", "bram"):
        y = ds.y[target]
        scores = {"gbt": [], "mlp": []}
        for _ in range(n_splits):
            idx = rng.permutation(len(ds.X))
            ntr = int(0.7 * len(idx))
            tr, te = idx[:ntr], idx[ntr:]
            gbt = ResourcePipeline(
                gbt_params=dict(n_estimators=100)).fit(ds.X[tr], y[tr])
            scores["gbt"].append(r2_score(y[te], gbt.predict(ds.X[te])))
            mlp = MLPBaseline(epochs=120).fit(ds.X[tr], y[tr])
            scores["mlp"].append(r2_score(y[te], mlp.predict(ds.X[te])))
        for m in ("gbt", "mlp"):
            out[m][target] = {"mean": float(np.mean(scores[m])),
                              "std": float(np.std(scores[m]))}
    return out


def search_time() -> Dict:
    """Sec 6 claim: 'for problems with massive solution spaces, it can cut
    the time spent searching in half' -- multidim projection regrouping vs
    flat-only exhaustive search on the heavily-parallelized apps."""
    from repro.core.planner import BankingPlanner

    planner = BankingPlanner()
    out = {}
    for app, kw in [("sgd", dict(par_a=4, par_b=3)),
                    ("spmv", dict(par_r=4, par_c=3)),
                    ("sw", dict(par=8))]:
        prog = problems.build(app, **kw)
        memname = list(prog.memories)[0]
        # use_cache=False: this figure measures search time, not cache hits
        t0 = time.perf_counter()
        planner.plan(prog, memname,
                     opts=SolverOptions(allow_multidim=True,
                                        allow_duplication=False),
                     use_cache=False)
        t_md = time.perf_counter() - t0
        t0 = time.perf_counter()
        planner.plan(prog, memname,
                     opts=SolverOptions(allow_multidim=False,
                                        allow_duplication=False,
                                        n_budget=96, n_cap_factor=8),
                     use_cache=False)
        t_flat = time.perf_counter() - t0
        out[app] = {"with_multidim_s": t_md, "flat_only_s": t_flat,
                    "speedup": t_flat / max(t_md, 1e-9)}
    return out
