"""Benchmark harness: one function per paper table/figure + kernel micros.

``PYTHONPATH=src python -m benchmarks.run [--fast]``

Prints ``name,us_per_call,derived`` CSV rows per the harness contract, plus
human-readable tables.  Roofline numbers live in launch/roofline.py (they
need the 512-device dry-run) -- see EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import json
import time


def _bench_callable(fn, *args, iters=3, warmup=1, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / iters
    return out, dt * 1e6


def bench_tables(fast: bool) -> None:
    from benchmarks import tables

    t2 = tables.table2()
    print("\n=== Table 2 (Virtex-7 proxy): app x system ===")
    print(f"{'app':12s} {'system':9s} {'LUT':>8s} {'FF':>8s} {'BRAM':>5s} {'DSP':>4s} {'t(s)':>6s}")
    for app, rows in t2.items():
        for sysname, r in rows.items():
            print(f"{app:12s} {sysname:9s} {r['lut']:8.0f} {r['ff']:8.0f} "
                  f"{r['bram']:5d} {r['dsp']:4d} {r['seconds']:6.2f} "
                  f"{r['scheme'] if r['banks'] == 0 else ''}")
    ch2 = tables.avg_change(t2)
    for sysname, d in ch2.items():
        print(f"Avg change vs {sysname}: "
              + " ".join(f"{k}={v:+.1f}%" for k, v in d.items()))
        print(f"table2_vs_{sysname},0,"
              + ";".join(f"{k}{v:+.1f}%" for k, v in d.items()))

    t3 = tables.table3()
    print("\n=== Table 3 (AWS F1 proxy): app x system ===")
    for app, rows in t3.items():
        for sysname, r in rows.items():
            print(f"{app:12s} {sysname:9s} {r['lut']:8.0f} {r['ff']:8.0f} "
                  f"{r['bram']:5d} {r['dsp']:4d} {r['seconds']:6.2f} "
                  f"{r['scheme'] if r['banks'] == 0 else ''}")
    ch3 = tables.avg_change(t3)
    for sysname, d in ch3.items():
        print(f"Avg change vs {sysname}: "
              + " ".join(f"{k}={v:+.1f}%" for k, v in d.items()))
        print(f"table3_vs_{sysname},0,"
              + ";".join(f"{k}{v:+.1f}%" for k, v in d.items()))

    st = tables.search_time()
    print("\n=== Search-time (Sec 6 claim) ===")
    for app, r in st.items():
        print(f"{app:8s} multidim={r['with_multidim_s']:.2f}s "
              f"flat-only={r['flat_only_s']:.2f}s speedup={r['speedup']:.2f}x")
        print(f"search_time_{app},{r['with_multidim_s']*1e6:.0f},"
              f"speedup={r['speedup']:.2f}x")

    import os
    cached = "results/fig11.json"
    if fast and os.path.exists(cached):
        f11 = json.load(open(cached))
        tag = " (cached: estimator CV is independent of ranking weights)"
    elif not fast:
        f11 = tables.fig11(n_splits=3)
        with open(cached, "w") as f:
            json.dump(f11, f, indent=1)
        tag = ""
    else:
        return
    print(f"\n=== Fig 11 (cost-model CV, 3 splits){tag} ===")
    for m in ("gbt", "mlp"):
        for tgt, s in f11[m].items():
            print(f"{m:4s} {tgt:5s} R2 = {s['mean']:.3f} +- {s['std']:.3f}")
            print(f"fig11_{m}_{tgt},0,R2={s['mean']:.3f}")


def bench_kernels() -> None:
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    print("\n=== Kernel micro-benches (interpret on CPU; structural) ===")
    B, S, H, Hkv, Dh = 1, 256, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, Dh)), jnp.float32)
    _, us = _bench_callable(
        lambda: ops.mha(q, k, v).block_until_ready(), iters=2)
    print(f"flash_attention_{S},{us:.0f},interpret")

    Bs, Hs, Q, P, N = 1, 4, 64, 32, 16
    x = jnp.asarray(rng.normal(size=(Bs, Hs, Q, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.2, (Bs, Hs, Q)), jnp.float32)
    cum = jnp.cumsum(-dt, -1)
    bm = jnp.asarray(rng.normal(size=(Bs, Q, N)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(Bs, Q, N)), jnp.float32)
    s0 = jnp.zeros((Bs, Hs, P, N), jnp.float32)
    _, us = _bench_callable(
        lambda: ops.ssd(x, dt, bm, cm, cum, s0)[0].block_until_ready(),
        iters=2)
    print(f"ssd_chunk_{Q},{us:.0f},interpret")


def bench_solver() -> None:
    from repro.core import problems
    from repro.core.planner import BankingPlanner

    planner = BankingPlanner()
    print("\n=== Solver latency per benchmark problem ===")
    for app in list(problems.STENCILS) + ["sw", "spmv", "sgd", "md_grid"]:
        prog = problems.build(app)
        memname = list(prog.memories)[0]
        t0 = time.perf_counter()
        plan = planner.plan(prog, memname, use_cache=False)
        us = (time.perf_counter() - t0) * 1e6
        print(f"solver_{app},{us:.0f},candidates={plan.num_candidates}")


def bench_planner_cache() -> None:
    """Cold plan vs warm signature-cache hit (the serving-hot-path win)."""
    from repro.core import problems
    from repro.core.planner import BankingPlanner

    planner = BankingPlanner()
    prog = problems.build("sobel")
    memname = list(prog.memories)[0]
    t0 = time.perf_counter()
    planner.plan(prog, memname)
    cold_us = (time.perf_counter() - t0) * 1e6
    _, warm_us = _bench_callable(
        lambda: planner.plan(prog, memname), iters=20, warmup=2)
    print("\n=== Planner cache (cold solve vs warm hit) ===")
    print(f"planner_cache,{warm_us:.0f},"
          f"cold={cold_us:.0f}us;speedup={cold_us / max(warm_us, 1e-9):.0f}x")


def bench_compile_cache() -> None:
    """Cold artifact lowering vs warm planner compile-cache hit.

    The cold path lowers the resolution graphs to jit-ready callables and
    builds the pack/unpack address tables; warm calls are dict hits on the
    planner's (signature, backend)-keyed compile cache -- the lowering
    happens once per scheme per process (or once ever, with cache_dir=)."""
    from repro.core import problems
    from repro.core.planner import BankingPlanner

    planner = BankingPlanner()
    prog = problems.build("sobel")
    memname = list(prog.memories)[0]
    plan = planner.plan(prog, memname)
    t0 = time.perf_counter()
    planner.compile(plan)
    cold_us = (time.perf_counter() - t0) * 1e6
    _, warm_us = _bench_callable(
        lambda: planner.compile(plan), iters=50, warmup=2)
    print("\n=== Compile cache (cold lower vs warm artifact hit) ===")
    print(f"compile_cache,{warm_us:.0f},"
          f"cold={cold_us:.0f}us;speedup={cold_us / max(warm_us, 1e-9):.0f}x")


def bench_plan_service() -> None:
    """The async front door: submit latency, time-to-first-fallback
    artifact, time-to-solved-swap, and the warm-store hit -- the four
    numbers that decide whether serving ever blocks on the solver.
    Emitted both as a CSV row and as results/BENCH_plan_service.json."""
    import tempfile

    from repro.core import PlanService, problems
    from repro.core.store import DirectoryStore

    # warm the jax import + trivial-lowering path so the fallback number
    # measures the artifact machinery, not a first-time jax import
    from repro.core import MemorySpec
    from repro.core.artifact import compile_trivial
    compile_trivial(MemorySpec("warm", dims=(8,), word_bits=16, ports=1))

    prog = problems.build("sobel")
    memname = list(prog.memories)[0]
    with tempfile.TemporaryDirectory() as d:
        svc = PlanService(store=DirectoryStore(d), workers=2)
        t0 = time.perf_counter()
        ticket = svc.submit(prog, memname)
        submit_us = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        fb = ticket.fallback()
        fallback_us = (time.perf_counter() - t0) * 1e6
        ticket.result(timeout=120)
        t0 = time.perf_counter()
        ticket.artifact()
        solved_swap_us = (time.perf_counter() - t0) * 1e6
        time_to_solved_s = time.time() - ticket.submitted_at
        # a second service over the same store: the cross-process warm hit
        warm_svc = PlanService(store=DirectoryStore(d), workers=2)
        t0 = time.perf_counter()
        warm_ticket = warm_svc.submit(prog, memname)
        warm_us = (time.perf_counter() - t0) * 1e6
        assert warm_ticket.done(), "warm store must answer inside submit"
        out = {
            "submit_us": submit_us,
            "fallback_artifact_us": fallback_us,
            "fallback_banks": fb.n_banks,
            "solved_swap_us": solved_swap_us,
            "time_to_solved_s": time_to_solved_s,
            "warm_store_hit_us": warm_us,
            "warm_ticket_done": warm_ticket.done(),
        }
    with open("results/BENCH_plan_service.json", "w") as f:
        json.dump(out, f, indent=1)
    print("\n=== Plan service (submit / fallback / solved swap / warm) ===")
    print(f"plan_service,{submit_us:.0f},"
          f"fallback={fallback_us:.0f}us;"
          f"solved_swap={solved_swap_us:.0f}us;"
          f"time_to_solved={time_to_solved_s*1e3:.0f}ms;"
          f"warm_hit={warm_us:.0f}us")


def bench_solver_shards(fast: bool = False) -> None:
    """Sharded candidate-space solve: 1/2/4-shard cold-solve wall-clock
    plus time-to-first-best, per benchmark problem.

    1-shard runs the in-thread pipeline (work-equivalent to the old
    monolithic search); multi-shard fans contiguous work units across a
    process pool with the reducer's section cuts pruning dispatch
    (``core.candidates.evaluate_parallel``).  Every configuration must
    agree on the chosen scheme -- the shard-equivalence property.
    Writes results/BENCH_solver_shards.json.
    """
    from repro.core import problems, unroll, build_groups
    from repro.core.candidates import CandidateSpace, evaluate_parallel
    from repro.core.planner import rank_solutions
    from repro.core.solver import SolverOptions

    apps = ["sobel"] if fast else ["sobel", "sw", "spmv"]
    shard_counts = (1, 2) if fast else (1, 2, 4)
    out = {}
    print("\n=== Sharded solver (cold solve, k shards) ===")
    for app in apps:
        prog = problems.build(app)
        memname = list(prog.memories)[0]
        up = unroll(prog)
        groups = build_groups(up, memname)
        mem = prog.memories[memname]
        rows = {}
        winners = set()
        for k in shard_counts:
            space = CandidateSpace(mem, groups, up.iterators,
                                   SolverOptions())
            t0 = time.perf_counter()
            red = evaluate_parallel(space, k)
            sols = red.finalize()
            wall_s = time.perf_counter() - t0
            best = rank_solutions(list(sols))[0]
            winners.add((best.kind, str(best.geometry), best.duplicates))
            rows[str(k)] = {
                "wall_s": wall_s,
                "time_to_first_best_s": red.first_best_seconds,
                "candidates_evaluated": red.evaluated,
                "space_size": len(space),
                "solutions": len(sols),
            }
            print(f"solver_shards_{app}_k{k},{wall_s*1e6:.0f},"
                  f"ttfb={red.first_best_seconds*1e6:.0f}us;"
                  f"evaluated={red.evaluated}/{len(space)}")
        assert len(winners) == 1, f"shard-equivalence broken for {app}"
        rows["same_winner_all_k"] = True
        rows["winner"] = next(iter(winners))[1]
        out[app] = rows
    with open("results/BENCH_solver_shards.json", "w") as f:
        json.dump(out, f, indent=1)


def bench_solve_fabric(fast: bool = False) -> None:
    """Distributed solve fabric: cold-solve wall-clock + time-to-first-
    best for 1/2/4 remote worker subprocesses vs the in-process fork
    pool, same problem, same-winner assert (shard equivalence over the
    wire).  Writes results/BENCH_solve_fabric.json.
    """
    from repro.core import (CandidateSpace, SolutionReducer, SolveFabric,
                            build_groups, problems, spawn_local_workers,
                            unroll)
    from repro.core.candidates import evaluate_parallel
    from repro.core.planner import rank_solutions
    from repro.core.solver import SolverOptions

    apps = ["sobel"] if fast else ["sobel", "sw"]
    counts = (1, 2) if fast else (1, 2, 4)
    out = {}
    print("\n=== Solve fabric (remote workers vs in-process pool) ===")
    for app in apps:
        prog = problems.build(app)
        memname = list(prog.memories)[0]
        up = unroll(prog)
        groups = build_groups(up, memname)
        mem = prog.memories[memname]
        rows = {}
        winners = set()

        def record(name, red, wall_s, extra=None):
            sols = red.finalize()
            best = rank_solutions(list(sols))[0]
            winners.add((best.kind, str(best.geometry), best.duplicates))
            rows[name] = dict(
                wall_s=wall_s,
                time_to_first_best_s=red.first_best_seconds,
                solutions=len(sols), **(extra or {}))
            ttfb = (red.first_best_seconds or 0.0) * 1e6
            print(f"solve_fabric_{app}_{name},{wall_s*1e6:.0f},"
                  f"ttfb={ttfb:.0f}us")

        # in-process pool baseline (the PR-4 scaling primitive)
        space = CandidateSpace(mem, groups, up.iterators, SolverOptions())
        t0 = time.perf_counter()
        red = evaluate_parallel(space, 2)
        record("pool_k2", red, time.perf_counter() - t0)

        for w in counts:
            fabric = SolveFabric(chunk=24)
            procs = spawn_local_workers(fabric.address, w)
            try:
                assert fabric.wait_for_workers(w, timeout=60)
                space = CandidateSpace(mem, groups, up.iterators,
                                       SolverOptions())
                red = SolutionReducer(space)
                t0 = time.perf_counter()
                report = fabric.solve(space, reducer=red)
                record(f"fabric_w{w}", red, time.perf_counter() - t0,
                       extra=dict(leases=report.leases,
                                  evaluated=report.evaluated,
                                  cut_broadcasts=report.cut_broadcasts))
            finally:
                for p in procs:
                    p.terminate()
                for p in procs:
                    p.wait()
                fabric.shutdown()
        assert len(winners) == 1, f"fabric equivalence broken for {app}"
        rows["same_winner_all_configs"] = True
        rows["winner"] = next(iter(winners))[1]
        out[app] = rows
    # worker counts beyond the host's cores oversubscribe CPU-bound
    # evaluators (the real win needs N hosts); record the context
    import os as _os
    out["host_cpus"] = _os.cpu_count()
    with open("results/BENCH_solve_fabric.json", "w") as f:
        json.dump(out, f, indent=1)


def bench_feedback_scorer(fast: bool = False) -> None:
    """Measured-cost feedback loop: cold ml/static ranking vs the rank
    after measurements contradict it, wall-clock from first observation
    to the demotion re-solve, and the per-call gather cost with timing
    hooks off (must be ~ the raw call) vs on.
    Writes results/BENCH_feedback_scorer.json.
    """
    import numpy as np

    from repro.core import (AccessDecl, Counter, Ctrl, FlatGeometry,
                            MemorySpec, MemoryStore, PlanService, Program,
                            Sched, compile_geometry)
    from repro.core.polytope import Affine
    from repro.core.solver import SolverOptions
    from repro.core.telemetry import (MeasuredScorer, TelemetryConfig,
                                      TelemetryLog, roofline_prior_seconds,
                                      scheme_hash)

    mem = MemorySpec("table", dims=(256,), word_bits=32, ports=1)
    prog = Program(
        root=Ctrl("reader", Sched.INNER,
                  counters=[Counter("i", 0, 1, 32, par=8)],
                  accesses=[AccessDecl("table", (Affine.of(i=1),))]),
        memories={"table": mem},
    )
    out = {}
    print("\n=== Feedback scorer (measure -> re-rank -> demote) ===")

    # -- cold rank vs measured-refreshed rank ---------------------------
    svc = PlanService(store=MemoryStore(), workers=1)
    hub = svc.enable_telemetry(TelemetryConfig(min_observations=4,
                                               flush_every=0))
    plan = svc.submit(prog, "table",
                      opts=SolverOptions(n_budget=8)).result(timeout=120)
    sols = plan.solutions[:2]
    assert len(sols) == 2, "need two candidate schemes"
    log = TelemetryLog()
    static = {scheme_hash(sols[0]): 1.0, scheme_hash(sols[1]): 2.0}
    scorer = MeasuredScorer(log=log,
                            static=lambda s: static[scheme_hash(s)])
    cold = sorted(sols, key=scorer)
    for _ in range(8):   # hardware says the cold winner is 10x slower
        log.observe(plan.signature, scheme_hash(sols[0]), "numpy",
                    "gather", (8,), 1e-3,
                    prior=roofline_prior_seconds(sols[0]))
        log.observe(plan.signature, scheme_hash(sols[1]), "numpy",
                    "gather", (8,), 1e-4,
                    prior=roofline_prior_seconds(sols[1]))
    measured = sorted(sols, key=scorer)
    out["cold_rank"] = [scheme_hash(s) for s in cold]
    out["measured_rank"] = [scheme_hash(s) for s in measured]
    out["rank_flipped"] = cold[0] is not measured[0]

    # -- demotion latency: first observation -> speculative re-solve ----
    art = svc.planner.compile(plan, backend="numpy")
    hub.log.observe(plan.signature, "rival-scheme", "numpy", "gather",
                    (8,), 1e-5)
    t0 = time.perf_counter()
    while svc.stats.demotions == 0:
        hub.observe(art, "gather", (8,), 1e-3)
    demote_us = (time.perf_counter() - t0) * 1e6
    svc.drain(timeout=120)
    resolve_s = time.perf_counter() - t0
    out["demotion_latency_us"] = demote_us
    out["demotion_resolve_s"] = resolve_s
    out["observations_to_demote"] = svc.stats.observations

    # -- per-call gather: hooks off must cost ~ the raw inner call ------
    geo = FlatGeometry(N=4, B=16, alpha=(1,), P=(16,))
    bare = compile_geometry(mem, geo, backend="numpy")
    table = np.arange(256 * 2, dtype=np.int32).reshape(256, 2)
    packed = np.asarray(bare.pack(table))
    rows = np.arange(8)
    iters = 50 if fast else 300
    _, raw_us = _bench_callable(lambda: bare._gather(packed, rows),
                                iters=iters, warmup=5)
    _, off_us = _bench_callable(lambda: bare.gather(packed, rows),
                                iters=iters, warmup=5)

    class _Sink:
        def observe(self, *a):
            pass

    bare.enable_telemetry(_Sink())
    _, on_us = _bench_callable(lambda: bare.gather(packed, rows),
                               iters=iters, warmup=5)
    bare.disable_telemetry()
    out["gather_raw_us"] = raw_us
    out["gather_hooks_off_us"] = off_us
    out["gather_hooks_on_us"] = on_us
    out["hooks_off_overhead_us"] = off_us - raw_us

    with open("results/BENCH_feedback_scorer.json", "w") as f:
        json.dump(out, f, indent=1)
    print(f"feedback_scorer,{demote_us:.0f},"
          f"rank_flipped={out['rank_flipped']};"
          f"resolve={resolve_s*1e3:.0f}ms;"
          f"hooks_off_overhead={off_us - raw_us:.2f}us;"
          f"hooks_on={on_us:.1f}us")


def bench_certify(fast: bool = False) -> None:
    """Independent conflict-freedom certification over the Sec-4 suite:
    per-plan certify latency (solver output re-decided pair-by-pair via
    the lattice/residue path) plus the certificate re-check latency, and
    the negative control -- a deliberately corrupted scheme MUST come
    back with a concrete two-point counterexample, never a pass.
    Writes results/BENCH_certify.json.
    """
    import dataclasses

    from repro.analysis.certify import certify_plan, certify_solution, \
        check_certificate
    from repro.core import problems, unroll
    from repro.core.planner import BankingPlanner

    apps = (["denoise", "sobel", "sgd"] if fast
            else list(problems.STENCILS) + ["sw", "spmv", "sgd"])
    planner = BankingPlanner()
    out = {}
    print("\n=== Certifier (independent conflict-freedom re-decision) ===")
    for app in apps:
        prog = problems.build(app)
        memname = list(prog.memories)[0]
        plan = planner.plan(prog, memname, use_cache=False)
        iters = unroll(prog).iterators
        t0 = time.perf_counter()
        res = certify_plan(plan, iters)
        certify_us = (time.perf_counter() - t0) * 1e6
        assert res.ok, f"{app}: solver/certifier disagreement: {res.reason}"
        t0 = time.perf_counter()
        ok, why = check_certificate(res.certificate)
        recheck_us = (time.perf_counter() - t0) * 1e6
        assert ok, f"{app}: certificate failed re-check: {why}"
        out[app] = {
            "certify_us": certify_us,
            "recheck_us": recheck_us,
            "pairs_checked": res.pairs_checked,
            "scheme": plan.best.describe(),
        }
        print(f"certify_{app},{certify_us:.0f},"
              f"pairs={res.pairs_checked};recheck={recheck_us:.0f}us")

    # negative control: forge sobel's winner down to one bank -- every
    # access now collides, and the certifier must SAY so concretely
    prog = problems.build("sobel")
    memname = list(prog.memories)[0]
    plan = planner.plan(prog, memname, use_cache=False)
    iters = unroll(prog).iterators
    forged = dataclasses.replace(
        plan.best, geometry=dataclasses.replace(plan.best.geometry,
                                                N=1, B=1))
    t0 = time.perf_counter()
    res = certify_solution(forged, plan.groups, iters)
    detect_us = (time.perf_counter() - t0) * 1e6
    assert not res.ok and res.counterexample is not None, \
        "corrupted scheme certified as conflict-free!"
    out["corrupted_control"] = {
        "detect_us": detect_us,
        "counterexample": res.counterexample.describe(),
    }
    print(f"certify_corrupted_control,{detect_us:.0f},detected=True")
    with open("results/BENCH_certify.json", "w") as f:
        json.dump(out, f, indent=1)


def bench_multi_tenant(fast: bool = False) -> None:
    """Multi-tenant QoS under solver saturation: three tenants (one
    deliberately noisy batch flooder) share ONE PlanService; per-tenant
    p50/p95 ticket latency is measured with QoS classes on vs off (off =
    every submit untagged: one band, plain FIFO).  The interactive
    tenant's p95 must stay bounded with QoS on, over-quota submits must
    defer -- never silently drop -- and the per-tenant stats slices must
    reconcile exactly with the global counters.
    Writes results/BENCH_multi_tenant.json.
    """
    from repro.core import (AccessDecl, Counter, Ctrl, MemorySpec,
                            PlanService, Program, Sched)
    from repro.core.polytope import Affine
    from repro.runtime.tenancy import TenantRegistry

    def program(tag: str, i: int):
        name = f"{tag}{i}"
        mem = MemorySpec(name, dims=(4096,), word_bits=32, ports=1)
        return Program(
            root=Ctrl("reader", Sched.INNER,
                      counters=[Counter("i", 0, 1, 24 + i, par=8)],
                      accesses=[AccessDecl(name, (Affine.of(i=1),))]),
            memories={name: mem},
        ), name

    n_batch, n_best, n_inter = (8, 3, 4) if fast else (16, 4, 6)

    def scenario(qos: bool) -> dict:
        registry = None
        if qos:
            registry = TenantRegistry()
            registry.register("interactive", "interactive")
            registry.register("batch", "batch")
            registry.register("best_effort", "best_effort")
        svc = PlanService(workers=2, tenants=registry)
        tickets = []
        # the flood lands FIRST: by the time interactive submits, the
        # queue is saturated with batch/best_effort work
        for i in range(n_batch):
            tickets.append(("batch", svc.submit(
                *program("b", i), use_cache=False,
                tenant="batch" if qos else None)))
        for i in range(n_best):
            tickets.append(("best_effort", svc.submit(
                *program("e", i), use_cache=False,
                tenant="best_effort" if qos else None)))
        for i in range(n_inter):
            tickets.append(("interactive", svc.submit(
                *program("q", i), use_cache=False,
                tenant="interactive" if qos else None)))
        for _, t in tickets:
            assert t.wait(timeout=300), "ticket never resolved"
        svc.drain(timeout=300)
        per = {}
        for tenant, t in tickets:
            if t.status == "shed":
                per.setdefault(tenant, []).append(None)
                continue
            per.setdefault(tenant, []).append(
                t.resolved_at - t.submitted_at)
        row = {}
        for tenant, lats in per.items():
            shed = sum(1 for x in lats if x is None)
            lats = sorted(x for x in lats if x is not None)
            row[tenant] = {
                "n": len(lats),
                "shed": shed,
                "p50_s": round(lats[len(lats) // 2], 4),
                "p95_s": round(lats[min(len(lats) - 1,
                                        int(len(lats) * 0.95))], 4),
            }
        row["deferred"] = svc.stats.deferred
        row["shed"] = svc.stats.shed
        # exact reconciliation: every global counter == sum of slices
        g = svc.stats.as_dict()
        slices = g.pop("tenants", {})
        mismatch = [k for k, v in g.items()
                    if v != sum(s.get(k, 0) for s in slices.values())]
        assert not mismatch, f"stats slices drifted: {mismatch}"
        svc.shutdown()
        return row

    print("\n=== Multi-tenant QoS (saturated solver, on vs off) ===")
    on = scenario(qos=True)
    off = scenario(qos=False)
    gap = (off["interactive"]["p95_s"]
           / max(on["interactive"]["p95_s"], 1e-9))
    out = {
        "qos_on": on, "qos_off": off,
        "interactive_p95_gap": round(gap, 2),
        "flood": {"batch": n_batch, "best_effort": n_best,
                  "interactive": n_inter},
    }
    # the headline property: QoS keeps the interactive tenant's p95 at
    # or under the unprioritized run's (equal is possible on an idle
    # host -- the flood may drain before interactive even queues)
    assert (on["interactive"]["p95_s"]
            <= off["interactive"]["p95_s"] * 1.5 + 0.05), \
        f"QoS made interactive latency WORSE: {out}"
    for name, row in (("on", on), ("off", off)):
        for tenant in ("interactive", "batch", "best_effort"):
            print(f"multi_tenant_{tenant}_qos_{name},"
                  f"{row[tenant]['p95_s']*1e6:.0f},"
                  f"p50={row[tenant]['p50_s']*1e3:.0f}ms;"
                  f"shed={row[tenant]['shed']}")
    print(f"multi_tenant_gap,0,interactive_p95_off/on={gap:.2f}x;"
          f"deferred_on={on['deferred']};shed_on={on['shed']}")
    with open("results/BENCH_multi_tenant.json", "w") as f:
        json.dump(out, f, indent=1)


def bench_joint_plan(fast: bool = False) -> None:
    """Whole-model joint planning under a shared resource budget, on two
    real model configs (dense qwen2_7b: one KV pool; MoE olmoe_1b_7b: KV
    pool + expert dispatch table).  The independent baseline lets every
    memory take its own argmin; the joint run co-selects under a BRAM
    budget set to 60% of the baseline's draw -- the argmins can NOT fit,
    the joint selection must.  Every non-trivial selected scheme must
    come back certified conflict-free (verify="store" is armed), and a
    slack-budget joint run must reproduce the baseline exactly.
    Writes results/BENCH_joint_plan.json.
    """
    del fast
    from repro.core import PlanService, ResourceBudget, SolverOptions
    from repro.core.jointplan import independent_use
    from repro.configs import get_arch
    from repro.runtime.server import model_memory_program

    out = {}
    print("\n=== Joint whole-model planning (budget vs independent) ===")
    for arch in ("qwen2-7b", "olmoe-1b-7b"):
        cfg = get_arch(arch).reduced()
        program = model_memory_program(cfg, max_len=64, page=16, readers=4)
        opts = SolverOptions(b_candidates=(16, 1), allow_multidim=False)
        svc = PlanService(workers=2, verify="store")
        # independent baseline: every memory argmins on its own
        t0 = time.perf_counter()
        plans = svc.planner.plan_all(program, opts=opts)
        indep_s = time.perf_counter() - t0
        indep = independent_use(plans)
        # slack-budget joint == independent, exactly
        slack = svc.submit_joint(program, opts=opts).result(timeout=300)
        assert slack.total_use.as_tuple() == indep.as_tuple(), \
            f"{arch}: slack joint drifted from independent planning"
        # 60% of the baseline BRAM: argmins cannot fit, joint must
        cap = ResourceBudget(bram=max(2, int(indep.bram * 0.6)))
        assert not cap.admits(indep), \
            f"{arch}: baseline unexpectedly fits the cap"
        t0 = time.perf_counter()
        ticket = svc.submit_joint(program, budget=cap, opts=opts,
                                  use_cache=False)
        jplan = ticket.result(timeout=300)
        joint_s = time.perf_counter() - t0
        assert jplan.feasible and jplan.fits(), \
            f"{arch}: joint selection failed to fit the budget"
        for name, m in jplan.members.items():
            assert m.trivial or m.certified, \
                f"{arch}:{name} selected scheme is uncertified"
        traded = sorted(
            name for name, m in jplan.members.items()
            if m.chosen.describe() != plans[name].best.describe())
        joint = jplan.total_use
        out[arch] = {
            "memories": sorted(jplan.members),
            "independent": indep.as_dict(),
            "budget": cap.as_dict(),
            "joint": joint.as_dict(),
            "independent_fits": cap.admits(indep),
            "joint_fits": jplan.fits(),
            "traded_down": traded,
            "members": jplan.as_dict()["members"],
            "independent_s": round(indep_s, 4),
            "joint_s": round(joint_s, 4),
            "stats": {k: getattr(svc.stats, k) for k in
                      ("joint_submits", "joint_solved", "joint_reselects",
                       "joint_infeasible", "joint_cert_evictions",
                       "certified")},
        }
        print(f"joint_plan_{arch.replace('-', '_')},{joint_s*1e6:.0f},"
              f"bram={indep.bram}->{joint.bram}(cap {cap.bram});"
              f"traded={'+'.join(traded) or 'none'};"
              f"certified={svc.stats.certified}")
        svc.shutdown()
    # headline: on every config the budgeted joint plan fits where the
    # independent argmins do not
    assert all(r["joint_fits"] and not r["independent_fits"]
               for r in out.values())
    with open("results/BENCH_joint_plan.json", "w") as f:
        json.dump(out, f, indent=1)


def bench_trace_overhead(fast: bool = False) -> None:
    """Observability-plane cost + the first measured w1 fabric-vs-pool
    breakdown.

    Part 1 proves tracing is effectively free: a disabled hook is one
    attribute load + None check (microbenchmarked per site, then scaled
    by the hook sites a cold solve crosses -- "hooks-off ~ raw"), and
    the hooks-ON cold-solve median stays within 3% of hooks-off.
    Part 2 answers the ROADMAP's standing question ("w1 fabric slower
    than pool: dispatch overhead is the next bottleneck") from the
    stitched trace itself: the lease wall time splits into worker eval,
    worker->driver result wire time, and dispatch gap (serialize +
    lease round-trips + driver-side frame handling).

    Writes results/BENCH_trace_overhead.json.
    """
    import statistics

    from repro.core import (PlanService, SolveFabric, problems,
                            spawn_local_workers)

    reps = 3 if fast else 7
    prog = problems.build("sobel")
    memname = list(prog.memories)[0]
    print("\n=== Trace overhead (hooks off/on) + w1 attribution ===")

    def cold_solve_ms(svc):
        t0 = time.perf_counter()
        assert svc.submit(prog, memname,
                          use_cache=False).result(timeout=120) is not None
        return (time.perf_counter() - t0) * 1e3

    def series(svc):
        cold_solve_ms(svc)                                 # warmup
        return [cold_solve_ms(svc) for _ in range(reps)]

    # -- part 1: hooks off vs on, same service path -------------------
    svc_off = PlanService(workers=2)
    off = series(svc_off)
    # the disabled hook, microbenchmarked: ONE attribute load + None
    # check (exactly what every instrumentation site compiles to when
    # enable_tracing was never called)
    n_iters = 200_000
    t0 = time.perf_counter()
    for _ in range(n_iters):
        if svc_off.tracer is not None:
            pass
    hook_ns = (time.perf_counter() - t0) / n_iters * 1e9
    svc_off.shutdown()

    svc_on = PlanService(workers=2)
    svc_on.enable_tracing()
    on = series(svc_on)
    trace = svc_on.recorder.traces()[-1]
    svc_on.shutdown()

    off_ms = statistics.median(off)
    on_ms = statistics.median(on)
    # ~40 guarded sites fire per cold solve; scaling the measured
    # per-site cost gives the hooks-off overhead vs raw (pre-tracing)
    hooks_off_pct = hook_ns * 40 / (off_ms * 1e6) * 100
    hooks_on_pct = (on_ms - off_ms) / off_ms * 100
    print(f"trace_overhead_hooks_off,{off_ms*1e3:.0f},"
          f"hook={hook_ns:.0f}ns;overhead={hooks_off_pct:.4f}%")
    print(f"trace_overhead_hooks_on,{on_ms*1e3:.0f},"
          f"overhead={hooks_on_pct:+.2f}%")

    def _stage(name):
        return round(sum(s.duration_ms for s in trace.spans
                         if s.name == name), 3)

    out = {
        "cold_solve": {
            "reps": reps,
            "hooks_off_ms": [round(v, 3) for v in off],
            "hooks_on_ms": [round(v, 3) for v in on],
            "hooks_off_median_ms": round(off_ms, 3),
            "hooks_on_median_ms": round(on_ms, 3),
            "disabled_hook_ns": round(hook_ns, 1),
            "hooks_off_overhead_pct": round(hooks_off_pct, 5),
            "hooks_on_overhead_pct": round(hooks_on_pct, 3),
            "traced_stage_ms": {n: _stage(n) for n in
                                ("prepare", "queue-wait", "enumerate",
                                 "shard-eval", "reduce")},
        },
    }

    # -- part 2: w1 fabric vs pool, attributed stage by stage ---------
    svc = PlanService(workers=2)
    svc.enable_tracing()
    pool_ms = statistics.median(series(svc))
    pool_trace = svc.recorder.traces()[-1]
    svc.shutdown()

    fabric = SolveFabric(chunk=24)
    procs = spawn_local_workers(fabric.address, 1)
    try:
        assert fabric.wait_for_workers(1, timeout=60)
        svc = PlanService(executor="fabric", fabric=fabric)
        svc.enable_tracing()
        fab_ms = statistics.median(series(svc))
        fab_trace = svc.recorder.traces()[-1]
        svc.shutdown()
    finally:
        for p in procs:
            p.terminate()
        for p in procs:
            p.wait()
        fabric.shutdown()

    spans = fab_trace.spans
    lease_wall = sum(s.duration_ms for s in spans if s.name == "lease")
    worker_eval = sum(s.duration_ms for s in spans if s.name == "w-eval")
    worker_wire = sum(s.attrs.get("wire_ms", 0.0) for s in spans
                     if s.name == "w-lease")
    serialize = sum(s.duration_ms for s in spans if s.name == "serialize")
    fab_solve = sum(s.duration_ms for s in spans
                    if s.name == "fabric-solve")
    dispatch_gap = max(0.0, lease_wall - worker_eval - worker_wire)
    pool_eval = sum(s.duration_ms for s in pool_trace.spans
                    if s.name == "shard-eval")
    attribution = {
        "pool_total_ms": round(pool_ms, 3),
        "fabric_w1_total_ms": round(fab_ms, 3),
        "gap_ms": round(fab_ms - pool_ms, 3),
        "pool_shard_eval_ms": round(pool_eval, 3),
        "fabric_solve_ms": round(fab_solve, 3),
        "serialize_ms": round(serialize, 3),
        "lease_wall_ms": round(lease_wall, 3),
        "worker_eval_ms": round(worker_eval, 3),
        "worker_result_wire_ms": round(worker_wire, 3),
        "dispatch_gap_ms": round(dispatch_gap, 3),
        "leases": sum(1 for s in spans if s.name == "lease"),
    }
    out["w1_attribution"] = attribution
    print(f"trace_overhead_w1_vs_pool,{fab_ms*1e3:.0f},"
          f"pool={pool_ms:.1f}ms;serialize={serialize:.1f}ms;"
          f"eval={worker_eval:.1f}ms;wire={worker_wire:.1f}ms;"
          f"dispatch_gap={dispatch_gap:.1f}ms")

    # the acceptance gates: disabled hooks are noise, enabled < 3%
    assert hooks_off_pct < 0.5, hooks_off_pct
    assert hooks_on_pct < 3.0, hooks_on_pct
    with open("results/BENCH_trace_overhead.json", "w") as f:
        json.dump(out, f, indent=1)


BENCHES = {
    "joint_plan": bench_joint_plan,
    "trace_overhead": bench_trace_overhead,
    "multi_tenant": bench_multi_tenant,
    "solver": lambda fast: bench_solver(),
    "planner_cache": lambda fast: bench_planner_cache(),
    "compile_cache": lambda fast: bench_compile_cache(),
    "plan_service": lambda fast: bench_plan_service(),
    "solver_shards": bench_solver_shards,
    "solve_fabric": bench_solve_fabric,
    "feedback_scorer": bench_feedback_scorer,
    "certify": bench_certify,
    "kernels": lambda fast: bench_kernels(),
    "tables": bench_tables,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the cost-model CV (slowest part)")
    ap.add_argument("--only", choices=sorted(BENCHES), default=None,
                    help="run a single benchmark (CI smoke)")
    args = ap.parse_args()
    import os
    os.makedirs("results", exist_ok=True)
    print("name,us_per_call,derived")
    if args.only is not None:
        BENCHES[args.only](args.fast)
        return
    bench_solver()
    bench_planner_cache()
    bench_compile_cache()
    bench_plan_service()
    bench_solver_shards(args.fast)
    bench_solve_fabric(args.fast)
    bench_multi_tenant(args.fast)
    bench_joint_plan(args.fast)
    bench_feedback_scorer(args.fast)
    bench_certify(args.fast)
    bench_trace_overhead(args.fast)
    bench_kernels()
    bench_tables(args.fast)


if __name__ == "__main__":
    main()
