"""Plan stores: cross-process DirectoryStore (lock contention, torn JSON
as a miss, legacy cache_dir layout equivalence) and MemoryStore."""

import json
import os
import threading
import time

import pytest

from repro.core import (AccessDecl, BankingPlanner, Counter, Ctrl,
                        MemorySpec, Program, Sched, SolverOptions)
from repro.core.polytope import Affine
from repro.core.store import DirectoryStore, FileLock, MemoryStore


def _reader_program(stride=1, count=32, par=8, dims=(256,), name="table"):
    mem = MemorySpec(name, dims=dims, word_bits=32, ports=1)
    return Program(
        root=Ctrl("reader", Sched.INNER,
                  counters=[Counter("i", 0, 1, count, par=par)],
                  accesses=[AccessDecl(name, (Affine.of(i=stride),))]),
        memories={name: mem},
    )


@pytest.fixture
def solve_counter(monkeypatch):
    """Counts cold solves at the universal chokepoint (candidate-space
    enumeration) -- every solve path passes through build_space."""
    calls = []
    real = BankingPlanner.build_space

    def counting(self, prep):
        calls.append(1)
        return real(self, prep)

    monkeypatch.setattr(BankingPlanner, "build_space", counting)
    return calls


# ---------------------------------------------------------------------------
# MemoryStore
# ---------------------------------------------------------------------------


def test_memory_store_roundtrip_and_family():
    planner = BankingPlanner(store=MemoryStore())
    a = planner.plan(_reader_program(), "table",
                     opts=SolverOptions(n_budget=8))
    store = planner.store
    assert store.get(a.signature, a.scorer_name).signature == a.signature
    assert store.get("nope", "proxy") is None
    assert store.get_artifact(a.signature, a.scorer_name, "jax") is None
    art = planner.compile(a)
    assert store.get_artifact(a.signature, a.scorer_name,
                              "jax").signature == art.signature
    near = store.find_family(a.family)
    assert near is not None and near.signature == a.signature
    assert store.find_family(a.family,
                             exclude_signature=a.signature) is None


def test_memory_store_shared_between_planners(solve_counter):
    store = MemoryStore()
    BankingPlanner(store=store).plan(_reader_program(), "table")
    hit = BankingPlanner(store=store).plan(_reader_program(), "table")
    assert hit.status == "cached-disk" and len(solve_counter) == 1


# ---------------------------------------------------------------------------
# DirectoryStore: legacy layout equivalence
# ---------------------------------------------------------------------------


def test_directory_store_uses_legacy_cache_dir_layout(tmp_path,
                                                      solve_counter):
    """A directory written through cache_dir= reads through DirectoryStore
    and vice versa -- same files, same warm-start behaviour."""
    old = BankingPlanner(cache_dir=tmp_path)
    plan = old.plan(_reader_program(), "table")
    old.compile(plan)
    assert isinstance(old.store, DirectoryStore)   # cache_dir IS a store now
    # the store API reads what cache_dir wrote, at the documented paths
    store = DirectoryStore(tmp_path)
    assert store.plan_path(plan.signature, "proxy").exists()
    assert store.artifact_path(plan.signature, "proxy", "jax").exists()
    got = store.get(plan.signature, "proxy")
    assert got.best.geometry == plan.best.geometry
    assert store.get_artifact(plan.signature, "proxy",
                              "jax").layout == old.compile(plan).layout
    # a second planner over the same directory: disk hit, zero solves
    warm = BankingPlanner(store=DirectoryStore(tmp_path))
    hit = warm.plan(_reader_program(), "table")
    assert hit.status == "cached-disk" and len(solve_counter) == 1
    # warm_start() preloads plans + artifacts from a store or a path
    fresh = BankingPlanner()
    assert fresh.warm_start(tmp_path) == 2
    assert fresh.plan(_reader_program(), "table").status == "cached"
    assert len(solve_counter) == 1
    # ...and single-file warm starts work for both file kinds
    solo = BankingPlanner()
    assert solo.warm_start(store.plan_path(plan.signature, "proxy")) == 1
    assert solo.warm_start(
        store.artifact_path(plan.signature, "proxy", "jax")) == 1
    solo.compile(solo.plan(_reader_program(), "table"))
    assert solo.stats.compiles == 0 and solo.stats.compile_hits == 1


def test_torn_json_reads_as_miss_and_heals(tmp_path, solve_counter):
    """A partially-written plan file (torn write, crashed process) is a
    miss -- the reader re-solves and the write path repairs the entry."""
    planner = BankingPlanner(cache_dir=tmp_path)
    plan = planner.plan(_reader_program(), "table")
    store = DirectoryStore(tmp_path)
    path = store.plan_path(plan.signature, "proxy")
    blob = path.read_text()
    path.write_text(blob[: len(blob) // 2])       # torn mid-write
    assert store.get(plan.signature, "proxy") is None
    repaired = BankingPlanner(cache_dir=tmp_path)
    again = repaired.plan(_reader_program(), "table")
    assert again.status == "solved" and len(solve_counter) == 2
    assert json.loads(path.read_text())["signature"] == plan.signature
    # foreign / wrong-format JSON is also just a miss
    path.write_text(json.dumps({"format": "something-else"}))
    assert store.get(plan.signature, "proxy") is None


# ---------------------------------------------------------------------------
# Eviction + signature versioning
# ---------------------------------------------------------------------------


def test_size_capped_lru_eviction(tmp_path):
    """A max_bytes store evicts least-recently-used entries (by mtime)
    after each write; recently-read entries are touched and survive."""
    probe = DirectoryStore(tmp_path)
    planner = BankingPlanner(store=probe)
    plans = [planner.plan(_reader_program(stride=s), "table",
                          opts=SolverOptions(n_budget=6, max_solutions=4))
             for s in (1, 2, 3)]
    sizes = [probe.plan_path(p.signature, "proxy").stat().st_size
             for p in plans]
    # cap fits roughly two entries -> writing a third must evict one
    capped = DirectoryStore(tmp_path, max_bytes=sizes[0] + sizes[1]
                            + sizes[2] // 2)
    # age the files oldest-first so LRU order is deterministic
    now = time.time()
    for i, p in enumerate(plans):
        path = capped.plan_path(p.signature, "proxy")
        os.utime(path, (now - 100 + i, now - 100 + i))
    # reading the OLDEST entry freshens it...
    assert capped.get(plans[0].signature, "proxy") is not None
    # ...so the write-triggered eviction takes the now-oldest instead
    capped.put(plans[0])
    assert capped.get(plans[1].signature, "proxy") is None      # evicted
    assert capped.get(plans[0].signature, "proxy") is not None  # touched
    total = sum(f.stat().st_size for f in tmp_path.glob("bp*.json"))
    assert total <= capped.max_bytes


def test_sweep_collects_stale_signature_versions(tmp_path):
    """sweep() removes entries whose filename signature carries a stale
    SIGNATURE_VERSION prefix -- and nothing else (foreign files like the
    persisted ml scorer share the directory)."""
    store = DirectoryStore(tmp_path)
    planner = BankingPlanner(store=store)
    plan = planner.plan(_reader_program(), "table",
                        opts=SolverOptions(n_budget=6, max_solutions=4))
    live = store.plan_path(plan.signature, "proxy")
    stale_sig = "bp0-" + plan.signature.split("-", 1)[1]
    stale = tmp_path / f"{stale_sig}.proxy.json"
    stale.write_text(live.read_text())
    stale_art = tmp_path / f"{stale_sig}.proxy.jax.compiled.json"
    stale_art.write_text("{}")
    foreign = tmp_path / "ml_scorer.json"
    foreign.write_text("{}")
    assert store.sweep() == 2
    assert not stale.exists() and not stale_art.exists()
    assert live.exists() and foreign.exists()
    assert store.get(plan.signature, "proxy") is not None
    assert store.sweep() == 0        # idempotent


def test_serve_launcher_wires_store_cap(tmp_path, monkeypatch):
    """launch/serve.py --plan-store-max-mb builds a capped store and
    sweeps it at startup (smoke: flag parsing + wiring only)."""
    import sys

    from repro.launch import serve as serve_mod

    built = {}
    real_store = serve_mod.__dict__.get("DirectoryStore")  # noqa: F841

    class SpyStore(DirectoryStore):
        def __init__(self, path, **kw):
            super().__init__(path, **kw)
            built["max_bytes"] = self.max_bytes

        def sweep(self):
            built["swept"] = True
            return super().sweep()

    class Bail(Exception):
        pass

    def stop(*a, **kw):
        raise Bail()

    monkeypatch.setattr("repro.core.store.DirectoryStore", SpyStore)
    monkeypatch.setattr("repro.configs.get_arch", stop, raising=False)
    monkeypatch.setattr(sys, "argv",
                        ["serve", "--arch", "qwen2_7b", "--smoke",
                         "--plan-store", str(tmp_path),
                         "--plan-store-max-mb", "2"])
    with pytest.raises(Bail):
        serve_mod.main()
    assert built == {"max_bytes": 2 * 2 ** 20, "swept": True}


# ---------------------------------------------------------------------------
# Lock file
# ---------------------------------------------------------------------------


def test_file_lock_mutual_exclusion(tmp_path):
    lock_path = tmp_path / "x.lock"
    counter = {"v": 0}
    errors = []

    def bump():
        try:
            for _ in range(25):
                with FileLock(lock_path, timeout=10.0):
                    v = counter["v"]
                    time.sleep(0.0002)       # widen the race window
                    counter["v"] = v + 1
        except BaseException as e:  # pragma: no cover - failure reporting
            errors.append(e)

    threads = [threading.Thread(target=bump) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert counter["v"] == 100
    assert not lock_path.exists()           # released


def test_stale_lock_is_broken_not_deadlocked(tmp_path):
    lock_path = tmp_path / "x.lock"
    lock_path.write_text("999999")          # a crashed holder's leftover
    old = time.time() - 3600
    os.utime(lock_path, (old, old))
    with FileLock(lock_path, timeout=2.0, stale_seconds=30.0):
        pass                                 # acquired by breaking the stale


def test_lock_timeout_raises(tmp_path):
    lock_path = tmp_path / "x.lock"
    with FileLock(lock_path, timeout=5.0):
        inner = FileLock(lock_path, timeout=0.05, stale_seconds=3600.0)
        with pytest.raises(TimeoutError):
            inner.acquire()


# ---------------------------------------------------------------------------
# Cross-process concurrency (two planners = two "processes" on one dir)
# ---------------------------------------------------------------------------


def test_two_planners_share_one_directory_concurrently(tmp_path):
    """Several planners hammer one DirectoryStore with the same and with
    distinct problems concurrently: every plan resolves, the shared files
    stay valid JSON, and the store ends deduplicated by signature."""
    programs = [_reader_program(stride=s) for s in (1, 2, 3)]
    planners = [BankingPlanner(store=DirectoryStore(tmp_path))
                for _ in range(2)]
    results, errors = [], []

    def worker(i):
        try:
            p = planners[i % 2].plan(programs[i % 3], "table")
            results.append(p)
        except BaseException as e:  # pragma: no cover - failure reporting
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors and len(results) == 8
    assert all(p.best is not None for p in results)
    # same stride -> same signature, regardless of which planner solved it
    sigs = {}
    for p in results:
        sigs.setdefault(p.signature, set()).add(p.best.geometry)
    assert len(sigs) == 3
    assert all(len(geos) == 1 for geos in sigs.values())
    # every persisted file is whole, valid JSON in the legacy layout
    files = [f for f in tmp_path.glob("*.json")]
    assert len([f for f in files if not f.name.endswith(".compiled.json")]) \
        == 3
    for f in files:
        assert json.loads(f.read_text())["signature"]
    # and a third "process" warm-starts entirely from the shared directory
    third = BankingPlanner(store=DirectoryStore(tmp_path))
    for prog in programs:
        assert third.plan(prog, "table").status == "cached-disk"
