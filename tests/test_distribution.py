"""Distribution layer: banking bridge, pipeline parallelism (subprocess
with a forced multi-device CPU), mini dry-run integration."""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.parallel import sharding as shd


def test_bankable_bridge():
    assert shd.bankable(8192, 16)
    assert shd.bankable(102400, 16)
    assert shd.bankable(64, 16)
    assert not shd.bankable(8, 16)        # fewer heads than lanes
    assert not shd.bankable(51865, 16)    # non-divisible vocab
    assert shd.bankable(240, 16)


def test_param_specs_roles():
    from repro.configs import get_arch
    from repro.models import get_model
    import dataclasses

    cfg = dataclasses.replace(get_arch("deepseek_67b"), n_layers=2)
    model = get_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    # fake a 16-wide model axis by asking bankable directly: use specs from
    # the production shape via a mesh-shaped namespace
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    specs = shd.param_specs(shapes, FakeMesh(), fsdp=False)
    assert specs["embed"] == jax.sharding.PartitionSpec("model", None)
    assert specs["layers"]["wq"][-1] == "model"
    assert specs["layers"]["wo"][1] == "model"
    assert specs["layers"]["w_down"][1] == "model"


def _run_subprocess(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_pipeline_matches_sequential():
    """4-stage GPipe == sequential layer application (subprocess: 4 devs)."""
    _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.parallel.pipeline import pipeline_apply

        S, L_per, M, mb, D = 4, 2, 8, 4, 16
        mesh = jax.make_mesh((S,), ("stage",))
        rng = np.random.default_rng(0)
        Ws = jnp.asarray(rng.normal(size=(S, L_per, D, D)) * 0.2, jnp.float32)

        def stage_fn(params, x):  # params (L_per, D, D)
            def body(h, w):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, params)
            return h

        x = jnp.asarray(rng.normal(size=(M, mb, D)), jnp.float32)
        got = pipeline_apply(stage_fn, Ws, x, mesh, axis="stage")
        # sequential reference
        h = x
        for s in range(S):
            h = jax.vmap(lambda xi: stage_fn(Ws[s], xi))(h)
        np.testing.assert_allclose(np.asarray(got), np.asarray(h),
                                   atol=1e-5)
        print("PIPELINE-OK")
    """)


@pytest.mark.slow
def test_mini_dryrun_multipod():
    """End-to-end dry-run on a (2,2,2) mini multi-pod mesh (subprocess)."""
    out = _run_subprocess("""
        import os
        import dataclasses, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        import repro.launch.dryrun as dr
        import repro.configs as C

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        base = C.get_arch("whisper_base")
        cfg = dataclasses.replace(base.reduced(), n_heads=4, n_kv_heads=2)
        C_get = C.get_arch
        dr.get_arch = lambda n: cfg
        shape = dataclasses.replace(dr.SHAPES["train_4k"], seq_len=64,
                                    global_batch=8)
        dr.SHAPES = dict(dr.SHAPES); dr.SHAPES["train_4k"] = shape
        r = dr.lower_cell("whisper_base", "train_4k", mesh)
        assert r["flops"] > 0
        print("DRYRUN-OK", r["compile_s"])
    """, devices=8)
    assert "DRYRUN-OK" in out


def test_logical_axis_cache_specs():
    from repro.configs import get_arch
    from repro.configs.base import SHAPES

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    specs = shd.cache_specs(get_arch("zamba2_2_7b"), SHAPES["decode_32k"],
                            FakeMesh())
    assert specs.k[3] == "model"  # 32 kv heads shard over 16
    specs_long = shd.cache_specs(get_arch("gemma3_12b"), SHAPES["long_500k"],
                                 FakeMesh())
    assert specs_long.k[2] == ("data", "model")  # seq spread over all axes
