"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Sk,H,Hkv,Dh", [
    (1, 128, 128, 2, 2, 64),
    (2, 256, 256, 4, 2, 64),
    (1, 128, 384, 4, 1, 128),   # GQA rep=4, rectangular
    (2, 64, 64, 2, 2, 32),      # small blocks
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 32), (False, 0)])
def test_flash_attention_sweep(B, Sq, Sk, H, Hkv, Dh, dtype, causal, window):
    q = _rand((B, Sq, H, Dh), dtype)
    k = _rand((B, Sk, Hkv, Dh), dtype)
    v = _rand((B, Sk, Hkv, Dh), dtype)
    got = ops.mha(q, k, v, causal=causal, window=window,
                  block_q=64, block_k=64)
    rep = H // Hkv
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, Dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, Sk, Dh)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, Sk, Dh)
    want = ref.mha_reference(qf, kf, vf, causal=causal, window=window)
    want = want.reshape(B, H, Sq, Dh).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.slow
@pytest.mark.parametrize("B,H,Q,P,N", [
    (1, 2, 32, 16, 8),
    (2, 4, 64, 32, 16),
    (1, 8, 128, 64, 32),
])
def test_ssd_chunk_sweep(B, H, Q, P, N):
    x = _rand((B, H, Q, P), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, size=(B, H, Q)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    cum = jnp.cumsum(dt * A[None, :, None], axis=-1)
    bm = _rand((B, Q, N), jnp.float32)
    cm = _rand((B, Q, N), jnp.float32)
    s0 = _rand((B, H, P, N), jnp.float32)
    y, s1 = ops.ssd(x, dt, bm, cm, cum, s0)
    yw, s1w = ref.ssd_chunk_reference(x, dt, bm, cm, cum, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yw), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s1w), atol=1e-4)


def test_ssd_kernel_matches_model_scan():
    """The kernel chunk == one step of models.ssm.ssd_chunked."""
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N, Q = 2, 128, 4, 16, 8, 32
    x = _rand((B, S, H, P), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, size=(B, S, H)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    bm = _rand((B, S, N), jnp.float32)
    cm = _rand((B, S, N), jnp.float32)
    y_model, s_model = ssd_chunked(x, dt, A, bm, cm, Q)
    # drive the kernel chunk-by-chunk
    s = jnp.zeros((B, H, P, N), jnp.float32)
    outs = []
    for c in range(S // Q):
        sl = slice(c * Q, (c + 1) * Q)
        dtc = dt[:, sl].transpose(0, 2, 1)            # (B, H, Q)
        cum = jnp.cumsum(dtc * A[None, :, None], axis=-1)
        y, s = ops.ssd(x[:, sl].transpose(0, 2, 1, 3), dtc,
                       bm[:, sl], cm[:, sl], cum, s)
        outs.append(y.transpose(0, 2, 1, 3))          # (B, Q, H, P)
    y_kern = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_kern), np.asarray(y_model),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_model), atol=2e-4)


@pytest.mark.parametrize("T,D,EC", [(32, 16, 48), (128, 64, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_dispatch_sweep(T, D, EC, dtype):
    x = _rand((T, D), dtype)
    slot = jnp.asarray(RNG.integers(0, T + 1, size=(EC,)), jnp.int32)
    got = ops.dispatch(x, slot)
    xp = jnp.concatenate([x, jnp.zeros((1, D), dtype)])
    want = ref.moe_dispatch_reference(xp, slot)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("A,par,ports", [(64, 8, 1), (60, 4, 2), (48, 6, 1)])
def test_banked_gather_sweep(A, par, ports):
    from repro.core import (AccessDecl, BankingPlanner, Counter, Ctrl,
                            MemorySpec, Program, Sched)
    from repro.core.polytope import Affine

    mem = MemorySpec("t", dims=(A,), word_bits=32, ports=ports)
    inner = Ctrl("rd", Sched.INNER,
                 counters=[Counter("i", 0, 1, A // par, par=par)],
                 accesses=[AccessDecl("t", (Affine.of(i=1),))])
    prog = Program(root=inner, memories={"t": mem})
    art = BankingPlanner().plan(prog, "t").compile()
    D = 8
    flat = _rand((A, D), jnp.float32)
    table = art.pack(flat)
    assert table.shape == art.layout.table_shape(D)
    idx = jnp.asarray(RNG.integers(0, A, size=(24,)), jnp.int32)
    got = art.gather(table, idx)
    want = ref.banked_gather_reference(flat, idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the ops-level wrappers accept the compiled artifact too
    got2 = ops.gather_banked(ops.pack_banked(flat, art), idx, art)
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(want))


@pytest.mark.slow
def test_moe_sorted_vs_dense_oracle():
    """sorted dispatch == dense oracle when capacity is unconstrained."""
    import dataclasses
    from repro.configs import get_arch
    from repro.models import moe as moe_mod

    cfg = dataclasses.replace(get_arch("olmoe_1b_7b").reduced(),
                              capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    params = moe_mod.init_moe_params(cfg, key)
    lp = jax.tree.map(lambda x: x[0], params["layers"])
    h = _rand((2, 16, cfg.d_model), jnp.float32)
    yd, _ = moe_mod.moe_ffn_dense(cfg, lp, h)
    ys, _ = moe_mod.moe_ffn_sorted(cfg, lp, h)
    np.testing.assert_allclose(np.asarray(yd, np.float32),
                               np.asarray(ys, np.float32), atol=3e-2)
