"""System-level banking properties: grouping, validity, scheme soundness."""


import numpy as np
import pytest

from repro.core import (
    AccessDecl,
    BankingPlanner,
    Counter,
    Ctrl,
    MemorySpec,
    Program,
    Sched,
    build_groups,
    unroll
)
from repro.core.polytope import Affine
from repro.core import problems


def _plan(prog, memory):
    """Fresh planner per problem: these tests exercise the solve path."""
    return BankingPlanner().plan(prog, memory)


def _simulate_conflicts(sol, accesses, iters, n_samples=60, seed=0):
    """Brute-force: sample synchronized iterator assignments and count the
    max number of distinct accesses landing on one bank per cycle."""
    rng = np.random.default_rng(seed)
    geo = sol.geometry
    worst = 1
    for _ in range(n_samples):
        env = {}
        for name, it in iters.items():
            cnt = it.count if it.count is not None else 32
            env[name] = it.start + it.step * int(rng.integers(0, max(cnt, 1)))
        # uninterpreted symbols: random but consistent per key
        banks = {}
        for a in accesses:
            for e in a.exprs:
                for k, _ in e.syms:
                    env.setdefault(k, int(rng.integers(0, 16)))
            x = [e.evaluate(env) for e in a.exprs]
            if any(xi < 0 or xi >= d + p for xi, d, p in
                   zip(x, sol.memory.dims, sol.pad)):
                continue
            b = geo.bank_address(x)
            banks.setdefault(b, set()).add(id(a))
        if banks:
            worst = max(worst, max(len(v) for v in banks.values()))
    return worst


def _dup_split(sol, groups):
    """Mirror the solver's bank-by-duplication routing: the largest read
    group splits round-robin across duplicates; others broadcast."""
    if sol.duplicates <= 1:
        return [list(g) for g in groups]
    read_groups = [g for g in groups if not any(a.is_write for a in g)]
    big = max(read_groups, key=len)
    out = [list(g) for g in groups if g is not big]
    for i in range(sol.duplicates):
        out.append(list(big)[i::sol.duplicates])
    return out


@pytest.mark.parametrize("name", problems.STENCILS + ["sw", "sgd", "md_grid"])
def test_best_scheme_is_conflict_free(name):
    prog = problems.build(name)
    memname = list(prog.memories)[0]
    rep = _plan(prog, memname)
    assert rep.best is not None, name
    up = unroll(prog)
    groups = build_groups(up, memname)
    for g in _dup_split(rep.best, groups):
        worst = _simulate_conflicts(rep.best, g, up.iterators)
        assert worst <= prog.memories[memname].ports, (
            name, rep.best.describe(), worst)


def test_md_grid_groups_match_paper():
    """Paper Eq. 4: one writer group (PL lanes), one reader group."""
    prog = problems.md_grid_program(PL=2, PX=2, PY=1, PZ=1, PQ=2)
    up = unroll(prog)
    groups = build_groups(up, "dvec")
    sizes = sorted(len(g) for g in groups)
    assert sizes == [2, 4]  # writers PL=2; readers PX*PY*PZ*PQ=4


def test_sequential_controllers_not_grouped():
    """sgd's two access modes are never concurrent -> two groups."""
    prog = problems.sgd_program(par_a=2, par_b=2)
    up = unroll(prog)
    groups = build_groups(up, "data")
    assert len(groups) == 2
    assert all(len(g) == 4 for g in groups)


def test_figure3_solutions():
    """Paper Fig. 3: arr(k+1), arr(k+2), k by 3 par 2 -> N=6 has FO=1."""
    mem = MemorySpec("arr", dims=(96,), word_bits=16, ports=1)
    inner = Ctrl("k", Sched.INNER,
                 counters=[Counter("k", 0, 3, 16, par=2)],
                 accesses=[AccessDecl("arr", (Affine.of(const=1, k=1),)),
                           AccessDecl("arr", (Affine.of(const=2, k=1),))])
    prog = Program(root=inner, memories={"arr": mem})
    rep = _plan(prog, "arr")
    kinds = {(s.geometry.N, s.geometry.B) for s in rep.solutions
             if s.kind == "flat"}
    assert (6, 1) in kinds  # paper's Option 3
    n6 = [s for s in rep.solutions
          if s.kind == "flat" and s.geometry.N == 6 and s.geometry.B == 1][0]
    assert max(n6.fan_outs) == 1
    # and a 5-bank option-1-style scheme exists with full fan-out
    assert any(s.kind == "flat" and s.geometry.N == 5 for s in rep.solutions)


def test_ports_relax_validity():
    """Dual-ported memories accept schemes single-ported ones reject."""
    def build(ports):
        mem = MemorySpec("m", dims=(32,), ports=ports)
        inner = Ctrl("i", Sched.INNER,
                     counters=[Counter("i", 0, 1, 16, par=2)],
                     accesses=[AccessDecl("m", (Affine.of(i=2),)),
                               AccessDecl("m", (Affine.of(i=2, const=1),))])
        return Program(root=inner, memories={"m": mem})

    r1 = _plan(build(1), "m")
    r2 = _plan(build(2), "m")
    assert min(s.num_banks for s in r2.solutions) <= \
        min(s.num_banks for s in r1.solutions)


def test_spmv_multidim_regrouping():
    """Paper Sec 4: spmv's random row offsets disappear under projection."""
    prog = problems.spmv_program()
    rep = _plan(prog, "mat")
    assert any(s.kind == "multidim" for s in rep.solutions)
    best_md = min((s for s in rep.solutions if s.kind == "multidim"),
                  key=lambda s: s.score)
    # row dimension banked 4 ways despite the uninterpreted column offset
    assert best_md.geometry.Ns[0] % 4 == 0


def test_duplication_offered_for_heavy_readers():
    prog = problems.sgd_program(par_a=4, par_b=3)
    rep = _plan(prog, "data")
    assert any(s.duplicates > 1 for s in rep.solutions)


def test_solver_all_solutions_dsp_free_with_full_transforms():
    prog = problems.build("sobel")
    rep = _plan(prog, "img")
    best = rep.best
    assert best.resources.total.dsp == 0


def test_unroll_strategies_synchronization():
    """Sec 3.2: data-dependent inner bounds desynchronize outer lanes under
    PoF (per-lane counter bases) but not when the subtree is static."""
    from repro.core.controller import Unroll

    def build(count, strategy):
        mem = MemorySpec("m", dims=(64,), ports=2)
        inner = Ctrl("q", Sched.INNER,
                     counters=[Counter("q", 0, 1, count)],
                     accesses=[AccessDecl("m", (Affine.of(q=1),))])
        outer = Ctrl("x", Sched.PIPELINED,
                     counters=[Counter("x", 0, 1, 8, par=2)],
                     children=[inner])
        return Program(root=outer, memories={"m": mem},
                       unroll_strategy=strategy)

    # static bounds: lanes stay lockstep -> one shared iterator q
    up = unroll(build(16, Unroll.POF))
    names = {t[0] for a in up.accesses for t in a.exprs[0].terms}
    assert len(names) == 1

    # data-dependent bounds (count=None) + PoF: per-lane fresh iterators
    up = unroll(build(None, Unroll.POF))
    names = {t[0] for a in up.accesses for t in a.exprs[0].terms}
    assert len(names) == 2  # q@0 and q@1 -- conservative widening


def test_vectorization_lanes_share_counter_base():
    """Lanes of one inner counter are the same physical counter: shared
    base + constant offsets (exact deltas), never fresh variables."""
    mem = MemorySpec("m", dims=(64,), ports=1)
    inner = Ctrl("i", Sched.INNER,
                 counters=[Counter("i", 0, 1, None, par=4)],  # data-dep stop
                 accesses=[AccessDecl("m", (Affine.of(i=1),))])
    prog = Program(root=inner, memories={"m": mem})
    up = unroll(prog)
    names = {t[0] for a in up.accesses for t in a.exprs[0].terms}
    assert len(names) == 1  # one base, four constant lane offsets
    consts = sorted(a.exprs[0].const for a in up.accesses)
    assert consts == [0, 1, 2, 3]
