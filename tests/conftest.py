"""Shared fixtures: certifier counterexamples auto-render as pytest
cases.

When the independent certifier (``repro.analysis.certify``) refutes a
scheme, its :class:`Counterexample` carries everything needed to replay
the collision.  The ``render_counterexample`` fixture turns one into an
importable test file under the pytest tmp dir and executes it, so any
solver/certifier disagreement found during a run can be frozen into the
suite as a reproducible case instead of a log line.
"""

import importlib.util

import pytest


def _render_counterexample(cex, tmp_path, name="test_rendered_cex"):
    """Write ``cex`` as a standalone pytest file, import it, and run the
    generated test function.  Returns the path for copying into tests/."""
    path = tmp_path / f"{name}.py"
    path.write_text(cex.to_pytest(name))
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    getattr(mod, name)()   # the rendered assertions must hold
    return path


@pytest.fixture
def render_counterexample(tmp_path):
    """Render a certifier :class:`Counterexample` as a pytest case file
    in ``tmp_path``, execute its assertions, and return the path."""
    def render(cex, name="test_rendered_cex"):
        return _render_counterexample(cex, tmp_path, name)
    return render
