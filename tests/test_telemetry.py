"""Measured-cost telemetry: observation log aggregation, cross-process
sidecar persistence, the "measured" scorer, demotion (exactly one
speculative re-solve), and the end-to-end self-correction loop --
serve a mis-ranked plan, measure it through Server.tick, demote,
re-solve, hot-swap to a measurably faster scheme."""

import json
import multiprocessing
import os
import time

import numpy as np
import pytest

from repro.core import (AccessDecl, BankingPlanner, Counter, Ctrl,
                        FlatGeometry, MemorySpec, MemoryStore, PlanService,
                        Program, Sched, SolverOptions, compile_geometry)
from repro.core import planner as planner_mod
from repro.core.cost_model import MLScorer, ResourcePipeline
from repro.core.features import FEATURE_NAMES
from repro.core.polytope import Affine
from repro.core.store import DirectoryStore
from repro.core.telemetry import (DATA_OPS, MeasuredCost, MeasuredScorer,
                                  TelemetryConfig, TelemetryLog,
                                  roofline_floor_seconds,
                                  roofline_prior_seconds, scheme_hash,
                                  shape_bucket)


def _reader_program(stride=1, count=32, par=8, dims=(256,), name="table"):
    mem = MemorySpec(name, dims=dims, word_bits=32, ports=1)
    return Program(
        root=Ctrl("reader", Sched.INNER,
                  counters=[Counter("i", 0, 1, count, par=par)],
                  accesses=[AccessDecl(name, (Affine.of(i=stride),))]),
        memories={name: mem},
    )


@pytest.fixture
def solve_counter(monkeypatch):
    """Counts cold solves at the universal chokepoint."""
    calls = []
    real = BankingPlanner.build_space

    def counting(self, prep):
        calls.append(1)
        return real(self, prep)

    monkeypatch.setattr(BankingPlanner, "build_space", counting)
    return calls


@pytest.fixture
def ml_isolation(monkeypatch, tmp_path):
    """Sandbox the process-wide ml-scorer globals: tests below retrain,
    refresh, and repoint the persisted pipeline without leaking into (or
    inheriting from) the rest of the suite."""
    saved = {k: planner_mod._ml_scorer_factory.__dict__.get(k)
             for k in ("_cached", "_cached_mtime")}
    monkeypatch.setattr(planner_mod, "_ML_SCORER_PATH",
                        tmp_path / "ml_scorer.json")
    for k in ("_cached", "_cached_mtime"):
        planner_mod._ml_scorer_factory.__dict__.pop(k, None)
    yield tmp_path / "ml_scorer.json"
    for k, v in saved.items():
        if v is None:
            planner_mod._ml_scorer_factory.__dict__.pop(k, None)
        else:
            planner_mod._ml_scorer_factory.__dict__[k] = v


# ---------------------------------------------------------------------------
# Records + log aggregation
# ---------------------------------------------------------------------------


def test_measured_cost_aggregation_and_roundtrip():
    rec = MeasuredCost(signature="sig", scheme="s1", backend="jax",
                       op="gather", bucket="8")
    for s in (1.0, 2.0, 3.0, 10.0):
        rec.observe(s)
    assert rec.count == 4 and rec.mean == pytest.approx(4.0)
    assert rec.p50() == pytest.approx(2.5)
    assert rec.p95() > rec.p50()
    other = MeasuredCost(signature="sig", scheme="s1", backend="jax",
                         op="gather", bucket="8")
    other.observe(20.0, prior=0.5)
    rec.merge(other)
    assert rec.count == 5
    assert rec.mean == pytest.approx((1 + 2 + 3 + 10 + 20) / 5)
    assert rec.prior == 0.5
    back = MeasuredCost.from_json(
        json.loads(json.dumps(rec.to_json())))
    assert back.key == rec.key and back.count == rec.count
    assert back.p50() == rec.p50() and back.prior == rec.prior


def test_shape_bucket_pow2_ceiling():
    assert shape_bucket((3,)) == "4"
    assert shape_bucket((4,)) == "4"
    assert shape_bucket((5, 17)) == "8x32"
    assert shape_bucket(()) == "scalar"
    assert shape_bucket(7) == "8"     # scalar count coerces
    assert shape_bucket((1,)) == "1"


def test_scheme_hash_is_structural_and_cached():
    mem = MemorySpec("m", dims=(64,), word_bits=16, ports=1)
    geo = FlatGeometry(N=4, B=8, alpha=(1,), P=(16,))
    a = compile_geometry(mem, geo, backend="numpy")
    b = compile_geometry(mem, geo, backend="numpy")
    assert scheme_hash(a) == scheme_hash(b)          # content, not identity
    assert a._scheme_hash == scheme_hash(a)          # cached on the object
    other = compile_geometry(
        mem, FlatGeometry(N=8, B=8, alpha=(1,), P=(8,)), backend="numpy")
    assert scheme_hash(other) != scheme_hash(a)


def test_log_pending_deltas_and_hydrate_do_not_double_count():
    log = TelemetryLog()
    log.observe("sig", "s1", "jax", "gather", (8,), 1.0, prior=0.25)
    log.observe("sig", "s1", "jax", "gather", (8,), 3.0)
    drained = log.drain()
    assert [r.count for r in drained["sig"]] == [2]
    assert log.drain() == {}                      # deltas cleared
    count, p50 = log.scheme_measured("s1")        # cumulative view intact
    assert count == 2 and p50 == pytest.approx(2.0)
    # hydrating store-side history merges reads without re-flushing
    foreign = MeasuredCost(signature="sig", scheme="s1", backend="jax",
                           op="gather", bucket="8")
    foreign.observe(5.0)
    assert log.hydrate([foreign]) == 1
    count, _ = log.scheme_measured("s1")
    assert count == 3 and log.drain() == {}
    assert log.calibration() == pytest.approx(log.scheme_measured("s1")[1]
                                              / 0.25)


# ---------------------------------------------------------------------------
# Cross-process persistence (tentpole acceptance: concurrent, lossless)
# ---------------------------------------------------------------------------


def _telemetry_worker(dirpath, sig, tag, n):
    from repro.core.store import DirectoryStore as DS
    from repro.core.telemetry import MeasuredCost as MC

    store = DS(dirpath)
    for i in range(n):
        rec = MC(signature=sig, scheme=f"s{tag}", backend="jax",
                 op="gather", bucket="8")
        rec.observe(0.001 * (i + 1))
        store.merge_telemetry(sig, [rec])


def test_two_processes_merge_telemetry_without_loss(tmp_path):
    """Two processes hammering one DirectoryStore sidecar with per-call
    deltas: the read-merge-write under the store lock loses nothing."""
    n = 20
    procs = [multiprocessing.Process(target=_telemetry_worker,
                                     args=(str(tmp_path), "sigX", tag, n))
             for tag in ("a", "b")]
    for p in procs:
        p.start()
    for p in procs:
        p.join(60)
        assert p.exitcode == 0
    recs = DirectoryStore(tmp_path).get_telemetry("sigX")
    by_scheme = {r.scheme: r for r in recs}
    assert set(by_scheme) == {"sa", "sb"}
    assert by_scheme["sa"].count == n and by_scheme["sb"].count == n
    assert by_scheme["sa"].mean == pytest.approx(
        0.001 * (n + 1) / 2)


def test_torn_telemetry_sidecar_reads_as_miss_and_heals(tmp_path):
    store = DirectoryStore(tmp_path)
    rec = MeasuredCost(signature="sigY", scheme="s1", backend="jax",
                       op="gather", bucket="4")
    rec.observe(2.0)
    store.merge_telemetry("sigY", [rec])
    path = store.telemetry_path("sigY")
    blob = path.read_text()
    path.write_text(blob[: len(blob) // 2])          # torn mid-write
    assert store.get_telemetry("sigY") == []
    # foreign format is also a miss
    path.write_text(json.dumps({"format": "something-else"}))
    assert store.get_telemetry("sigY") == []
    # the next merge heals the sidecar
    store.merge_telemetry("sigY", [rec.copy()])
    healed = store.get_telemetry("sigY")
    assert len(healed) == 1 and healed[0].count == 1
    assert json.loads(path.read_text())["format"]


def test_memory_store_telemetry_and_delete():
    store = MemoryStore()
    rec = MeasuredCost(signature="sigZ", scheme="s1", backend="jax",
                       op="gather", bucket="4")
    rec.observe(1.0)
    store.merge_telemetry("sigZ", [rec])
    store.merge_telemetry("sigZ", [rec.copy()])
    got = store.get_telemetry("sigZ")
    assert len(got) == 1 and got[0].count == 2
    got[0].observe(9.0)                     # copies: no cache poisoning
    assert store.get_telemetry("sigZ")[0].count == 2


# ---------------------------------------------------------------------------
# Priors + the "measured" scorer
# ---------------------------------------------------------------------------


def test_roofline_prior_orders_schemes_by_serialization():
    mem = MemorySpec("m", dims=(64,), word_bits=16, ports=1)
    free = compile_geometry(mem, FlatGeometry(N=8, B=8, alpha=(1,), P=(8,)),
                            backend="numpy")
    free.fan_outs = (1,)
    slow = compile_geometry(mem, FlatGeometry(N=1, B=1, alpha=(1,), P=(1,)),
                            backend="numpy")
    slow.fan_outs = (8,)                     # fully serialized
    assert roofline_prior_seconds(slow) > 2 * roofline_prior_seconds(free)
    assert roofline_prior_seconds(free) >= roofline_floor_seconds()


def test_measured_scorer_flips_ranking_on_contradicting_measurements():
    """Static prediction ranks A first; once the log holds measurements
    showing A slow and B fast, the measured blend flips the order."""
    plan = BankingPlanner().plan(_reader_program(),
                                 "table", opts=SolverOptions(n_budget=8))
    assert len(plan.solutions) >= 2
    a, b = plan.solutions[0], plan.solutions[1]
    static_rank = {scheme_hash(a): 1.0, scheme_hash(b): 2.0}
    log = TelemetryLog()
    scorer = MeasuredScorer(log=log,
                            static=lambda s: static_rank[scheme_hash(s)])
    assert scorer(a) < scorer(b)             # empty log: static wins
    # hardware disagrees: A measured 10x slower than B
    for _ in range(8):
        log.observe("sig", scheme_hash(a), "jax", "gather", (8,), 1e-3,
                    prior=roofline_prior_seconds(a))
        log.observe("sig", scheme_hash(b), "jax", "gather", (8,), 1e-4,
                    prior=roofline_prior_seconds(b))
    assert scorer(a) > scorer(b)             # ranking flipped
    # a never-measured scheme ranks by calibrated prior, not the static fn
    if len(plan.solutions) > 2:
        c = plan.solutions[2]
        expected = log.calibration() * roofline_prior_seconds(c)
        assert scorer(c) == pytest.approx(expected)


def test_measured_scorer_registered_in_registry():
    from repro.core.planner import resolve_scorer

    name, fn = resolve_scorer("measured")
    assert name == "measured" and isinstance(fn, MeasuredScorer)


# ---------------------------------------------------------------------------
# Demotion: exactly one speculative re-solve
# ---------------------------------------------------------------------------


def test_demotion_triggers_exactly_one_resolve(solve_counter):
    svc = PlanService(store=MemoryStore(), workers=1)
    hub = svc.enable_telemetry(TelemetryConfig(min_observations=4,
                                               demote_ratio=2.0,
                                               flush_every=0))
    plan = svc.submit(_reader_program(), "table",
                      opts=SolverOptions(n_budget=8)).result()
    assert len(solve_counter) == 1
    art = svc.planner.compile(plan, backend="numpy")
    assert art._telemetry is hub
    # a measured sibling scheme is 100x faster than the served one
    hub.log.observe(plan.signature, "rival-scheme", "numpy", "gather",
                    (8,), 1e-5)
    for _ in range(hub.config.min_observations):
        hub.observe(art, "gather", (8,), 1e-3)
    assert svc.stats.demotions == 1
    assert svc.drain(timeout=60)
    assert len(solve_counter) == 2           # exactly one re-solve
    key = (plan.signature, plan.scorer_name)
    ticket = hub.replacement(key)
    assert ticket is not None and ticket.result() is not None
    assert hub.replacement(key) is None      # pop-once
    # keep hammering: no resubmit storm
    for _ in range(20):
        hub.observe(art, "gather", (8,), 1e-3)
    svc.drain(timeout=60)
    assert svc.stats.demotions == 1 and len(solve_counter) == 2
    assert svc.stats.observations == 4 + 20


def test_no_demotion_without_enough_evidence_or_margin():
    svc = PlanService(store=MemoryStore(), workers=1)
    hub = svc.enable_telemetry(TelemetryConfig(min_observations=8,
                                               demote_ratio=2.0,
                                               flush_every=0))
    plan = svc.submit(_reader_program(), "table",
                      opts=SolverOptions(n_budget=8)).result()
    art = svc.planner.compile(plan, backend="numpy")
    hub.log.observe(plan.signature, "rival-scheme", "numpy", "gather",
                    (8,), 1e-5)
    for _ in range(7):                        # below min_observations
        hub.observe(art, "gather", (8,), 1e-3)
    assert svc.stats.demotions == 0
    # measured but NOT persistently worse than the rival's estimate
    svc2 = PlanService(store=MemoryStore(), workers=1)
    hub2 = svc2.enable_telemetry(TelemetryConfig(min_observations=4,
                                                 demote_ratio=2.0,
                                                 flush_every=0))
    plan2 = svc2.submit(_reader_program(), "table",
                        opts=SolverOptions(n_budget=8)).result()
    art2 = svc2.planner.compile(plan2, backend="numpy")
    hub2.log.observe(plan2.signature, "rival-scheme", "numpy", "gather",
                     (8,), 1e-3)
    for _ in range(8):
        hub2.observe(art2, "gather", (8,), 1.5e-3)   # within 2x of rival
    assert svc2.stats.demotions == 0


# ---------------------------------------------------------------------------
# Online refresh
# ---------------------------------------------------------------------------


def test_refresh_refits_ml_scorer_from_measured_pairs(ml_isolation):
    ml_path = ml_isolation
    svc = PlanService(store=MemoryStore(), workers=1)
    hub = svc.enable_telemetry(TelemetryConfig(flush_every=0))
    plan = svc.submit(_reader_program(), "table",
                      opts=SolverOptions(n_budget=8)).result()
    assert hub.refresh() is False             # nothing measured yet
    assert len(plan.solutions) >= 2
    for sol, secs in zip(plan.solutions[:2], (1e-3, 1e-4)):
        for _ in range(4):
            hub.log.observe(plan.signature, scheme_hash(sol), "jax",
                            "gather", (8,), secs,
                            prior=roofline_prior_seconds(sol))
    assert hub.refresh() is True
    assert svc.stats.refreshes == 1
    assert ml_path.exists()
    refit = MLScorer.from_json(json.loads(ml_path.read_text()))
    assert "measured_us" in refit.pipelines
    # the persisted refit IS what the "ml" registry entry now resolves
    resolved = planner_mod._ml_scorer_factory()
    assert "measured_us" in resolved.pipelines


def test_with_pipeline_returns_copy():
    rng = np.random.default_rng(0)
    X = rng.random((24, len(FEATURE_NAMES)))
    pipe = ResourcePipeline(gbt_params=dict(n_estimators=3)).fit(
        X, rng.random(24))
    base = MLScorer({"lut": pipe}, weights={"lut": 1.0})
    grafted = base.with_pipeline("measured_us", pipe, weight=2.0)
    assert "measured_us" in grafted.pipelines
    assert "measured_us" not in base.pipelines       # no mutation
    assert grafted.weights["measured_us"] == 2.0


# ---------------------------------------------------------------------------
# Satellite: set_ml_scorer_path invalidation + mtime reload
# ---------------------------------------------------------------------------


def _tiny_scorer_json(weight):
    rng = np.random.default_rng(int(weight))
    X = rng.random((24, len(FEATURE_NAMES)))
    pipe = ResourcePipeline(gbt_params=dict(n_estimators=3)).fit(
        X, rng.random(24))
    return MLScorer({"lut": pipe}, weights={"lut": float(weight)}).to_json()


def test_set_ml_scorer_path_invalidates_and_mtime_reloads(ml_isolation,
                                                          tmp_path):
    from repro.core.planner import resolve_scorer, set_ml_scorer_path

    path_a = tmp_path / "a" / "ml_scorer.json"
    path_b = tmp_path / "b" / "ml_scorer.json"
    for p, w in ((path_a, 1.0), (path_b, 2.0)):
        p.parent.mkdir()
        p.write_text(json.dumps(_tiny_scorer_json(w)))
    set_ml_scorer_path(path_a)
    first = resolve_scorer("ml")[1]
    assert first.weights["lut"] == 1.0
    assert resolve_scorer("ml")[1] is first          # cached, same path
    # switching the path must drop the cached scorer
    set_ml_scorer_path(path_b)
    second = resolve_scorer("ml")[1]
    assert second is not first and second.weights["lut"] == 2.0
    # refreshing the file on disk (mtime advances) must reload
    path_b.write_text(json.dumps(_tiny_scorer_json(3.0)))
    bumped = time.time() + 2
    os.utime(path_b, (bumped, bumped))
    third = resolve_scorer("ml")[1]
    assert third is not second and third.weights["lut"] == 3.0
    assert resolve_scorer("ml")[1] is third          # stable again


# ---------------------------------------------------------------------------
# Satellite: roofline import must not reconfigure jax
# ---------------------------------------------------------------------------


def test_roofline_import_does_not_mutate_xla_flags(monkeypatch):
    import importlib

    from repro.launch import roofline

    monkeypatch.setenv("XLA_FLAGS", "--some-user-flag")
    importlib.reload(roofline)
    assert os.environ["XLA_FLAGS"] == "--some-user-flag"
    # the CLI helper appends exactly once, and respects an existing pin
    roofline._force_dryrun_devices()
    assert "--xla_force_host_platform_device_count=512" \
        in os.environ["XLA_FLAGS"]
    flags = os.environ["XLA_FLAGS"]
    roofline._force_dryrun_devices()
    assert os.environ["XLA_FLAGS"] == flags          # idempotent
    # telemetry's prior reads the constant without the env mutation
    monkeypatch.setenv("XLA_FLAGS", "")
    from repro.core.telemetry import roofline_bandwidth
    assert roofline_bandwidth() == roofline.HBM_BW
    assert os.environ["XLA_FLAGS"] == ""


# ---------------------------------------------------------------------------
# Satellite: stats surface
# ---------------------------------------------------------------------------


def test_service_stats_as_dict_has_telemetry_counters():
    svc = PlanService(store=MemoryStore())
    d = svc.stats.as_dict()
    for key in ("observations", "refreshes", "demotions", "submits",
                "sync_hits", "solved"):
        assert key in d and d[key] == 0
    svc.stats.observations += 3
    assert svc.stats.as_dict()["observations"] == 3
    json.dumps(svc.stats.as_dict())                  # JSON-serializable


def test_serve_launcher_wires_telemetry_flag(tmp_path, monkeypatch):
    """launch/serve.py --telemetry enables the hub on the service and
    submits the KV plan under scorer="measured" (smoke: wiring only)."""
    import sys

    from repro.core import service as service_mod
    from repro.launch import serve as serve_mod

    seen = {}

    class SpyService(service_mod.PlanService):
        def enable_telemetry(self, config=None, log=None):
            seen["enabled"] = True
            return super().enable_telemetry(config, log)

    class Bail(Exception):
        pass

    def stop(*a, **kw):
        raise Bail()

    monkeypatch.setattr("repro.core.service.PlanService", SpyService)
    monkeypatch.setattr("repro.configs.get_arch", stop, raising=False)
    monkeypatch.setattr(sys, "argv",
                        ["serve", "--arch", "qwen2_7b", "--smoke",
                         "--plan-store", str(tmp_path),
                         "--telemetry", "--stats-interval", "30"])
    with pytest.raises(Bail):
        serve_mod.main()
    assert seen == {"enabled": True}


# ---------------------------------------------------------------------------
# Timing hooks: off by default, measurable when on, ~free when off
# ---------------------------------------------------------------------------


class _SinkSpy:
    def __init__(self):
        self.calls = []

    def observe(self, art, op, shape, seconds):
        self.calls.append((op, tuple(shape), seconds))


def test_timing_hooks_opt_in_and_zero_cost_when_off():
    mem = MemorySpec("m", dims=(64,), word_bits=16, ports=1)
    art = compile_geometry(mem, FlatGeometry(N=4, B=16, alpha=(1,), P=(16,)),
                           backend="numpy")
    table = np.arange(64 * 2, dtype=np.int32).reshape(64, 2)
    packed = np.asarray(art.pack(table))
    rows = np.arange(8)
    assert art._telemetry is None                    # off by default
    sink = _SinkSpy()
    art.enable_telemetry(sink)
    out = np.asarray(art.gather(packed, rows))
    np.testing.assert_array_equal(out, table[rows])  # identical results
    packed2 = art.scatter(packed, rows, out)
    assert [c[0] for c in sink.calls] == ["gather", "scatter"]
    assert all(c[2] >= 0 for c in sink.calls)
    art.disable_telemetry()
    art.gather(packed2, rows)
    assert len(sink.calls) == 2                      # nothing new logged
    # hooks-off per-call overhead ~ 0: wrapped (no sink) vs raw inner path
    reps = 300

    def median_time(fn):
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    direct = median_time(lambda: art._gather(packed, rows))
    wrapped = median_time(lambda: art.gather(packed, rows))
    assert wrapped <= direct * 1.5 + 50e-6


# ---------------------------------------------------------------------------
# End-to-end self-correction (tentpole acceptance)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_server_demotes_mis_ranked_plan_and_hot_swaps(tmp_path,
                                                      ml_isolation,
                                                      monkeypatch):
    """Serve from a deliberately mis-ranked stored plan; measured
    gather/scatter latencies recorded through Server.tick demote it, the
    service re-solves speculatively, and the server hot-swaps to a scheme
    whose measured cost is lower -- ServiceStats counting each step."""
    import dataclasses

    from repro.configs import get_arch
    from repro.core.planner import BankingPlan
    from repro.models import get_model
    from repro.runtime.server import Request, Server, page_ticket, \
        _page_program

    store_dir = tmp_path / "plans"
    # -- plant the mis-ranked plan: the WORST-prior candidate, stored as
    #    the "measured" scorer's answer ----------------------------------
    seed_planner = BankingPlanner(store=DirectoryStore(store_dir))
    opts = SolverOptions(b_candidates=(8, 1), allow_multidim=False)
    plan0 = seed_planner.plan(_page_program(32, 8, 4), "kv_pool", opts=opts)
    assert len(plan0.solutions) >= 2
    bad = max(plan0.solutions, key=roofline_prior_seconds)
    bad_hash = scheme_hash(bad)
    # mis-ranked by construction: its analytic prior alone exceeds the
    # demotion threshold over the conflict-free floor
    assert roofline_prior_seconds(bad) > 2.0 * roofline_floor_seconds()
    planted = BankingPlan(
        memory="kv_pool", signature=plan0.signature, best=bad,
        scorer_name="measured", status="solved", created_at=time.time(),
        opts=opts, family=plan0.family)
    DirectoryStore(store_dir).put(planted)

    svc = PlanService(store=DirectoryStore(store_dir), workers=2)
    hub = svc.enable_telemetry(TelemetryConfig(min_observations=4,
                                               demote_ratio=2.0,
                                               flush_every=8))
    # interpret-mode CPU timing can't see bank conflicts; inflate the bad
    # scheme's observed latency so measurements contradict its ranking
    # the way real hardware would (plumbing stays fully real)
    real_observe = TelemetryLog.observe

    def skewed(self, signature, scheme, backend, op, shape, seconds,
               prior=0.0):
        if scheme == bad_hash and op in DATA_OPS:
            seconds *= 50.0
        return real_observe(self, signature, scheme, backend, op, shape,
                            seconds, prior=prior)

    monkeypatch.setattr(TelemetryLog, "observe", skewed)

    ticket = page_ticket(None, max_len=32, page=8, readers=4,
                         service=svc, scorer="measured")
    assert ticket.done()                      # planted plan answered it
    assert scheme_hash(ticket.result().best) == bad_hash
    assert svc.stats.sync_hits == 1

    cfg = get_arch("qwen2_7b").reduced()
    cfg = dataclasses.replace(cfg, n_layers=1, d_model=32, d_ff=64,
                              vocab=64, n_heads=2, n_kv_heads=2,
                              head_dim=16)
    server = Server(get_model(cfg), max_batch=2, max_len=32,
                    kv_plan=ticket)
    assert scheme_hash(server._kv_art) == bad_hash   # serving the loser

    rng = np.random.default_rng(0)
    uid = 0
    settle = 6       # post-swap ticks: measure the replacement scheme too
    for _ in range(200):
        if not server.queue and len(server.active) < 2:
            prompt = rng.integers(2, 60, size=3).astype(np.int32)
            server.submit(Request(uid=uid, prompt=prompt, max_new=8))
            uid += 1
        server.tick()
        if svc.stats.demotions and server.swaps:
            settle -= 1
            if settle <= 0:
                break
    # the loop self-corrected: demoted, re-solved, hot-swapped
    assert svc.stats.demotions == 1
    assert svc.stats.observations > 0
    final = server._kv_art
    final_hash = scheme_hash(final)
    assert final_hash != bad_hash
    assert server.swaps >= 1
    # the winner is measurably faster than the demoted loser
    bad_count, bad_p50 = hub.log.scheme_measured(bad_hash)
    new_count, new_p50 = hub.log.scheme_measured(final_hash)
    assert bad_count >= 4 and new_count > 0
    assert new_p50 < bad_p50
    # the loser lost its cache slot everywhere
    store = DirectoryStore(store_dir)
    replacement_plan = store.get(plan0.signature, "measured")
    assert replacement_plan is not None
    assert scheme_hash(replacement_plan.best) != bad_hash
    # observations persisted through the sidecar (flush cadence + final)
    hub.flush()
    persisted = store.get_telemetry(plan0.signature)
    assert any(r.scheme == bad_hash for r in persisted)
    # and the accumulated (features, measured) pairs refresh the ml model
    assert hub.refresh() is True
    assert svc.stats.refreshes >= 1
    assert ml_isolation.exists()
