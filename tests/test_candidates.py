"""Shardable candidate-space pipeline: shard equivalence against the
monolithic search, reducer truncation/dedup/monotonicity, shard
self-containment (pickling), and the parallel drivers."""

import pickle
import threading

import pytest

from repro.core import (
    CandidateSpace,
    SolutionReducer,
    SolverOptions,
    build_groups,
    evaluate,
    evaluate_parallel,
    unroll
)
from repro.core import problems
from repro.core.candidates import EvaluatedCandidate
from repro.core.planner import rank_solutions
from repro.core.solver import solve, solve_monolithic


def _problem(app):
    prog = problems.build(app)
    memname = list(prog.memories)[0]
    up = unroll(prog)
    return (prog.memories[memname], build_groups(up, memname),
            up.iterators)


def _key(s):
    return (s.kind, s.geometry, s.duplicates)


def _dedup(keys):
    seen = set()
    return [k for k in keys if not (k in seen or seen.add(k))]


# ---------------------------------------------------------------------------
# Shard equivalence (the ISSUE acceptance property)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("app", ["sobel", "motion-lh", "sgd", "md_grid"])
def test_shard_equivalence_matrix(app):
    """Merging evaluate() over space.shards(k) for k in {1, 2, 4} yields
    the identical solution list -- and the identical ranked winner -- as
    the pre-redesign monolithic solve."""
    mem, groups, iters = _problem(app)
    mono = solve_monolithic(mem, groups, iters)
    mono_keys = _dedup([_key(s) for s in mono])
    mono_winner = _key(rank_solutions(list(mono))[0])
    for k in (1, 2, 4):
        space = CandidateSpace(mem, groups, iters, SolverOptions())
        red = SolutionReducer(space)
        for shard in space.shards(k):
            for ev in evaluate(shard, gate=red):
                red.add(ev)
        sols = red.finalize()
        assert [_key(s) for s in sols] == mono_keys, (app, k)
        assert _key(rank_solutions(list(sols))[0]) == mono_winner, (app, k)


def test_solve_is_the_single_shard_pipeline():
    mem, groups, iters = _problem("sobel")
    pipe = [_key(s) for s in solve(mem, groups, iters)]
    mono = _dedup([_key(s) for s in solve_monolithic(mem, groups, iters)])
    assert pipe == mono


def test_shard_equivalence_under_merged_thread_streams():
    """Interleaved arrival order (concurrent shard threads sharing one
    reducer + gate) must not change the final list."""
    mem, groups, iters = _problem("sobel")
    want = [_key(s) for s in solve(mem, groups, iters)]
    space = CandidateSpace(mem, groups, iters, SolverOptions())
    red = SolutionReducer(space)

    def run(shard):
        for ev in evaluate(shard, gate=red):
            red.add(ev)

    threads = [threading.Thread(target=run, args=(sh,))
               for sh in space.shards(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert [_key(s) for s in red.finalize()] == want


# ---------------------------------------------------------------------------
# Enumeration / partitioning
# ---------------------------------------------------------------------------


def test_shards_partition_the_space_exactly():
    mem, groups, iters = _problem("sobel")
    space = CandidateSpace(mem, groups, iters, SolverOptions())
    for k in (1, 3, 8):
        for interleave in (True, False):
            shards = space.shards(k, interleave=interleave)
            idxs = sorted(c.index for sh in shards for c in sh.candidates)
            assert idxs == list(range(len(space)))


def test_sections_cover_candidates_and_encode_budgets():
    mem, groups, iters = _problem("sgd")      # has duplication sections
    opts = SolverOptions()
    space = CandidateSpace(mem, groups, iters, opts)
    assert [s.name for s in space.sections][:1] == ["flat"]
    assert any(s.name.startswith("dup x") for s in space.sections)
    covered = []
    for s in space.sections:
        assert s.cap > 0
        covered.extend(range(s.start, s.stop))
        if s.name.startswith("dup"):
            assert s.keep == 2 and s.D > 1
        else:
            assert s.cap == opts.max_solutions
    assert covered == list(range(len(space)))
    # candidates point back at their section
    for c in space.candidates:
        sec = space.sections[c.section]
        assert sec.start <= c.index < sec.stop


def test_local_stop_prunes_beyond_the_cut():
    """A single shard stops each section once its own emissions reach
    the cap -- far fewer evaluations than the whole space."""
    mem, groups, iters = _problem("sobel")
    space = CandidateSpace(mem, groups, iters, SolverOptions())
    shard = space.shards(1)[0]
    n_evaluated = sum(1 for _ in evaluate(shard))
    assert n_evaluated < len(space)


# ---------------------------------------------------------------------------
# Shard self-containment
# ---------------------------------------------------------------------------


def test_pickled_shard_evaluates_identically():
    """Shards are self-contained: a pickled shard (fresh conflict cache
    on the far side) yields byte-identical evaluation results."""
    mem, groups, iters = _problem("motion-lh")
    space = CandidateSpace(mem, groups, iters, SolverOptions())
    shard = space.shards(4)[1]
    local = [( e.index, [_key(s) for s in e.solutions], e.valid_mask)
             for e in evaluate(shard)]
    far = pickle.loads(pickle.dumps(shard))
    assert far.space is not shard.space
    remote = [(e.index, [_key(s) for s in e.solutions], e.valid_mask)
              for e in evaluate(far)]
    assert remote == local


def test_evaluate_parallel_matches_single_shard():
    """The process-pool driver (cut-filtered dispatch) returns the same
    ranked winner and solution list as the in-thread pipeline."""
    mem, groups, iters = _problem("sobel")
    want = [_key(s) for s in solve(mem, groups, iters)]
    space = CandidateSpace(mem, groups, iters, SolverOptions())
    red = evaluate_parallel(space, 2)
    assert [_key(s) for s in red.finalize()] == want


# ---------------------------------------------------------------------------
# Reducer semantics
# ---------------------------------------------------------------------------


def test_reducer_best_never_regresses_and_matches_final():
    mem, groups, iters = _problem("sobel")
    space = CandidateSpace(mem, groups, iters, SolverOptions())
    red = SolutionReducer(space)
    scores = []
    for ev in evaluate(space.shards(1)[0], gate=red):
        red.add(ev)
        best = red.best()
        if best is not None:
            scores.append(best.score)
    assert scores, "search admitted no solutions"
    assert all(a >= b for a, b in zip(scores, scores[1:]))
    sols = red.finalize()
    assert red.best().score == min(s.score for s in sols) == scores[-1]
    assert red.version == red.promotions > 0
    assert red.first_best_seconds is not None


def test_reducer_out_of_order_arrival_equals_in_order():
    mem, groups, iters = _problem("motion-lh")
    space = CandidateSpace(mem, groups, iters, SolverOptions())
    evs = list(evaluate(space.shards(1)[0]))
    fwd = SolutionReducer(space)
    for e in evs:
        fwd.add(e)
    rev = SolutionReducer(space)
    for e in reversed(evs):
        rev.add(e)
    assert ([_key(s) for s in fwd.finalize()]
            == [_key(s) for s in rev.finalize()])


def test_reducer_dedupes_identical_schemes():
    """Identical geometries reaching the reducer twice are dropped
    before scoring; the duplicate still counts toward the section cap
    (monolithic accounting)."""
    mem, groups, iters = _problem("sobel")
    space = CandidateSpace(mem, groups, iters, SolverOptions())
    shard = space.shards(1)[0]
    it = evaluate(shard)
    first_valid = None
    for ev in it:
        if ev.solutions:
            first_valid = ev
            break
    assert first_valid is not None
    red = SolutionReducer(space)
    doubled = EvaluatedCandidate(
        index=first_valid.index,
        solutions=list(first_valid.solutions) * 2,
        valid_mask=first_valid.valid_mask * 2)
    red.add(doubled)
    admitted = red.finalize()
    assert red.dedup_hits == len(first_valid.solutions)
    assert len(admitted) == len(first_valid.solutions)
