"""Exactness of the residue-reachability emptiness oracle."""

import itertools

import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.polytope import (Affine, Iterator, delta_can_hit_window,
                                 reachable_residues)


@given(
    st.integers(min_value=1, max_value=24),             # modulus M
    st.lists(st.tuples(st.integers(-9, 9),              # coeff
                       st.integers(1, 7)),              # count
             min_size=1, max_size=3),
    st.integers(-20, 20),                                # const
)
@settings(max_examples=60, deadline=None)
def test_reachable_residues_exact(M, terms, const):
    names = [f"i{k}" for k in range(len(terms))]
    expr = Affine(terms=tuple((n, c) for n, (c, _) in zip(names, terms)),
                  const=const)
    iters = {n: Iterator(n, start=0, step=1, count=cnt)
             for n, (_, cnt) in zip(names, terms)}
    got = set(int(r) for r in reachable_residues(expr, iters, M))
    want = set()
    for combo in itertools.product(*[range(cnt) for _, cnt in terms]):
        v = const + sum(c * t for (c, _), t in zip(terms, combo))
        want.add(v % M)
    assert got == want


@given(
    st.integers(min_value=1, max_value=8),    # N
    st.integers(min_value=1, max_value=4),    # B
    st.integers(-30, 30),                     # delta const
    st.integers(-6, 6), st.integers(1, 8),    # coeff, count
)
@settings(max_examples=60, deadline=None)
def test_delta_window_matches_bruteforce(N, B, const, coeff, count):
    """Conflict test == exists i: |delta(i)| mod N*B in (-B, B)."""
    expr = Affine(terms=(("i", coeff),) if coeff else (), const=const)
    iters = {"i": Iterator("i", 0, 1, count)}
    got = delta_can_hit_window(expr, iters, N, B)
    M = N * B
    want = False
    for t in range(count):
        d = (const + coeff * t) % M
        if d < B or d > M - B:
            want = True
    assert got == want


def test_unbounded_iterator_is_conservative():
    expr = Affine(terms=(("q", 3),), const=1)
    # no bounds on q -> subgroup gcd(3, 9) = 3: residues {1, 4, 7} mod 9
    got = set(int(r) for r in reachable_residues(expr, {}, 9))
    assert got == {1, 4, 7}


def test_symbol_cancellation():
    a = Affine.of(const=2, i=1).with_sym("f@0")
    b = Affine.of(const=0, i=1).with_sym("f@0")
    d = a - b
    assert not d.syms and d.const == 2  # same symbol cancels exactly

    c = Affine.of(const=0, i=1).with_sym("f@1")
    d2 = a - c
    assert d2.syms  # different lanes' symbols stay -> conservative
