"""Exactness + resource properties of the Sec-3.4 datapath transforms."""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import transforms as T


IN_BITS = 20
XS = st.integers(min_value=0, max_value=(1 << IN_BITS) - 1)


@given(XS, st.integers(min_value=1, max_value=65))
@settings(max_examples=60, deadline=None)
def test_mod_const_exact(x, c):
    node = T.mod_const(T.var("x"), c, in_bits=IN_BITS)
    assert T.evaluate(node, {"x": x}) == x % c


@given(XS, st.integers(min_value=1, max_value=65))
@settings(max_examples=60, deadline=None)
def test_div_const_exact(x, c):
    node = T.div_const(T.var("x"), c, in_bits=IN_BITS)
    assert T.evaluate(node, {"x": x}) == x // c


@given(XS, st.integers(min_value=-65, max_value=65))
@settings(max_examples=60, deadline=None)
def test_mul_const_exact(x, c):
    node = T.mul_const(T.var("x"), c, R=4)
    assert T.evaluate(node, {"x": x}) == x * c


@pytest.mark.parametrize("c", [2, 3, 4, 7, 8, 15, 16, 31, 32, 63])
def test_friendly_constants_are_dsp_free(c):
    """Crandall/pow2/NAF rewrites must leave no raw mul/div/mod."""
    for build, _ in [(T.mod_const, "%"), (T.div_const, "/")]:
        node = build(T.var("x"), c, in_bits=IN_BITS)
        raw = T.count_raw_ops(node)
        assert raw["div"] == 0 and raw["mod"] == 0, (c, raw)


@pytest.mark.parametrize("c", [5, 9, 21])  # divide Mersenne numbers (Eq. 6)
def test_mersenne_multiple_mod(c):
    nk = T.mersenne_multiple(c)
    assert nk is not None
    node = T.mod_const(T.var("x"), c, in_bits=IN_BITS)
    assert T.count_raw_ops(node)["mod"] == 0
    for x in range(0, 1 << IN_BITS, 9973):
        assert T.evaluate(node, {"x": x}) == x % c


def test_transform_cost_below_raw():
    """Transforms trade DSPs (scarce) for LUT adders; weighted cost drops."""
    w = 16
    for c in (3, 7, 15, 31):
        full = T.cost(T.mod_const(T.var("x"), c, in_bits=w), w)
        raw = T.cost(T.raw_mod(T.var("x"), c), w)
        assert full.dsp == 0 and raw.dsp > 0
        assert full.lut + 120 * full.dsp < raw.lut + 120 * raw.dsp


def test_lower_jnp_matches_evaluate():
    import jax.numpy as jnp
    node = T.mod_const(T.div_const(T.var("x"), 3, in_bits=IN_BITS), 7,
                       in_bits=IN_BITS)
    fn = T.lower_jnp(node)
    xs = np.arange(0, 5000, 13, dtype=np.int32)
    got = np.asarray(fn(x=jnp.asarray(xs)))
    want = (xs // 3) % 7
    np.testing.assert_array_equal(got, want)


def test_naf_digits():
    for c in range(1, 200):
        digits = T.naf_digits(c)
        assert sum(s * (1 << e) for s, e in digits) == c
        # non-adjacency property
        es = sorted(e for _, e in digits)
        assert all(b - a >= 2 for a, b in zip(es, es[1:]))
