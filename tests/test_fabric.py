"""SolveFabric: remote shard workers over the localhost wire protocol.

Covers the wire codecs, the shard-equivalence matrix evaluated by real
worker subprocesses, the PlanService ``executor="fabric"`` backend for
1/2/4 workers (the ISSUE acceptance matrix), worker-kill requeue
convergence, measurable cut-broadcast pruning, and the no-worker
fallbacks.
"""

import signal
import threading
import time

import pytest

from repro.core import (CandidateSpace, PlanService, SolutionReducer,
                        SolveFabric, SolverOptions, build_groups,
                        rank_solutions, space_from_wire, space_to_wire,
                        spawn_local_workers, unroll)
from repro.core import problems
from repro.core.candidates import (evaluate, events_from_wire,
                                   events_to_wire, shard_from_indices)
from repro.core.planner import BankingPlanner
from repro.core.solver import solve_monolithic

APPS = ["sobel", "motion-lh", "sgd", "md_grid"]


def _problem(app):
    prog = problems.build(app)
    memname = list(prog.memories)[0]
    up = unroll(prog)
    return (prog.memories[memname], build_groups(up, memname),
            up.iterators)


def _key(s):
    return (s.kind, s.geometry, s.duplicates)


def _mono_winner(app):
    mem, groups, iters = _problem(app)
    return _key(rank_solutions(list(solve_monolithic(mem, groups,
                                                     iters)))[0])


class _Cluster:
    """A fabric plus n local worker subprocesses, cleaned up reliably."""

    def __init__(self, n, **kw):
        self.fabric = SolveFabric(**kw)
        self.procs = spawn_local_workers(self.fabric.address, n) if n else []
        if n:
            assert self.fabric.wait_for_workers(n, timeout=60), \
                f"{n} workers did not attach"

    def kill(self, i):
        self.procs[i].send_signal(signal.SIGKILL)

    def close(self):
        for p in self.procs:
            p.terminate()
        for p in self.procs:
            p.wait(timeout=10)
        self.fabric.shutdown()


@pytest.fixture
def cluster2():
    c = _Cluster(2, chunk=16)
    yield c
    c.close()


# ---------------------------------------------------------------------------
# Wire codecs
# ---------------------------------------------------------------------------


def test_wire_codecs_round_trip():
    """Space and event streams survive the wire byte-for-byte: a decoded
    space evaluates a leased work unit to identical results."""
    mem, groups, iters = _problem("motion-lh")
    space = CandidateSpace(mem, groups, iters, SolverOptions())
    far = space_from_wire(space_to_wire(space))
    assert far is not space and len(far) == len(space)
    idxs = list(range(0, min(64, len(space))))
    local = [(e.index, [_key(s) for s in e.solutions], e.valid_mask)
             for e in evaluate(shard_from_indices(space, idxs))]
    events = list(evaluate(shard_from_indices(far, idxs)))
    wired = events_from_wire(events_to_wire(events))
    remote = [(e.index, [_key(s) for s in e.solutions], e.valid_mask)
              for e in wired]
    assert remote == local


# ---------------------------------------------------------------------------
# CI smoke: one ticket end-to-end through 2 worker subprocesses
# ---------------------------------------------------------------------------


def test_fabric_smoke_one_ticket_end_to_end(cluster2):
    """A localhost fabric with 2 worker subprocesses solves one
    PlanService ticket end-to-end: remote leases, streamed results,
    cut broadcasts, and the exact monolithic winner."""
    svc = PlanService(workers=2, executor="fabric", fabric=cluster2.fabric)
    prog = problems.build("sobel")
    ticket = svc.submit(prog, list(prog.memories)[0])
    plan = ticket.result(timeout=120)
    assert plan.status == "solved"
    assert _key(plan.best) == _mono_winner("sobel")
    assert svc.stats.fabric_solves == 1 and svc.stats.fabric_fallbacks == 0
    assert svc.stats.fabric_leases > 0
    assert cluster2.fabric.stats.evaluated > 0   # work really went remote
    assert ticket.best_so_far() is plan.best     # progressive API intact


# ---------------------------------------------------------------------------
# Shard equivalence over the wire (the ISSUE acceptance matrix)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("app", APPS)
def test_two_worker_fabric_shard_equivalence_matrix(cluster2, app):
    """k in {1, 2, 4} work units evaluated by two remote workers merge
    to the identical solution list -- and the identical ranked winner --
    as the monolithic search (shard equivalence over the wire)."""
    mem, groups, iters = _problem(app)
    mono = solve_monolithic(mem, groups, iters)
    seen = set()
    mono_keys = [k for s in mono if (k := _key(s)) not in seen
                 and not seen.add(k)]
    winner = _key(rank_solutions(list(mono))[0])
    for k in (1, 2, 4):
        space = CandidateSpace(mem, groups, iters, SolverOptions())
        red = SolutionReducer(space)
        cluster2.fabric.solve(space, reducer=red,
                              chunk=max(1, -(-len(space) // k)))
        sols = red.finalize()
        assert [_key(s) for s in sols] == mono_keys, (app, k)
        assert _key(rank_solutions(list(sols))[0]) == winner, (app, k)


@pytest.mark.parametrize("workers", [1, 2, 4])
def test_plan_service_fabric_executor_matches_monolithic(workers):
    """ISSUE acceptance: PlanService with executor="fabric" returns a
    plan identical to solve_monolithic() for every benchmark problem,
    regardless of worker count."""
    c = _Cluster(workers, chunk=16)
    try:
        svc = PlanService(workers=2, executor="fabric", fabric=c.fabric)
        for app in APPS:
            prog = problems.build(app)
            memname = list(prog.memories)[0]
            plan = svc.submit(prog, memname).result(timeout=120)
            assert _key(plan.best) == _mono_winner(app), (app, workers)
        assert svc.stats.fabric_solves == len(APPS)
    finally:
        c.close()


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------


def test_worker_kill_mid_solve_requeues_and_converges():
    """SIGKILLing a worker mid-solve requeues its leases (killed worker
    excluded) onto the surviving worker; the merged result still equals
    the monolithic winner."""
    c = _Cluster(2, chunk=8, lease_window=2)
    try:
        mem, groups, iters = _problem("sobel")
        space = CandidateSpace(mem, groups, iters, SolverOptions())
        red = SolutionReducer(space)
        done = {}

        def run():
            done["report"] = c.fabric.solve(space, reducer=red)

        t = threading.Thread(target=run)
        t.start()
        deadline = time.monotonic() + 60
        while (c.fabric.stats.results_frames < 1
               and time.monotonic() < deadline):
            time.sleep(0.001)
        assert c.fabric.stats.results_frames >= 1, "no results before kill"
        c.kill(0)
        t.join(timeout=120)
        assert not t.is_alive(), "solve hung after the worker died"
        assert c.fabric.stats.workers_lost >= 1
        winner = _key(rank_solutions(list(red.finalize()))[0])
        assert winner == _mono_winner("sobel")
    finally:
        c.close()


def test_no_workers_solves_locally():
    """A fabric with zero attached workers still converges: the driving
    thread evaluates orphan units itself."""
    fabric = SolveFabric(chunk=32)
    try:
        mem, groups, iters = _problem("sobel")
        space = CandidateSpace(mem, groups, iters, SolverOptions())
        red = SolutionReducer(space)
        report = fabric.solve(space, reducer=red)
        assert report.local_evaluated > 0 and report.leases == 0
        winner = _key(rank_solutions(list(red.finalize()))[0])
        assert winner == _mono_winner("sobel")
    finally:
        fabric.shutdown()


def test_per_ticket_executor_override(cluster2):
    """A pool-default service routes a single submit to the fabric via
    submit(executor="fabric") -- and rejects unknown executors."""
    svc = PlanService(workers=2, fabric=cluster2.fabric)   # default: pool
    prog = problems.build("sobel")
    memname = list(prog.memories)[0]
    plan = svc.submit(prog, memname, executor="fabric").result(timeout=120)
    assert _key(plan.best) == _mono_winner("sobel")
    assert svc.stats.fabric_solves == 1
    assert svc.stats.shards_spawned == 0       # the pool never fanned out
    with pytest.raises(ValueError, match="unknown executor"):
        svc.submit(prog, memname, executor="nope")
    with pytest.raises(ValueError, match="unknown executor"):
        PlanService(executor="nope")


def test_service_fabric_executor_falls_back_to_pool():
    """executor="fabric" with no fabric attached must not wedge: the
    in-process pool runs the solve and the fallback is counted."""
    svc = PlanService(workers=2, executor="fabric")
    prog = problems.build("sobel")
    plan = svc.submit(prog, list(prog.memories)[0]).result(timeout=60)
    assert _key(plan.best) == _mono_winner("sobel")
    assert svc.stats.fabric_fallbacks == 1 and svc.stats.fabric_solves == 0
    assert svc.stats.shards_spawned >= 1       # the pool really ran


# ---------------------------------------------------------------------------
# Cut broadcast
# ---------------------------------------------------------------------------


def test_cut_broadcast_reduces_evaluated_candidates():
    """With the cut protocol on, remote workers skip provably-dead
    candidates (dispatch filtering + mid-lease broadcast); without it
    they evaluate far more of the space for the same final answer."""
    mem, groups, iters = _problem("sobel")
    evaluated = {}
    for cuts in (True, False):
        c = _Cluster(1, chunk=16, lease_window=1, broadcast_cuts=cuts)
        try:
            space = CandidateSpace(mem, groups, iters, SolverOptions())
            red = SolutionReducer(space)
            report = c.fabric.solve(space, reducer=red)
            evaluated[cuts] = report.evaluated
            winner = _key(rank_solutions(list(red.finalize()))[0])
            assert winner == _mono_winner("sobel"), f"cuts={cuts}"
        finally:
            c.close()
    assert evaluated[True] < evaluated[False], evaluated
    assert c.fabric.stats.cut_broadcasts == 0   # really ran without cuts


# ---------------------------------------------------------------------------
# Adaptive per-ticket shard budgets (pool path)
# ---------------------------------------------------------------------------


def test_adaptive_budget_small_space_skips_fan_out():
    """With the default (adaptive) shard budget, a small candidate space
    solves as ONE shard -- no fan-out overhead -- while a larger space
    still fans out across the pool."""
    svc = PlanService(workers=4)          # shard_budget=None -> adaptive
    assert svc.shard_budget is None
    prog = problems.build("sobel")
    memname = list(prog.memories)[0]
    tiny = SolverOptions(max_solutions=4, n_budget=2, alpha_budget=2,
                         allow_multidim=False, allow_duplication=False)
    svc.submit(prog, memname, opts=tiny).result(timeout=60)
    assert svc.stats.adaptive_budgets == 1
    assert svc.stats.shards_spawned == 1   # small space: single shard
    svc.submit(prog, memname).result(timeout=60)   # full-size space
    assert svc.stats.adaptive_budgets == 2
    assert svc.stats.shards_spawned > 1    # big space: real fan-out


def test_suggested_shards_scales_with_enumeration():
    mem, groups, iters = _problem("sobel")
    space = CandidateSpace(mem, groups, iters, SolverOptions())
    assert space.suggested_shards(8) > 1
    assert space.suggested_shards(1) == 1
    tiny = CandidateSpace(mem, groups, iters,
                          SolverOptions(max_solutions=4, n_budget=2,
                                        alpha_budget=2,
                                        allow_multidim=False,
                                        allow_duplication=False))
    assert tiny.suggested_shards(8) == 1
    # explicit budgets still win over the adaptive default
    planner = BankingPlanner()
    svc = PlanService(planner=planner, workers=2, shard_budget=3)
    assert svc.shard_budget == 3


# ---------------------------------------------------------------------------
# Worker heartbeats
# ---------------------------------------------------------------------------


def test_worker_heartbeats_are_counted():
    """Real workers emit the lightweight hb frame on their own cadence
    (even while idle) and the fabric counts every one."""
    fabric = SolveFabric(chunk=16)
    procs = []
    try:
        procs = spawn_local_workers(fabric.address, 1, hb_interval=0.1)
        assert fabric.wait_for_workers(1, timeout=60)
        deadline = time.monotonic() + 30
        while fabric.stats.heartbeats < 3 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert fabric.stats.heartbeats >= 3
    finally:
        for p in procs:
            p.terminate()
        for p in procs:
            p.wait(timeout=10)
        fabric.shutdown()


def test_heartbeat_timeout_drops_silent_worker_before_lease_timeout():
    """A worker that has spoken hb and then goes silent while holding a
    lease is dropped after ``hb_timeout`` -- NOT after the much longer
    lease timeout -- and its lease converges locally."""
    from repro.core.fabric import read_frame, write_frame
    fabric = SolveFabric(chunk=64, hb_timeout=1.0, lease_timeout=300.0)
    sock = None
    try:
        import socket as socket_mod
        host, _, port = fabric.address.rpartition(":")
        sock = socket_mod.create_connection((host, int(port)))
        send_lock = threading.Lock()
        write_frame(sock, {"t": "join", "pid": 0, "host": "fake"},
                    send_lock)
        got_lease = threading.Event()

        def fake_worker():
            # hb once after the first lease, then total silence: the
            # fabric must not wait lease_timeout=300s for this one
            try:
                while True:
                    msg = read_frame(sock)
                    if msg.get("t") == "lease" and not got_lease.is_set():
                        write_frame(sock, {"t": "hb"}, send_lock)
                        got_lease.set()
            except Exception:
                pass

        threading.Thread(target=fake_worker, daemon=True).start()
        assert fabric.wait_for_workers(1, timeout=30)
        mem, groups, iters = _problem("sobel")
        space = CandidateSpace(mem, groups, iters, SolverOptions())
        red = SolutionReducer(space)
        t0 = time.monotonic()
        report = fabric.solve(space, reducer=red)
        wall = time.monotonic() - t0
        assert got_lease.is_set(), "fake worker never got a lease"
        assert wall < 60, f"hb drop did not beat lease_timeout ({wall=})"
        assert fabric.stats.heartbeats >= 1
        assert fabric.stats.workers_lost >= 1
        assert report.local_evaluated > 0     # orphan units ran locally
        winner = _key(rank_solutions(list(red.finalize()))[0])
        assert winner == _mono_winner("sobel")
    finally:
        fabric.shutdown()
        if sock is not None:
            sock.close()


def test_lease_cap_bounds_concurrent_leases(cluster2):
    """solve(lease_cap=1) never holds more than one lease in flight --
    the per-tenant fabric QoS knob -- and still converges exactly."""
    mem, groups, iters = _problem("sobel")
    space = CandidateSpace(mem, groups, iters, SolverOptions())
    red = SolutionReducer(space)
    report = cluster2.fabric.solve(space, reducer=red, chunk=8,
                                   lease_cap=1)
    assert report.peak_leases == 1
    assert report.leases > 1          # sequential leases, not one giant
    winner = _key(rank_solutions(list(red.finalize()))[0])
    assert winner == _mono_winner("sobel")
