"""Joint whole-model planning: Pareto frontiers, exact co-selection
under a shared ResourceBudget, the JointTicket graph (progressive
re-selection, completion-order invariance, certifier-backed eviction),
plan_all rebased on it, joint/ store persistence, and the server's
coherent multi-pool swap."""

import threading
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import (AccessDecl, BankingPlanner, Counter, Ctrl,
                        MemorySpec, PlanService, Program, ResourceBudget,
                        ResourceUse, Sched, co_select, pareto_frontier,
                        trivial_solution)
from repro.core.jointplan import (FrontierPoint, JointPlan, TRIVIAL_PENALTY,
                                  independent_use, is_trivial)
from repro.core.polytope import Affine
from repro.core.store import DirectoryStore


def _joint_program(dims_a=(256,), dims_b=(128,), par_a=8, par_b=4):
    """Two banked memories behind one FORKJOIN root -- the minimal
    whole-model shape (think: KV pool + MoE dispatch table)."""
    a = MemorySpec("a", dims=dims_a, word_bits=16, ports=1)
    b = MemorySpec("b", dims=dims_b, word_bits=32, ports=1)
    return Program(
        root=Ctrl("model", Sched.FORKJOIN, children=[
            Ctrl("ra", Sched.INNER,
                 counters=[Counter("i", 0, 1, 32, par=par_a)],
                 accesses=[AccessDecl("a", (Affine.of(i=1),))]),
            Ctrl("rb", Sched.INNER,
                 counters=[Counter("j", 0, 1, 32, par=par_b)],
                 accesses=[AccessDecl("b", (Affine.of(j=1),))]),
        ]),
        memories={"a": a, "b": b},
    )


def _pt(score, trivial=False, **axes):
    """Synthetic frontier point: co_select only reads score/use/trivial
    plus key(), so a stub solution with a flat geometry suffices."""
    sol = SimpleNamespace(kind="flat",
                          geometry=SimpleNamespace(
                              N=axes.get("banks", 1), B=1,
                              alpha=(1,), Ns=None, Bs=None, alphas=None),
                          duplicates=1, score=score)
    return FrontierPoint(solution=sol, use=ResourceUse(**axes),
                         score=score, trivial=trivial)


# ---------------------------------------------------------------------------
# Budget currency
# ---------------------------------------------------------------------------


def test_resource_use_arithmetic_and_budget():
    u = ResourceUse(banks=4, volume=64, lut=10.0, bram=4)
    v = ResourceUse(banks=2, volume=32, lut=5.0, bram=2, dsp=1)
    s = u + v
    assert (s.banks, s.volume, s.lut, s.bram, s.dsp) == (6, 96, 15.0, 6, 1)
    assert not ResourceBudget().bounded              # slack admits anything
    assert ResourceBudget().admits(s)
    tight = ResourceBudget(bram=5, banks=6)
    assert tight.bounded and not tight.admits(s)     # bram 6 > 5
    assert ResourceBudget(bram=6, banks=6).admits(s)
    head = tight.headroom(s)
    assert head == {"banks": 0, "bram": -1}


# ---------------------------------------------------------------------------
# Pareto frontiers
# ---------------------------------------------------------------------------


def _solved_frontier(cap=8):
    planner = BankingPlanner()
    prog = _joint_program()
    prep = planner.prepare(prog, "a", use_cache=False)
    plan = planner.plan(prog, "a", use_cache=False)
    triv = trivial_solution(prep.mem, prep.groups, prep.iterators, prep.opts)
    return pareto_frontier(plan.solutions, trivial=triv, cap=cap), plan


def test_pareto_frontier_trivial_always_last_and_penalized():
    front, plan = _solved_frontier()
    assert front[-1].trivial and is_trivial(front[-1].solution)
    assert front[-1].score > TRIVIAL_PENALTY
    reals = front[:-1]
    assert reals, "solver produced no real frontier points"
    # best-cost-first, and the argmin scheme leads the frontier
    assert reals[0].score == min(p.score for p in reals)
    assert reals[0].solution.num_banks == plan.best.num_banks
    # no real point dominates another (Pareto property)
    for p in reals:
        for q in reals:
            if p is q:
                continue
            assert not (q.score <= p.score
                        and all(x <= y for x, y in zip(q.use.as_tuple(),
                                                       p.use.as_tuple()))
                        and (q.score < p.score
                             or q.use.as_tuple() != p.use.as_tuple()))


def test_pareto_frontier_cap_keeps_per_axis_minima():
    full, _ = _solved_frontier(cap=64)
    capped, _ = _solved_frontier(cap=3)
    assert len(capped) <= 3 + len(ResourceUse().as_dict())  # cap + axis mins
    # every axis's cheapest draw survives truncation
    reals_full = [p for p in full if not p.trivial]
    reals_cap = [p for p in capped if not p.trivial]
    for axis in ("banks", "bram", "lut"):
        lo = min(p.use.axis(axis) for p in reals_full)
        assert min(p.use.axis(axis) for p in reals_cap) == lo


def test_frontier_of_empty_stream_is_trivial_only():
    planner = BankingPlanner()
    prog = _joint_program()
    prep = planner.prepare(prog, "a", use_cache=False)
    triv = trivial_solution(prep.mem, prep.groups, prep.iterators, prep.opts)
    front = pareto_frontier([], trivial=triv, cap=4)
    assert len(front) == 1 and front[0].trivial


# ---------------------------------------------------------------------------
# Exact co-selection
# ---------------------------------------------------------------------------


def _fronts():
    big = TRIVIAL_PENALTY * 2
    return {
        "a": [_pt(10.0, banks=8, bram=8, volume=64),
              _pt(30.0, banks=2, bram=2, volume=64),
              _pt(big, trivial=True, banks=1, bram=1, volume=64)],
        "b": [_pt(5.0, banks=4, bram=4, volume=32),
              _pt(50.0, banks=2, bram=2, volume=32),
              _pt(big, trivial=True, banks=1, bram=1, volume=32)],
    }


def test_co_select_slack_budget_is_independent_argmin():
    for budget in (None, ResourceBudget()):
        sel = co_select(_fronts(), budget)
        assert sel.feasible
        assert sel.picks["a"].score == 10.0 and sel.picks["b"].score == 5.0
        assert sel.total_score == 15.0 and sel.total_use.bram == 12


def test_co_select_trades_down_the_right_memory():
    # bram cap 10: argmins draw 12.  Cheapest total under the cap keeps
    # a's argmin (8) and trades b down (2) -> 60.0 beats trading a
    # down (2+4=6 for 35.0)... which is cheaper still: the exact search
    # must find 35.0, not the greedy 60.0.
    sel = co_select(_fronts(), ResourceBudget(bram=10))
    assert sel.feasible and sel.total_use.bram <= 10
    assert sel.total_score == 35.0
    assert sel.picks["a"].score == 30.0 and sel.picks["b"].score == 5.0
    # no trivial member was needed
    assert not any(p.trivial for p in sel.picks.values())


def test_co_select_falls_back_to_trivial_under_pressure():
    # bram=3: the cheapest real pair draws 4, so exactly one member must
    # serialize -- and the exact search trades down the one whose real
    # scheme it can keep cheapest (keep a's 30.0, trivialize b)
    sel = co_select(_fronts(), ResourceBudget(bram=3))
    assert sel.feasible and sel.total_use.bram <= 3
    picked_trivial = [n for n, p in sel.picks.items() if p.trivial]
    assert picked_trivial == ["b"]
    assert sel.picks["a"].score == 30.0


def test_co_select_infeasible_returns_all_trivial_never_raises():
    sel = co_select(_fronts(), ResourceBudget(bram=1))   # floor is 2
    assert not sel.feasible
    assert all(p.trivial for p in sel.picks.values())
    assert sel.total_use.bram == 2                       # honest accounting


# ---------------------------------------------------------------------------
# The JointTicket graph (service front door)
# ---------------------------------------------------------------------------


def test_submit_joint_slack_equals_independent():
    svc = PlanService(workers=2)
    prog = _joint_program()
    jplan = svc.submit_joint(prog).result(timeout=120)
    assert jplan.feasible and jplan.fits()
    for name in ("a", "b"):
        indep = svc.submit(prog, name).result(timeout=120)
        m = jplan.members[name]
        assert not m.trivial
        assert m.chosen.describe() == indep.best.describe()
    assert jplan.total_use.as_tuple() == independent_use(
        {n: svc.submit(prog, n).result(timeout=120)
         for n in ("a", "b")}).as_tuple()
    svc.shutdown()


def test_submit_joint_budget_fits_where_independent_does_not():
    svc = PlanService(workers=2)
    prog = _joint_program()
    free = svc.submit_joint(prog).result(timeout=120)
    cap = ResourceBudget(bram=max(2, int(free.total_use.bram * 0.6)))
    assert not cap.admits(free.total_use)        # independent blows it
    squeezed = svc.submit_joint(prog, budget=cap).result(timeout=120)
    assert squeezed.feasible and squeezed.fits()
    assert squeezed.total_use.bram <= cap.bram
    # fitting required actually trading some member down
    traded = [n for n in ("a", "b")
              if (squeezed.members[n].chosen.describe()
                  != free.members[n].chosen.describe())]
    assert traded
    svc.shutdown()


def test_submit_joint_infeasible_never_raises():
    svc = PlanService(workers=2)
    prog = _joint_program()
    # two memories, one physical bank total: even all-trivial needs 2
    t = svc.submit_joint(prog, budget=ResourceBudget(banks=1))
    jplan = t.result(timeout=120)                # resolves, no exception
    assert not jplan.feasible and not jplan.fits()
    assert all(m.trivial and is_trivial(m.chosen)
               for m in jplan.members.values())
    assert svc.stats.joint_infeasible == 1
    # the ticket still hands out executable artifacts for every member
    arts = t.artifacts(backend="numpy")
    assert set(arts) == {"a", "b"} and all(a.n_banks == 1
                                           for a in arts.values())
    svc.shutdown()


def test_joint_fallback_serves_before_any_solve(monkeypatch):
    gate = threading.Event()
    real = BankingPlanner.build_space

    def gated(self, prep):
        gate.wait(30)
        return real(self, prep)

    monkeypatch.setattr(BankingPlanner, "build_space", gated)
    svc = PlanService(workers=2)
    t = svc.submit_joint(_joint_program())
    assert not t.done()
    fbs = t.fallback(backend="numpy")
    assert set(fbs) == {"a", "b"}
    flat = np.arange(256 * 2, dtype=np.float32).reshape(256, 2)
    got = fbs["a"].gather(fbs["a"].pack(flat), np.asarray([0, 5, 255]))
    np.testing.assert_array_equal(got, flat[[0, 5, 255]])
    gate.set()
    assert t.result(timeout=120).feasible
    svc.shutdown()


@pytest.mark.parametrize("block_first", ["a", "b"])
def test_selection_invariant_to_completion_order(monkeypatch, block_first):
    """The same problem solved with either member landing last must
    produce the identical joint plan -- selection is a pure function of
    the final frontiers, not of arrival order."""
    gate = threading.Event()
    real = BankingPlanner.build_space

    def gated(self, prep):
        if prep.mem.name == block_first:
            gate.wait(30)
        return real(self, prep)

    monkeypatch.setattr(BankingPlanner, "build_space", gated)
    svc = PlanService(workers=2)
    prog = _joint_program()
    budget = ResourceBudget(bram=9)
    t = svc.submit_joint(prog, budget=budget)
    other = "b" if block_first == "a" else "a"
    t.members[other].result(timeout=120)         # other member lands first
    gate.set()
    jplan = t.result(timeout=120)
    svc.shutdown()
    # reference: the same problem with no gating at all
    svc2 = PlanService(workers=2)
    ref = svc2.submit_joint(prog, budget=budget).result(timeout=120)
    svc2.shutdown()
    assert jplan.signature == ref.signature
    assert jplan.total_use.as_tuple() == ref.total_use.as_tuple()
    for name in ("a", "b"):
        assert (jplan.members[name].chosen.describe()
                == ref.members[name].chosen.describe())


def test_progressive_reselection_while_members_land(monkeypatch):
    """While one member is still solving, selection() serves the landed
    member's real scheme + the other's trivial; best_version bumps when
    the blocked member finally lands."""
    gate = threading.Event()
    real = BankingPlanner.build_space

    def gated(self, prep):
        if prep.mem.name == "a":
            gate.wait(30)
        return real(self, prep)

    monkeypatch.setattr(BankingPlanner, "build_space", gated)
    svc = PlanService(workers=2)
    t = svc.submit_joint(_joint_program())
    t.members["b"].result(timeout=120)
    sel = t.selection()
    assert not sel.picks["b"].trivial      # landed member: real scheme
    assert sel.picks["a"].trivial          # in-flight member: trivial
    v = t.best_version()
    gate.set()
    jplan = t.result(timeout=120)
    assert not jplan.members["a"].trivial
    assert t.best_version() > v            # the joint selection moved
    assert svc.stats.joint_reselects >= 1
    svc.shutdown()


def test_cert_rejection_of_one_member_never_poisons_group(monkeypatch):
    """A certifier that refuses every scheme for memory 'a' must degrade
    'a' to trivial -- 'b' still lands solved AND certified."""
    from repro.analysis import certify as certify_mod

    real = certify_mod.certify_solution

    def hostile(sol, groups, iterators, **kw):
        res = real(sol, groups, iterators, **kw)
        if sol.memory.name == "a" and not is_trivial(sol):
            res.ok = False
            res.certificate = None
        return res

    monkeypatch.setattr(certify_mod, "certify_solution", hostile)
    svc = PlanService(workers=2, verify="store")
    jplan = svc.submit_joint(_joint_program()).result(timeout=120)
    a, b = jplan.members["a"], jplan.members["b"]
    assert a.trivial and not a.certified and a.certificate is None
    assert not b.trivial and b.certified and b.certificate is not None
    # the certificate is machine-checkable (PR-7 contract)
    from repro.analysis import check_certificate
    from repro.analysis.certify import ConflictCertificate
    ok, _why = check_certificate(ConflictCertificate.from_json(b.certificate))
    assert ok
    svc.shutdown()


def test_joint_plan_persists_and_hydrates(tmp_path):
    store = DirectoryStore(tmp_path)
    svc = PlanService(workers=2, store=store)
    prog = _joint_program()
    budget = ResourceBudget(bram=64)
    first = svc.submit_joint(prog, budget=budget).result(timeout=120)
    assert (tmp_path / "joint" / f"{first.signature}.json").exists()
    svc.shutdown()
    # a fresh service over the same directory answers before returning
    svc2 = PlanService(workers=2, store=DirectoryStore(tmp_path))
    t = svc2.submit_joint(prog, budget=budget)
    assert t.done() and svc2.stats.joint_sync_hits == 1
    hydrated = t.result()
    assert hydrated.status == "cached-disk"
    assert hydrated.signature == first.signature
    assert hydrated.total_use.as_tuple() == first.total_use.as_tuple()
    for name in ("a", "b"):
        assert (hydrated.members[name].chosen.describe()
                == first.members[name].chosen.describe())
    # round-trip through JSON is exact on the accounting view
    assert (JointPlan.from_json(first.to_json()).as_dict()
            == first.as_dict())
    svc2.shutdown()


# ---------------------------------------------------------------------------
# plan_all rides the joint graph
# ---------------------------------------------------------------------------


def test_plan_all_without_budget_matches_independent():
    planner = BankingPlanner()
    prog = _joint_program()
    plans = planner.plan_all(prog)
    assert set(plans) == {"a", "b"}
    for name, p in plans.items():
        assert p.status in ("solved", "cached")
        indep = planner.plan(prog, name)
        assert p.best.describe() == indep.best.describe()
    row = plans["a"].table_row()
    assert "volume" in row and row["banks"] == plans["a"].best.num_banks
    d = plans["a"].as_dict()
    assert d["resources"]["total"]["bram"] >= 1


def test_plan_all_under_budget_fits_where_independent_does_not():
    planner = BankingPlanner()
    prog = _joint_program()
    free = planner.plan_all(prog)
    free_use = independent_use(free)
    cap = ResourceBudget(bram=max(2, int(free_use.bram * 0.6)))
    assert not cap.admits(free_use)
    squeezed = planner.plan_all(prog, budget=cap)
    got = ResourceUse()
    for p in squeezed.values():
        got = got + ResourceUse.of_solution(p.best)
    assert cap.admits(got)


def test_plan_all_timeout_contract(monkeypatch):
    gate = threading.Event()
    real = BankingPlanner.build_space

    def gated(self, prep):
        gate.wait(30)
        return real(self, prep)

    monkeypatch.setattr(BankingPlanner, "build_space", gated)
    planner = BankingPlanner()
    plans = planner.plan_all(_joint_program(), timeout=0.2)
    gate.set()
    for p in plans.values():
        assert p.status == "timeout"
        assert "exceeded 0.2s budget" in p.error


# ---------------------------------------------------------------------------
# Coherent multi-pool server swap
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_server_swaps_all_pools_coherently(monkeypatch):
    """An MoE model serves through TWO banked pools (KV pages + MoE
    dispatch).  With the KV solve gated, the server starts on the joint
    fallback; releasing the gate must promote BOTH pools in ONE
    generation bump -- never a mixed generation, asserted every tick."""
    jax = pytest.importorskip("jax")  # noqa: F841
    from repro.configs import get_arch
    from repro.models import get_model
    from repro.runtime.server import Request, Server, joint_ticket

    gate = threading.Event()
    real = BankingPlanner.build_space

    def gated(self, prep):
        if prep.mem.name == "kv_pool":
            gate.wait(60)
        return real(self, prep)

    monkeypatch.setattr(BankingPlanner, "build_space", gated)
    cfg = get_arch("olmoe-1b-7b").reduced()
    svc = PlanService(workers=2)
    ticket = joint_ticket(cfg, max_len=32, page=8, readers=2, service=svc)
    assert set(ticket.members) == {"kv_pool", "moe_dispatch"}
    model = get_model(cfg)
    server = Server(model, max_batch=2, max_len=32, kv_plan=ticket)
    assert "moe_dispatch" in server.pools
    assert server.coherent and set(server.generations.values()) == {0}

    # every tick must observe a single generation across all pools
    orig_tick = server._tick

    def checked_tick():
        assert server.coherent, f"mixed generations: {server.generations}"
        orig_tick()

    server._tick = checked_tick
    rng = np.random.default_rng(0)
    r0 = Request(uid=0, prompt=rng.integers(
        2, cfg.vocab - 1, size=3).astype(np.int32), max_new=2)
    server.submit(r0)
    server.run(max_ticks=50)          # serve from fallback, gate closed
    assert r0.done and r0.out
    gate.set()
    plan = ticket.result(timeout=120)
    assert not plan.members["kv_pool"].trivial
    r1 = Request(uid=1, prompt=rng.integers(
        2, cfg.vocab - 1, size=3).astype(np.int32), max_new=2)
    server.submit(r1)
    server.run(max_ticks=50)          # adopts the final joint selection
    assert server.joint_swaps + server.joint_promotions >= 1
    assert server.coherent
    gens = set(server.generations.values())
    assert len(gens) == 1 and gens.pop() >= 1
    assert r1.done and r1.out
    svc.shutdown()
