"""Property tests: a CompiledBankingPlan's transformed resolution circuit
agrees with the brute-force numpy reference (raw Eq. 1-2 over the
geometry) across flat and multidim geometries, and pack/unpack is a
lossless round-trip under padding."""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import assume, given, settings, strategies as st

from repro.core import (FlatGeometry, MemorySpec, MultiDimGeometry,
                        compile_geometry)
from repro.core.geometry import propose_P


def _coords(addr, dims):
    out, rem = [], addr
    for d in reversed(dims):
        out.append(rem % d)
        rem //= d
    return tuple(reversed(out))


@st.composite
def flat_cases(draw):
    n = draw(st.integers(1, 2))
    dims = tuple(draw(st.integers(4, 20)) for _ in range(n))
    N = draw(st.integers(1, 8))
    B = draw(st.sampled_from([1, 2, 3, 4]))
    if draw(st.booleans()) or n == 1:
        d = draw(st.integers(0, n - 1))
        alpha = tuple(1 if i == d else 0 for i in range(n))
    else:
        alpha = (1,) * n
    mem = MemorySpec("m", dims=dims, word_bits=16, ports=1)
    P = propose_P(mem, N, B, alpha)[0]
    return mem, FlatGeometry(N=N, B=B, alpha=alpha, P=P)


@st.composite
def multidim_cases(draw):
    dims = tuple(draw(st.integers(4, 12)) for _ in range(2))
    Ns = tuple(draw(st.integers(1, 4)) for _ in range(2))
    Bs = tuple(draw(st.sampled_from([1, 2])) for _ in range(2))
    mem = MemorySpec("m", dims=dims, word_bits=16, ports=1)
    return mem, MultiDimGeometry(Ns=Ns, Bs=Bs, alphas=(1, 1))


@settings(max_examples=40, deadline=None)
@given(flat_cases())
def test_flat_ba_bo_match_bruteforce(case):
    mem, geo = case
    art = compile_geometry(mem, geo, backend="numpy")
    A = art.layout.logical_size
    ba, bo = art.resolve(np.arange(A, dtype=np.int64))
    ba = np.broadcast_to(np.asarray(ba), (A,))
    bo = np.broadcast_to(np.asarray(bo), (A,))
    for a in range(A):
        x = _coords(a, mem.dims)
        assert ba[a] == geo.bank_address(x)
        assert bo[a] == geo.bank_offset(x, mem.dims)


@settings(max_examples=30, deadline=None)
@given(multidim_cases())
def test_multidim_ba_bo_match_bruteforce(case):
    mem, geo = case
    art = compile_geometry(mem, geo, backend="numpy")
    A = art.layout.logical_size
    ba, bo = art.resolve(np.arange(A, dtype=np.int64))
    ba = np.broadcast_to(np.asarray(ba), (A,))
    bo = np.broadcast_to(np.asarray(bo), (A,))
    for a in range(A):
        x = _coords(a, mem.dims)
        folded = 0
        for b, n in zip(geo.bank_address(x), geo.Ns):
            folded = folded * n + b
        assert ba[a] == folded
        assert bo[a] == geo.bank_offset(x, mem.dims)


@settings(max_examples=25, deadline=None)
@given(flat_cases())
def test_unpack_inverts_pack_under_padding(case):
    import jax.numpy as jnp

    mem, geo = case
    art = compile_geometry(mem, geo)
    A = art.layout.logical_size
    # pack is only injective when the layout places every logical address
    # in its own slot -- true for verified P orthotopes; skip degenerate
    # fallback layouts where the capacity argument fails
    ba, bo = art._tables()
    assume(len({(int(a), int(o)) for a, o in zip(ba, bo)}) == A)
    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(A, 2)), jnp.float32)
    assert (np.asarray(art.unpack(art.pack(x))) == np.asarray(x)).all()
