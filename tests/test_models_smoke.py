"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models import get_model


KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    b = {"tokens": jnp.asarray(np.arange(B * S).reshape(B, S) % 17 + 2,
                               jnp.int32),
         "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "encdec":
        b["frames"] = jnp.full((B, S, cfg.d_model), 0.01, jnp.bfloat16)
    return b


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_arch(arch).reduced()
    model = get_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss)), arch
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_arch(arch).reduced()
    model = get_model(cfg)
    params = model.init(KEY)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, 32))(params, batch)
    assert logits.shape == (B, cfg.vocab), arch
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    logits2, cache2 = jax.jit(model.decode)(params, cache, tok)
    assert logits2.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ["qwen2_7b", "mamba2_370m", "zamba2_2_7b"])
def test_decode_matches_forward(arch):
    """Prefill+decode logits == full-forward logits at the same position."""
    cfg = get_arch(arch).reduced()
    model = get_model(cfg)
    params = model.init(KEY)
    B, S = 1, 16
    toks = jnp.asarray(np.arange(S).reshape(B, S) % 13 + 2, jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    # full forward over S+1 tokens vs prefill(S) + decode(1)
    nxt = jnp.full((B, 1), 5, jnp.int32)
    full = {"tokens": jnp.concatenate([toks, nxt], axis=1)}
    logits_pre, cache = jax.jit(lambda p, b: model.prefill(p, b, 32))(
        params, batch)
    logits_dec, _ = jax.jit(model.decode)(params, cache, nxt)

    if cfg.family in ("dense", "vlm"):
        from repro.models import transformer as tfm
        h = tfm.forward(cfg, params, full["tokens"])
        logits_full = tfm.logits_fn(cfg, params, h)[:, -1]
    elif cfg.family == "ssm":
        from repro.models import ssm
        h = ssm.forward(cfg, params, full["tokens"])
        from repro.models import transformer as tfm
        logits_full = tfm.logits_fn(cfg, params, h)[:, -1]
    else:
        from repro.models import hybrid
        from repro.models import transformer as tfm
        h = hybrid.forward(cfg, params, full["tokens"])
        logits_full = tfm.logits_fn(cfg, params, h)[:, -1]

    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full, np.float32), atol=0.15, rtol=0.05)


def test_gemma3_layer_windows():
    from repro.models.transformer import layer_windows
    cfg = get_arch("gemma3_12b")
    w = layer_windows(cfg)
    assert len(w) == 48
    assert (w[5::6] == 0).all()          # every 6th layer global
    assert (w[w != 0] == 1024).all()     # rest local
    assert (w != 0).sum() == 40


def test_chunked_attention_matches_naive():
    from repro.models.layers import chunked_attention, naive_attention
    rng = np.random.default_rng(0)
    B, Sq, Sk, H, Hkv, Dh = 2, 64, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, Sq, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, Hkv, Dh)), jnp.float32)
    for window in (0, 16):
        got = chunked_attention(q, k, v, causal=True, window=window,
                                block_k=16, block_q=16)
        want = naive_attention(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)


def test_decode_attention_matches_naive():
    from repro.models.layers import decode_attention, naive_attention
    rng = np.random.default_rng(1)
    B, Sk, H, Hkv, Dh = 2, 128, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, 1, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, Hkv, Dh)), jnp.float32)
    got = decode_attention(q, k, v, causal=True, q_offset=63, kv_len=64)
    want = naive_attention(q, k, v, causal=True, q_offset=63, kv_len=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
