"""Planner subsystem: signature cache, JSON durability, scorer registry."""

import json

import numpy as np
import pytest

from repro.core import (AccessDecl, BankingPlan, BankingPlanner, Counter,
                        Ctrl, MemorySpec, PlanRequest, Program, Sched,
                        SolverOptions, program_signature,
                        register_scorer, resolve_scorer)
from repro.core import planner as planner_mod
from repro.core.polytope import Affine


def _reader_program(stride=1, count=32, par=8, dims=(256,), name="table"):
    mem = MemorySpec(name, dims=dims, word_bits=32, ports=1)
    return Program(
        root=Ctrl("reader", Sched.INNER,
                  counters=[Counter("i", 0, 1, count, par=par)],
                  accesses=[AccessDecl(name, (Affine.of(i=stride),))]),
        memories={name: mem},
    )


@pytest.fixture
def solve_counter(monkeypatch):
    """Count real solver invocations made through the planner.

    Every cold-solve path (the blocking plan() and the service's sharded
    workers alike) begins by enumerating its candidate space through
    BankingPlanner.build_space -- the one chokepoint worth counting."""
    calls = []
    real = BankingPlanner.build_space

    def counting(self, prep):
        calls.append(1)
        return real(self, prep)

    monkeypatch.setattr(BankingPlanner, "build_space", counting)
    return calls


# ---------------------------------------------------------------------------
# Cache semantics
# ---------------------------------------------------------------------------


def test_cache_hit_performs_zero_solver_calls(solve_counter):
    planner = BankingPlanner()
    p1 = planner.plan(_reader_program(), "table")
    assert len(solve_counter) == 1 and p1.status == "solved"
    # a structurally identical but freshly-built program -> pure cache hit
    p2 = planner.plan(_reader_program(), "table")
    assert len(solve_counter) == 1          # ZERO additional solver calls
    assert p2.status == "cached"
    assert p2.best.geometry == p1.best.geometry
    assert planner.stats.hits == 1 and planner.stats.solves == 1


def test_mutated_access_re_solves(solve_counter):
    planner = BankingPlanner()
    planner.plan(_reader_program(stride=1), "table")
    planner.plan(_reader_program(stride=2), "table")   # different polytopes
    assert len(solve_counter) == 2
    assert planner.stats.misses == 2 and planner.stats.hits == 0


def test_signature_is_structural_not_nominal():
    """Same polytopes under a different memory name -> same signature."""
    a = program_signature(_reader_program(name="kv_pool"), "kv_pool")
    b = program_signature(_reader_program(name="table"), "table")
    assert a == b
    # ...but solver options are part of the identity
    c = program_signature(_reader_program(name="table"), "table",
                          SolverOptions(n_budget=7))
    assert c != b


def test_opts_and_scorer_key_the_cache(solve_counter):
    planner = BankingPlanner()
    prog = _reader_program()
    planner.plan(prog, "table", opts=SolverOptions(n_budget=8))
    planner.plan(prog, "table", opts=SolverOptions(n_budget=16))
    assert len(solve_counter) == 2
    # same opts, different scorer -> re-rank requires a fresh solve entry
    planner.plan(prog, "table", opts=SolverOptions(n_budget=8),
                 scorer=lambda s: float(s.num_banks))
    assert len(solve_counter) == 3


def test_plan_request_object_entry_point(solve_counter):
    planner = BankingPlanner()
    req = PlanRequest(program=_reader_program(), memory="table")
    plan = planner.plan(req)
    assert plan.best is not None and len(solve_counter) == 1


# ---------------------------------------------------------------------------
# Durability: JSON round-trip + disk cache
# ---------------------------------------------------------------------------


def test_plan_json_roundtrip_preserves_scheme():
    planner = BankingPlanner()
    plan = planner.plan(_reader_program(), "table")
    blob = json.dumps(plan.to_json())            # proves JSON-serializable
    back = BankingPlan.from_json(json.loads(blob))
    assert back.signature == plan.signature
    assert back.scorer_name == plan.scorer_name
    assert back.num_candidates == plan.num_candidates
    assert back.solve_seconds == plan.solve_seconds
    b0, b1 = plan.best, back.best
    assert b1.kind == b0.kind and b1.geometry == b0.geometry
    assert (b1.num_banks, b1.bank_volume, b1.P, b1.pad) == \
        (b0.num_banks, b0.bank_volume, b0.P, b0.pad)
    assert b1.fan_outs == b0.fan_outs and b1.score == b0.score
    assert b1.resources.total.lut == pytest.approx(b0.resources.total.lut)
    # the reloaded plan compiles to an artifact that drives the kernel
    from repro.kernels import ref
    from repro.core import compile_plan
    import jax.numpy as jnp
    art = compile_plan(back)
    flat = jnp.asarray(np.random.default_rng(0).normal(size=(256, 4)),
                       jnp.float32)
    table = art.pack(flat)
    idx = jnp.asarray([0, 5, 200, 131], jnp.int32)
    got = art.gather(table, idx)
    assert (np.asarray(got) == np.asarray(
        ref.banked_gather_reference(flat, idx))).all()


def test_disk_cache_warm_start(tmp_path, solve_counter):
    cold = BankingPlanner(cache_dir=tmp_path)
    plan = cold.plan(_reader_program(), "table")
    assert len(list(tmp_path.glob("*.json"))) == 1
    # a new planner process warm-starts from the persisted plan
    warm = BankingPlanner(cache_dir=tmp_path)
    hit = warm.plan(_reader_program(), "table")
    assert hit.status == "cached-disk"
    assert hit.best.geometry == plan.best.geometry
    assert len(solve_counter) == 1           # only the cold planner solved
    # explicit warm_start() preloads into the in-memory cache
    fresh = BankingPlanner()
    assert fresh.warm_start(tmp_path) == 1
    assert fresh.plan(_reader_program(), "table").status == "cached"
    assert len(solve_counter) == 1


def test_corrupt_disk_plan_falls_back_to_solve(tmp_path, solve_counter):
    BankingPlanner(cache_dir=tmp_path).plan(_reader_program(), "table")
    for f in tmp_path.glob("*.json"):
        f.write_text("{not json")
    repaired = BankingPlanner(cache_dir=tmp_path)
    plan = repaired.plan(_reader_program(), "table")
    assert plan.status == "solved" and len(solve_counter) == 2
    # the re-solve rewrote the damaged file
    assert BankingPlanner(cache_dir=tmp_path).plan(
        _reader_program(), "table").status == "cached-disk"


# ---------------------------------------------------------------------------
# Scorer registry
# ---------------------------------------------------------------------------


def test_scorer_registry_resolution():
    name, fn = resolve_scorer("proxy")
    assert name == "proxy" and fn is None
    name, fn = resolve_scorer(None)
    assert name == "proxy"

    def my_scorer(sol):
        return float(sol.num_banks)

    name, fn = resolve_scorer(my_scorer)
    assert name.startswith("custom:my_scorer:") and fn is my_scorer

    register_scorer("banks", lambda: my_scorer)
    name, fn = resolve_scorer("banks")
    assert name == "banks" and fn is my_scorer


def test_distinct_callable_scorers_do_not_alias(solve_counter):
    """Two different lambdas share __name__; identity must key the cache."""
    planner = BankingPlanner()
    p1 = planner.plan(_reader_program(), "table",
                      scorer=lambda s: float(s.num_banks))
    p2 = planner.plan(_reader_program(), "table",
                      scorer=lambda s: -float(s.num_banks))
    assert len(solve_counter) == 2
    assert [s.num_banks for s in p1.solutions] == \
        sorted(s.num_banks for s in p1.solutions)
    assert [s.num_banks for s in p2.solutions] == \
        sorted((s.num_banks for s in p2.solutions), reverse=True)


def test_cache_hit_is_isolated_and_relabeled(solve_counter):
    planner = BankingPlanner()
    planner.plan(_reader_program(name="kv_pool"), "kv_pool")
    hit = planner.plan(_reader_program(name="table"), "table")
    assert hit.status == "cached" and len(solve_counter) == 1
    assert hit.memory == "table"        # relabeled for the requester
    hit.solutions.clear()               # caller mutation must not poison
    again = planner.plan(_reader_program(name="table"), "table")
    assert again.solutions and len(solve_counter) == 1


def test_unknown_scorer_name_raises():
    with pytest.raises(ValueError, match="unknown scorer 'nope'"):
        resolve_scorer("nope")
    with pytest.raises(ValueError, match="proxy"):
        BankingPlanner().plan(_reader_program(), "table", scorer="nope")


def test_registered_scorer_drives_ranking():
    register_scorer("neg_banks", lambda: (lambda s: -float(s.num_banks)))
    plan = BankingPlanner(scorer="neg_banks").plan(_reader_program(), "table")
    assert plan.scorer_name == "neg_banks"
    banks = [s.num_banks for s in plan.solutions]
    assert banks == sorted(banks, reverse=True)


# ---------------------------------------------------------------------------
# Batched planning
# ---------------------------------------------------------------------------


def test_plan_all_covers_every_memory():
    mem_a = MemorySpec("a", dims=(64,), ports=1)
    mem_b = MemorySpec("b", dims=(32, 32), ports=1)
    prog = Program(
        root=Ctrl("root", Sched.SEQUENTIAL, children=[
            Ctrl("ra", Sched.INNER,
                 counters=[Counter("i", 0, 1, 16, par=4)],
                 accesses=[AccessDecl("a", (Affine.of(i=1),))]),
            Ctrl("rb", Sched.INNER,
                 counters=[Counter("r", 0, 1, 16, par=2),
                           Counter("c", 0, 1, 16)],
                 accesses=[AccessDecl("b", (Affine.of(r=1), Affine.of(c=1)))]),
        ]),
        memories={"a": mem_a, "b": mem_b},
    )
    plans = BankingPlanner().plan_all(prog)
    assert set(plans) == {"a", "b"}
    assert all(p.status == "solved" and p.best is not None
               for p in plans.values())


def test_plan_all_timeout_yields_timeout_plan(monkeypatch):
    import time as time_mod

    real = planner_mod.solve

    def slow_solve(*a, **kw):
        time_mod.sleep(1.5)
        return real(*a, **kw)

    monkeypatch.setattr(planner_mod, "solve", slow_solve)
    plans = BankingPlanner().plan_all(_reader_program(), timeout=0.05)
    assert plans["table"].status == "timeout"
    assert plans["table"].best is None


# ---------------------------------------------------------------------------
# ML scorer persistence (trained pipelines live next to the plan cache)
# ---------------------------------------------------------------------------


def _tiny_ml_scorer():
    from repro.core.cost_model import MLScorer, ResourcePipeline
    from repro.core.features import FEATURE_NAMES

    rng = np.random.default_rng(0)
    X = rng.uniform(1, 8, size=(24, len(FEATURE_NAMES)))
    pipes = {
        k: ResourcePipeline(gbt_params=dict(n_estimators=3)).fit(
            X, rng.uniform(10, 100, size=24))
        for k in ("lut", "ff")
    }
    return MLScorer(pipes), X


def test_ml_scorer_json_roundtrip_predicts_identically():
    from repro.core.cost_model import MLScorer

    scorer, X = _tiny_ml_scorer()
    back = MLScorer.from_json(json.loads(json.dumps(scorer.to_json())))
    for k in scorer.pipelines:
        np.testing.assert_allclose(back.pipelines[k].predict(X),
                                   scorer.pipelines[k].predict(X))
    assert back.weights == scorer.weights


def test_ml_factory_loads_persisted_pipeline_instead_of_training(
        tmp_path, monkeypatch):
    scorer, _ = _tiny_ml_scorer()
    path = tmp_path / "ml_scorer.json"
    path.write_text(json.dumps(scorer.to_json()))

    monkeypatch.setattr(planner_mod, "_ML_SCORER_PATH", path)
    monkeypatch.setattr(planner_mod._ml_scorer_factory, "_cached", None,
                        raising=False)

    def boom():
        raise AssertionError("factory re-trained despite persisted pipeline")

    monkeypatch.setattr(planner_mod, "_train_ml_scorer", boom)
    _, loaded = resolve_scorer("ml")
    assert set(loaded.pipelines) == set(scorer.pipelines)
    # corrupt pipeline file falls back to training
    path.write_text("{not json")
    monkeypatch.setattr(planner_mod._ml_scorer_factory, "_cached", None,
                        raising=False)
    with pytest.raises(AssertionError, match="re-trained"):
        resolve_scorer("ml")


def test_planner_cache_dir_points_ml_scorer_next_to_plans(
        tmp_path, monkeypatch):
    monkeypatch.setattr(planner_mod, "_ML_SCORER_PATH", None)
    BankingPlanner(cache_dir=tmp_path)
    assert planner_mod._ML_SCORER_PATH == tmp_path / "ml_scorer.json"


# ---------------------------------------------------------------------------
# One shared code path: plan() == submit().result()
# ---------------------------------------------------------------------------


def test_plan_is_thin_submit_result(solve_counter):
    """The blocking front door routes through the inline service."""
    planner = BankingPlanner()
    prog = _reader_program(stride=3, count=16, par=4)
    plan = planner.plan(prog, "table")
    assert plan.status == "solved" and len(solve_counter) == 1
    svc = planner.service
    assert svc.planner is planner
    # a submit for the same problem is answered from the plan() solve
    ticket = svc.submit(prog, "table")
    assert ticket.done() and len(solve_counter) == 1
    assert ticket.result().best.geometry == plan.best.geometry
    assert svc.stats.sync_hits >= 1


def test_legacy_free_functions_are_gone():
    import repro.core as core
    assert not hasattr(core, "partition_memory")
    assert not hasattr(core, "partition_all")
    assert not hasattr(core, "BankingReport")


def test_table_row_reads_off_plan():
    plan = BankingPlanner().plan(_reader_program(), "table")
    row = plan.table_row()
    assert row["banks"] == plan.best.num_banks
    assert row["seconds"] == plan.solve_seconds
    assert row["lut"] == pytest.approx(plan.best.resources.total.lut)


def test_family_signature_ignores_solver_options():
    prog = _reader_program()
    planner = BankingPlanner()
    a = planner.plan(prog, "table", opts=SolverOptions(n_budget=8))
    b = planner.plan(prog, "table", opts=SolverOptions(n_budget=16))
    assert a.signature != b.signature      # options key the exact cache
    assert a.family == b.family            # ...but share a family
    c = planner.plan(_reader_program(stride=2), "table")
    assert c.family != a.family            # different polytopes differ
