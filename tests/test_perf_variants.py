"""Correctness of the Perf-iteration code paths (EXPERIMENTS.md §Perf)."""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import ArchConfig


def _gemma_like() -> ArchConfig:
    return ArchConfig(
        name="g-mini", family="dense", n_layers=6, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
        sliding_window=8, local_global_ratio=2,  # 2 local : 1 global
    )


@pytest.mark.slow
def test_grouped_ring_decode_matches_dense_decode():
    """Ring-banked local caches must be bit-compatible with the full-buffer
    decode (window masking == ring retention), including past wrap-around."""
    from repro.models import transformer as tfm

    cfg = _gemma_like()
    key = jax.random.PRNGKey(0)
    params = tfm.init_dense_params(cfg, key)
    B, steps = 2, 14  # > window (8): exercises ring wrap
    max_len = 32

    cache_full = tfm.init_cache(cfg, B, max_len)
    cache_ring = tfm.init_grouped_cache(cfg, B, max_len)
    tok = jnp.full((B, 1), 3, jnp.int32)
    dec_full = jax.jit(lambda p, c, t: tfm.decode_step(cfg, p, c, t))
    dec_ring = jax.jit(lambda p, c, t: tfm.grouped_decode_step(cfg, p, c, t))
    for step in range(steps):
        lf, cache_full = dec_full(params, cache_full, tok)
        lr, cache_ring = dec_ring(params, cache_ring, tok)
        np.testing.assert_allclose(
            np.asarray(lf, np.float32), np.asarray(lr, np.float32),
            atol=0.05, rtol=0.02), step
        tok = jnp.argmax(lf, -1).astype(jnp.int32)[:, None]


def test_moe_a2a_fallback_without_mesh():
    """Without a mesh policy, a2a must equal the sorted implementation."""
    from repro.models import moe as moe_mod

    cfg = dataclasses.replace(get_arch("olmoe_1b_7b").reduced(),
                              capacity_factor=8.0)
    params = moe_mod.init_moe_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda x: x[0], params["layers"])
    h = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, cfg.d_model)),
                    jnp.float32)
    ys, _ = moe_mod.moe_ffn_sorted(cfg, lp, h)
    ya, _ = moe_mod.moe_ffn_a2a(cfg, lp, h)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ya), atol=1e-5)


@pytest.mark.slow
def test_moe_a2a_matches_oracle_on_mesh():
    """4-device subprocess: shard_map dispatch == dense oracle."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    code = textwrap.dedent("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_arch
        from repro.models import moe as moe_mod
        from repro.parallel.hints import sharding_policy

        cfg = dataclasses.replace(get_arch("olmoe_1b_7b").reduced(),
                                  n_experts=4, top_k=2, capacity_factor=8.0)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        params = moe_mod.init_moe_params(cfg, jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda x: x[0], params["layers"])
        h = jnp.asarray(np.random.default_rng(0).normal(
            size=(2, 16, cfg.d_model)), jnp.float32)
        yd, _ = moe_mod.moe_ffn_dense(cfg, lp, h)
        with mesh, sharding_policy({"__mesh__": mesh}):
            ya, _ = jax.jit(lambda l, x: moe_mod.moe_ffn_a2a(cfg, l, x))(lp, h)
        np.testing.assert_allclose(np.asarray(yd, np.float32),
                                   np.asarray(ya, np.float32), atol=3e-2)
        print("A2A-OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "A2A-OK" in out.stdout


def test_adamw_bf16_moments():
    from repro.optim import adamw

    params = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    st = adamw.init(params, moment_dtype=jnp.bfloat16)
    assert st.m["w"].dtype == jnp.bfloat16
    cfg = adamw.AdamWConfig(total_steps=10, warmup_steps=1)
    grads = {"w": jnp.full((8, 8), 0.1, jnp.float32)}
    new_p, st2 = adamw.update(cfg, grads, st, params)
    assert st2.m["w"].dtype == jnp.bfloat16
    # the fp32 master must move even when the bf16 live copy rounds back
    assert float(jnp.abs(st2.master["w"] - 1.0).max()) > 0
    assert new_p["w"].dtype == jnp.bfloat16


def test_zero_pod_axis_specs():
    from repro.parallel import sharding as shd

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    import jax
    from repro.models import get_model
    cfg = dataclasses.replace(get_arch("llama4_maverick"), n_layers=2)
    shapes = jax.eval_shape(
        lambda: get_model(cfg).init(jax.random.PRNGKey(0)))
    specs = shd.param_specs(shapes, FakeMesh(), fsdp=True,
                            fsdp_axes=("data", "pod"))
    # expert F dim cut across BOTH pure-DP axes (32-way ZeRO)
    assert specs["layers"]["we_gate"][3] == ("data", "pod")


def test_int8_kv_decode_close_to_exact():
    """Quantized-cache decode must track the exact decode closely."""
    from repro.models import transformer as tfm

    cfg = get_arch("qwen2_7b").reduced()
    params = tfm.init_dense_params(cfg, jax.random.PRNGKey(0))
    B, steps, max_len = 2, 6, 16
    cache = tfm.init_cache(cfg, B, max_len)
    cache_q = tfm.init_quant_cache(cfg, B, max_len)
    tok = jnp.full((B, 1), 3, jnp.int32)
    dec = jax.jit(lambda p, c, t: tfm.decode_step(cfg, p, c, t))
    dec_q = jax.jit(lambda p, c, t: tfm.decode_step_quant(cfg, p, c, t))
    for _ in range(steps):
        lf, cache = dec(params, cache, tok)
        lq, cache_q = dec_q(params, cache_q, tok)
        pf = jax.nn.softmax(lf.astype(jnp.float32))
        pq = jax.nn.softmax(lq.astype(jnp.float32))
        # distributions must stay close (int8 cache error ~0.5%)
        assert float(jnp.abs(pf - pq).max()) < 0.05
        tok = jnp.argmax(lf, -1).astype(jnp.int32)[:, None]
