"""Fault tolerance: checkpoint/restart, bitwise resume, elastic re-shard,
straggler detection, data determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.configs import get_arch
from repro.data.pipeline import DataConfig, get_batch, PrefetchingLoader
from repro.models import get_model
from repro.optim import adamw
from repro.runtime.trainer import TrainConfig, train


def _tiny():
    cfg = get_arch("qwen2_7b").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=64, d_ff=128,
                              vocab=128, n_heads=2, n_kv_heads=2, head_dim=32)
    return cfg


def test_data_determinism_and_rank_slicing():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8, seed=3)
    a = get_batch(cfg, 5)
    b = get_batch(cfg, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    shards = [get_batch(cfg, 5, rank=r, world=4)["tokens"] for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(shards), a["tokens"])
    c = get_batch(cfg, 6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_prefetch_loader_state():
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=4)
    ld = PrefetchingLoader(cfg, start_step=0)
    b0 = next(ld)
    b1 = next(ld)
    assert ld.state == 2
    ld.close()
    # resume from state reproduces the stream
    ld2 = PrefetchingLoader(cfg, start_step=1)
    b1b = next(ld2)
    ld2.close()
    np.testing.assert_array_equal(b1["tokens"], b1b["tokens"])


def test_ckpt_atomic_save_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"a": jnp.arange(5, dtype=jnp.float32),
             "nested": {"b": jnp.ones((2, 3))}}
    for step in (10, 20, 30):
        mgr.save(step, state, {"data_state": step})
    assert mgr.all_steps() == [20, 30]  # keep=2 GC
    restored, meta = mgr.restore(state)
    np.testing.assert_array_equal(restored["a"], state["a"])
    assert meta["step"] == 30


def test_ckpt_elastic_reshard(tmp_path):
    """Save, then restore with explicit shardings on the current devices --
    the elastic-rescale path (logical state is mesh-independent)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mgr.save(1, state)
    mesh = jax.make_mesh((1,), ("model",))
    shardings = {"w": NamedSharding(mesh, P("model", None))}
    restored, _ = mgr.restore(state, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["w"].sharding == shardings["w"]


@pytest.mark.slow
def test_train_restart_bitwise_identical(tmp_path):
    """Kill at step 17, restart, final state == uninterrupted run."""
    cfg = _tiny()
    model = get_model(cfg)
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=1)
    tc = lambda d: TrainConfig(total_steps=24, ckpt_every=8, log_every=100,
                               ckpt_dir=str(d))
    oc = adamw.AdamWConfig(total_steps=24, warmup_steps=4)

    # uninterrupted reference
    ref = train(model, dc, tc(tmp_path / "ref"), oc)

    # interrupted: die at step 17 (after the step-16 checkpoint)
    class Boom(Exception):
        pass

    def killer(step):
        if step == 17 and not os.environ.get("_RESUMED"):
            raise Boom()

    with pytest.raises(Boom):
        train(model, dc, tc(tmp_path / "ft"), oc, failure_hook=killer)
    os.environ["_RESUMED"] = "1"
    try:
        out = train(model, dc, tc(tmp_path / "ft"), oc)
    finally:
        del os.environ["_RESUMED"]

    # bitwise-identical final params
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the loss tail matches the reference trajectory
    np.testing.assert_allclose(ref["losses"][-4:], out["losses"][-4:],
                               rtol=0, atol=0)


def test_straggler_detection():
    from repro.runtime.trainer import StragglerMonitor
    mon = StragglerMonitor(window=10, factor=3.0)
    flagged = [mon.record(i, 0.1) for i in range(8)]
    assert not any(flagged)
    assert mon.record(8, 1.0)  # 10x median -> straggler
    assert mon.flagged == [8]


def test_compressed_psum_error_feedback():
    """int8 EF-compression: accumulated mean error stays bounded and the
    residual carries exactly the quantization error."""
    from repro.parallel.collectives import (dequantize_int8,
                                            quantize_int8)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(256,)).astype(np.float32)
    residual = np.zeros_like(x)
    drift = []
    for _ in range(20):
        xt = x + residual
        q, s = quantize_int8(jnp.asarray(xt))
        deq = np.asarray(dequantize_int8(q, s))
        residual = xt - deq
        drift.append(np.abs(residual).max())
    # error feedback keeps the residual bounded by one quantization step
    assert drift[-1] <= float(np.abs(x).max() / 127.0 * 2)
