"""Static verification layer: independent conflict-freedom certifier,
Program lint pass, and untrusted-fabric result checking.

The certifier re-decides every access pair of a finished scheme via a
separate decision path (bounded lattice enumeration + residue-witness
sets), so a bug in the solver's sumset DP cannot vouch for itself.
Covers: certifier/solver agreement over benchmark problems, concrete
counterexamples from corrupted schemes (auto-rendered as pytest cases),
machine-checked certificate round-trips, the lint diagnostics, store
certificate sidecars + hydrate re-verification, PlanService verify
modes, and a fabric solve that converges past an adversarial worker
injecting forged solutions.
"""

import dataclasses
import json
import os
import queue
import socket
import threading

import numpy as np
import pytest

from repro.analysis import (CertificationError, LintError,
                            certificate_matches_plan, certify_plan,
                            certify_solution, check_certificate,
                            decide_delta, lint_program, make_batch_verifier)
from repro.analysis.certify import ConflictCertificate
from repro.core import (AccessDecl, Counter, Ctrl, MemorySpec, PlanService,
                        Program, Sched, SolveFabric, build_groups, problems,
                        rank_solutions, unroll)
from repro.core.candidates import (evaluate, events_to_wire,
                                   shard_from_indices, space_from_wire)
from repro.core.fabric import read_frame, write_frame
from repro.core.planner import BankingPlanner
from repro.core.polytope import Affine, Iterator, delta_can_hit_window
from repro.core.solver import solve_monolithic
from repro.core.store import DirectoryStore, MemoryStore

# flat, duplication-split, and multidim certification paths
APPS = ["denoise", "sobel", "sgd"]


def _key(s):
    return (s.kind, s.geometry, s.duplicates)


def _problem(app):
    prog = problems.build(app)
    memname = list(prog.memories)[0]
    up = unroll(prog)
    return prog, memname, up


# ---------------------------------------------------------------------------
# The independent pair decision vs the solver's oracle
# ---------------------------------------------------------------------------


def test_decide_delta_matches_oracle_randomized():
    """decide_delta (lattice/residue path) agrees with the solver's
    sumset-DP oracle on randomized deltas mixing bounded, unbounded,
    and undeclared iterators plus uninterpreted syms -- and every
    conflict verdict carries a witness that lands in the window."""
    rng = np.random.default_rng(7)
    for trial in range(400):
        n_terms = int(rng.integers(0, 4))
        terms, iters = [], {}
        for t in range(n_terms):
            name = f"i{t}"
            coeff = int(rng.integers(-5, 6))
            if coeff == 0:
                coeff = 1
            terms.append((name, coeff))
            kind = rng.integers(0, 3)
            if kind == 0:      # bounded
                iters[name] = Iterator(name, int(rng.integers(-3, 4)),
                                       int(rng.integers(1, 4)),
                                       int(rng.integers(1, 7)))
            elif kind == 1:    # unbounded (data-dependent count)
                iters[name] = Iterator(name, int(rng.integers(-3, 4)),
                                       int(rng.integers(1, 4)), None)
            # kind == 2: undeclared -- the oracle treats it as free
        syms = ()
        if rng.integers(0, 3) == 0:
            syms = (("q@site", int(rng.integers(-3, 4)) or 1),)
        delta = Affine(terms=tuple(terms), syms=syms,
                       const=int(rng.integers(-8, 9)))
        N = int(rng.integers(1, 9))
        B = int(rng.choice([1, 1, 2, 3, 4]))
        oracle = bool(delta_can_hit_window(delta, iters, N, B))
        dec = decide_delta(delta, iters, N, B)
        assert dec.conflict == oracle, (trial, delta, iters, N, B)
        if dec.conflict and dec.witness is not None:
            M = N * B
            r = delta.evaluate(dec.witness) % M
            assert r <= B - 1 or r >= M - B + 1, (trial, dec.witness)


def test_decide_delta_witness_set_fallback_agrees():
    """Forcing the witness-set fold (enum_cap too small for the lattice
    product) must not change any verdict."""
    rng = np.random.default_rng(11)
    for trial in range(150):
        iters = {
            "a": Iterator("a", 0, 1, int(rng.integers(2, 7))),
            "b": Iterator("b", int(rng.integers(-2, 3)), 2,
                          int(rng.integers(2, 7))),
        }
        delta = Affine(terms=(("a", int(rng.integers(1, 5))),
                              ("b", -int(rng.integers(1, 5)))),
                       const=int(rng.integers(-4, 5)))
        N, B = int(rng.integers(1, 7)), int(rng.choice([1, 2, 3]))
        full = decide_delta(delta, iters, N, B)
        folded = decide_delta(delta, iters, N, B, enum_cap=2)
        assert full.conflict == folded.conflict, (trial, delta, N, B)


# ---------------------------------------------------------------------------
# Certifier vs solver over the benchmark suite
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("app", APPS)
def test_certifier_agrees_with_solver(app):
    """Every solver-chosen scheme certifies with zero disagreements,
    the emitted certificate re-checks, and it matches its plan."""
    prog, memname, up = _problem(app)
    plan = BankingPlanner().plan(prog, memname, use_cache=False)
    res = certify_plan(plan, up.iterators)
    assert res.ok, f"{app}: {res.reason}"
    assert res.pairs_checked > 0
    ok, why = check_certificate(res.certificate)
    assert ok, f"{app}: {why}"
    assert certificate_matches_plan(res.certificate, plan)
    # serialization round-trip preserves checkability
    wire = json.loads(json.dumps(res.certificate.to_json()))
    ok, why = check_certificate(ConflictCertificate(wire))
    assert ok, why


def test_corrupted_scheme_yields_counterexample(render_counterexample):
    """A deliberately corrupted scheme (forged down to one bank) must
    come back with a concrete two-point counterexample -- which renders
    and passes as a standalone pytest case."""
    prog, memname, up = _problem("sobel")
    plan = BankingPlanner().plan(prog, memname, use_cache=False)
    forged = dataclasses.replace(
        plan.best,
        geometry=dataclasses.replace(plan.best.geometry, N=1, B=1))
    res = certify_solution(forged, plan.groups, up.iterators)
    assert not res.ok and res.counterexample is not None
    cex = res.counterexample
    assert cex.x1 != cex.x2 or cex.a_label != cex.b_label
    assert "bank" in cex.describe() or "window" in cex.describe()
    path = render_counterexample(cex, name="test_sobel_forged_one_bank")
    assert path.exists()


def test_certificate_tampering_detected():
    """check_certificate refuses a certificate whose proofs, edges, or
    clique no longer match a fresh re-decision."""
    prog, memname, up = _problem("denoise")
    plan = BankingPlanner().plan(prog, memname, use_cache=False)
    good = certify_plan(plan, up.iterators).certificate

    # flip one proof's verdict
    doc = json.loads(json.dumps(good.to_json()))
    key = next(iter(doc["proofs"]))
    doc["proofs"][key]["conflict"] = not doc["proofs"][key]["conflict"]
    ok, why = check_certificate(ConflictCertificate(doc))
    assert not ok

    # understate a group's clique
    doc = json.loads(json.dumps(good.to_json()))
    doc["groups"][0]["clique"] = 0
    ok, why = check_certificate(ConflictCertificate(doc))
    assert not ok

    # a tampered geometry no longer matches the plan
    doc = json.loads(json.dumps(good.to_json()))
    doc["geometry"]["N"] = 1
    assert not certificate_matches_plan(ConflictCertificate(doc), plan)


# ---------------------------------------------------------------------------
# Program lint
# ---------------------------------------------------------------------------


def _mk_program(counters, accesses, dims=(64,), ports=2):
    mem = MemorySpec("buf", dims, 32, ports=ports)
    root = Ctrl("main", Sched.INNER, counters=counters, accesses=accesses)
    return Program(root=root, memories={"buf": mem})


def test_lint_flags_out_of_bounds_access():
    prog = _mk_program(
        [Counter("x", 0, 1, 16, par=2)],
        [AccessDecl("buf", (Affine.of(x=1),), label="r0")], dims=(8,))
    report = lint_program(prog, "buf")
    assert not report.ok
    assert any(d.code == "oob-access" for d in report.errors)


def test_lint_flags_degenerate_counters():
    prog = _mk_program(
        [Counter("x", 0, 0, 4, par=2), Counter("y", 0, 1, 0)],
        [AccessDecl("buf", (Affine.of(x=1),))])
    codes = [d.code for d in lint_program(prog, "buf").errors]
    assert codes.count("degenerate-counter") >= 2


def test_lint_flags_sym_collision_across_call_sites():
    inner_a = Ctrl("site_a", Sched.INNER,
                   counters=[Counter("i", 0, 1, 4, par=2)],
                   accesses=[AccessDecl(
                       "buf", (Affine.of(i=1).with_sym("q"),))])
    inner_b = Ctrl("site_b", Sched.INNER,
                   counters=[Counter("j", 0, 1, 4, par=2)],
                   accesses=[AccessDecl(
                       "buf", (Affine.of(j=1).with_sym("q"),))])
    mem = MemorySpec("buf", (64,), 32, ports=2)
    root = Ctrl("main", Sched.SEQUENTIAL, children=[inner_a, inner_b])
    prog = Program(root=root, memories={"buf": mem})
    report = lint_program(prog, "buf")
    assert any(d.code == "sym-collision" for d in report.errors)


def test_lint_flags_port_oversubscription():
    """ports-many identical write addresses per cycle: no geometry can
    separate them -- error; identical reads only warn (duplication)."""
    writes = [AccessDecl("buf", (Affine.of(x=1),), is_write=True,
                         label=f"w{k}") for k in range(3)]
    prog = _mk_program([Counter("x", 0, 1, 8, par=1)], writes, ports=1)
    report = lint_program(prog, "buf")
    assert any(d.code == "port-oversubscription" for d in report.errors)
    reads = [AccessDecl("buf", (Affine.of(x=1),), label=f"r{k}")
             for k in range(3)]
    prog = _mk_program([Counter("x", 0, 1, 8, par=1)], reads, ports=1)
    report = lint_program(prog, "buf")
    assert report.ok
    assert any(d.code == "port-oversubscription" for d in report.warnings)


def test_lint_clean_on_benchmark_programs():
    for app in APPS:
        prog, memname, _ = _problem(app)
        assert lint_program(prog, memname).ok, app


# ---------------------------------------------------------------------------
# Store: certificate sidecars + hydrate re-verification
# ---------------------------------------------------------------------------


def test_memory_store_certificate_round_trip():
    store = MemoryStore()
    assert store.get_certificate("sig", "s") is None
    store.put_certificate("sig", "s", {"verdict": "certified"})
    assert store.get_certificate("sig", "s")["verdict"] == "certified"


def test_directory_store_certificates_and_hydrate_verify(tmp_path):
    prog, memname, up = _problem("denoise")
    store = DirectoryStore(tmp_path)
    planner = BankingPlanner(store=store)
    plan = planner.plan(prog, memname)
    res = certify_plan(plan, up.iterators)
    store.put_certificate(plan.signature, plan.scorer_name,
                          res.certificate.to_json())
    assert store.certificate_path(plan.signature,
                                  plan.scorer_name).exists()

    # an armed fresh store serves the plan only because the cert checks
    armed = DirectoryStore(tmp_path, verify_hydrated=True)
    assert armed.get(plan.signature, plan.scorer_name) is not None

    # tampering with the certificate turns the entry into a miss
    p = armed.certificate_path(plan.signature, plan.scorer_name)
    doc = json.loads(p.read_text())
    doc["geometry"]["N"] = 1
    p.write_text(json.dumps(doc))
    assert DirectoryStore(tmp_path, verify_hydrated=True).get(
        plan.signature, plan.scorer_name) is None

    # no certificate at all: an armed store refuses, a relaxed one serves
    p.unlink()
    assert DirectoryStore(tmp_path, verify_hydrated=True).get(
        plan.signature, plan.scorer_name) is None
    assert DirectoryStore(tmp_path).get(
        plan.signature, plan.scorer_name) is not None

    # delete removes the sidecar with the plan
    store.put_certificate(plan.signature, plan.scorer_name,
                          res.certificate.to_json())
    store.delete(plan.signature, plan.scorer_name)
    assert not store.certificate_path(plan.signature,
                                      plan.scorer_name).exists()


# ---------------------------------------------------------------------------
# PlanService verify modes
# ---------------------------------------------------------------------------


def test_service_verify_store_certifies_and_persists(tmp_path):
    prog, memname, _ = _problem("denoise")
    store = DirectoryStore(tmp_path)
    svc = PlanService(store=store, workers=2, verify="store")
    assert store.verify_hydrated     # armed store refuses uncertified
    try:
        plan = svc.submit(prog, memname).result(timeout=120)
        assert svc.stats.certified == 1 and svc.stats.cert_failures == 0
        cert = store.get_certificate(plan.signature, plan.scorer_name)
        assert cert is not None and cert["verdict"] == "certified"
        ok, why = check_certificate(ConflictCertificate(cert))
        assert ok, why
    finally:
        svc.shutdown()


def test_service_lint_gate_refuses_bad_program():
    prog = _mk_program(
        [Counter("x", 0, 1, 16, par=2)],
        [AccessDecl("buf", (Affine.of(x=1),), label="r0")], dims=(8,))
    svc = PlanService(workers=1, verify="store")
    try:
        with pytest.raises(LintError) as exc:
            svc.submit(prog, "buf")
        assert not exc.value.report.ok
        assert svc.stats.lint_errors == 1
        # per-submit opt-out still solves the (conflict-clean) program
        svc.submit(prog, "buf", verify="off").result(timeout=60)
    finally:
        svc.shutdown()


def test_service_rejects_unknown_verify_mode():
    with pytest.raises(ValueError, match="unknown verify mode"):
        PlanService(verify="sometimes")
    svc = PlanService(workers=1)
    try:
        prog, memname, _ = _problem("denoise")
        with pytest.raises(ValueError, match="unknown verify mode"):
            svc.submit(prog, memname, verify="sometimes")
    finally:
        svc.shutdown()


def test_service_cert_failure_aborts_caching(monkeypatch, tmp_path):
    """A certification failure surfaces through the ticket AND keeps the
    refused plan out of every cache layer."""
    from repro.analysis import certify as certify_mod
    from repro.analysis.certify import CertifyResult

    def refuse(plan, iters, **kw):
        return CertifyResult(False, None, None, 1, 0.0,
                             reason="forced refusal")

    monkeypatch.setattr(certify_mod, "certify_plan", refuse)
    prog, memname, _ = _problem("denoise")
    store = DirectoryStore(tmp_path)
    svc = PlanService(store=store, workers=1, verify="store")
    try:
        ticket = svc.submit(prog, memname)
        with pytest.raises(CertificationError, match="forced refusal"):
            ticket.result(timeout=120)
        assert svc.stats.cert_failures == 1
        assert store.get(ticket.signature, ticket.scorer_name) is None
        assert svc.planner.lookup(ticket._prep) is None
    finally:
        svc.shutdown()


# ---------------------------------------------------------------------------
# Untrusted fabric: adversarial worker injecting forged solutions
# ---------------------------------------------------------------------------


def _run_malicious_worker(address):
    """Speaks the real worker wire protocol but corrupts every solution
    it streams back: geometry forged to a single bank and the score
    forced to -1e9, so an unchecked reducer would crown a colliding
    scheme the winner."""
    host, _, port = address.rpartition(":")
    sock = socket.create_connection((host or "127.0.0.1", int(port)))
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    send_lock = threading.Lock()
    write_frame(sock, {"t": "join", "pid": os.getpid(), "host": "evil"},
                send_lock)
    spaces, leases = {}, queue.Queue()

    def reader():
        try:
            while True:
                msg = read_frame(sock)
                t = msg.get("t")
                if t == "space":
                    spaces[msg["solve_id"]] = space_from_wire(msg["payload"])
                elif t == "lease":
                    leases.put(msg)
                elif t == "shutdown":
                    break
        except Exception:
            pass
        finally:
            leases.put(None)

    threading.Thread(target=reader, daemon=True).start()
    while True:
        msg = leases.get()
        if msg is None:
            break
        sid, lid = msg["solve_id"], msg["lease_id"]
        space = spaces.get(sid)
        try:
            if space is None:
                write_frame(sock, {"t": "error", "lease_id": lid,
                                   "error": "no space"}, send_lock)
                continue
            shard = shard_from_indices(space, msg["indices"])
            batch = []
            for ev in evaluate(shard):
                forged = []
                for sol in ev.solutions:
                    if sol.kind == "flat":
                        g = dataclasses.replace(sol.geometry, N=1, B=1)
                        forged.append(dataclasses.replace(
                            sol, geometry=g, score=-1e9, note="forged"))
                    else:
                        forged.append(dataclasses.replace(
                            sol, score=-1e9, note="forged"))
                batch.append(dataclasses.replace(ev, solutions=forged))
            write_frame(sock, {"t": "results", "lease_id": lid,
                               "payload": events_to_wire(batch)}, send_lock)
            write_frame(sock, {"t": "done", "lease_id": lid,
                               "evaluated": len(batch)}, send_lock)
        except OSError:
            break
    try:
        sock.close()
    except OSError:
        pass


def test_adversarial_fabric_worker_is_rejected_and_solve_converges():
    """ISSUE acceptance: a fabric solve with an adversarial worker
    injecting bogus solutions still converges to the exact monolithic
    answer, with ServiceStats.cert_rejected > 0 -- forged batches are
    refused by the certifier gate, their units requeued away from the
    sender and evaluated locally."""
    prog, memname, up = _problem("sobel")
    mono = _key(rank_solutions(list(solve_monolithic(
        prog.memories[memname], build_groups(up, memname),
        up.iterators)))[0])

    fabric = SolveFabric(chunk=32)
    t = threading.Thread(target=_run_malicious_worker,
                         args=(fabric.address,), daemon=True)
    t.start()
    assert fabric.wait_for_workers(1, timeout=30)
    svc = PlanService(workers=2, executor="fabric", fabric=fabric,
                      verify="all")
    try:
        plan = svc.submit(prog, memname).result(timeout=240)
        assert _key(plan.best) == mono, \
            "forged solutions corrupted the solve"
        assert svc.stats.cert_rejected > 0
        assert fabric.stats.cert_rejected > 0
        assert fabric.stats.local_evaluated > 0   # orphans ran locally
        assert svc.stats.certified == 1           # final plan certified
        assert plan.best.note != "forged"
    finally:
        svc.shutdown()
        fabric.shutdown()


def test_batch_verifier_accepts_honest_events():
    """make_batch_verifier passes genuinely evaluated batches through
    untouched (returns None) and refuses forged ones."""
    prog, memname, up = _problem("denoise")
    from repro.core import CandidateSpace
    from repro.core.solver import SolverOptions
    space = CandidateSpace(prog.memories[memname],
                           build_groups(up, memname), up.iterators,
                           SolverOptions())
    verify = make_batch_verifier(space)
    honest = list(evaluate(shard_from_indices(
        space, list(range(min(16, len(space)))))))
    assert verify(honest) is None
    forged = []
    for ev in honest:
        if ev.solutions:
            sol = ev.solutions[0]
            if sol.kind != "flat":
                continue
            g = dataclasses.replace(sol.geometry, N=1, B=1)
            forged.append(dataclasses.replace(
                ev, solutions=[dataclasses.replace(sol, geometry=g)]))
    assert forged, "expected at least one flat solution to forge"
    res = verify(forged)
    assert res is not None and not res.ok
