"""CompiledBankingPlan: the executable artifact between planner and
consumers -- resolution correctness, layout round-trips, compile cache,
serialization, and the downstream bridges (pager, PartitionSpec)."""


import numpy as np
import pytest

from repro.core import (AccessDecl, BankingPlanner, CompiledBankingPlan,
                        Counter, Ctrl, FlatGeometry, MemorySpec,
                        MultiDimGeometry, Program, Sched, compile_geometry,
                        compile_plan)
from repro.core.geometry import propose_P
from repro.core.polytope import Affine


def _reader_program(dims=(256,), par=8, count=32, name="table"):
    mem = MemorySpec(name, dims=dims, word_bits=32, ports=1)
    return Program(
        root=Ctrl("reader", Sched.INNER,
                  counters=[Counter("i", 0, 1, count, par=par)],
                  accesses=[AccessDecl(name, (Affine.of(i=1),))]),
        memories={name: mem},
    )


def _coords(addr, dims):
    out, rem = [], addr
    for d in reversed(dims):
        out.append(rem % d)
        rem //= d
    return tuple(reversed(out))


# ---------------------------------------------------------------------------
# Resolution circuit == brute-force Eq. 1-2 (deterministic sweep; the
# hypothesis generalization lives in test_artifact_properties.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dims,N,B,unit_dim", [
    ((24,), 3, 1, 0),
    ((60,), 8, 1, 0),          # pad = 4
    ((32,), 4, 2, 0),
    ((21,), 5, 3, 0),
    ((8, 12), 4, 1, 1),
    ((8, 12), 3, 2, 0),
    ((6, 10), 4, 1, None),     # diagonal alpha = (1, 1)
])
def test_flat_resolution_matches_bruteforce(dims, N, B, unit_dim):
    n = len(dims)
    alpha = ((1,) * n if unit_dim is None else
             tuple(1 if i == unit_dim else 0 for i in range(n)))
    mem = MemorySpec("m", dims=dims, word_bits=16, ports=1)
    geo = FlatGeometry(N=N, B=B, alpha=alpha, P=propose_P(mem, N, B, alpha)[0])
    art = compile_geometry(mem, geo, backend="numpy")
    A = art.layout.logical_size
    ba, bo = art.resolve(np.arange(A, dtype=np.int64))
    ba = np.broadcast_to(np.asarray(ba), (A,))
    bo = np.broadcast_to(np.asarray(bo), (A,))
    for a in range(A):
        x = _coords(a, dims)
        assert ba[a] == geo.bank_address(x), (a, x)
        assert bo[a] == geo.bank_offset(x, dims), (a, x)
        assert 0 <= bo[a] < art.bank_volume


@pytest.mark.parametrize("dims,Ns,Bs", [
    ((8, 12), (2, 3), (1, 1)),
    ((8, 12), (4, 1), (2, 1)),
    ((6, 6), (3, 2), (1, 1)),
])
def test_multidim_resolution_matches_bruteforce(dims, Ns, Bs):
    mem = MemorySpec("m", dims=dims, word_bits=16, ports=1)
    geo = MultiDimGeometry(Ns=Ns, Bs=Bs, alphas=(1,) * len(dims))
    art = compile_geometry(mem, geo, backend="numpy")
    A = art.layout.logical_size
    ba, bo = art.resolve(np.arange(A, dtype=np.int64))
    for a in range(A):
        x = _coords(a, dims)
        bat = geo.bank_address(x)
        folded = 0
        for b, n in zip(bat, Ns):
            folded = folded * n + b
        assert ba[a] == folded, (a, x)
        assert bo[a] == geo.bank_offset(x, dims), (a, x)


def test_unpack_inverts_pack_with_padding():
    import jax.numpy as jnp

    mem = MemorySpec("m", dims=(60,), word_bits=32, ports=1)
    geo = FlatGeometry(N=8, B=1, alpha=(1,), P=propose_P(mem, 8, 1, (1,))[0])
    art = compile_geometry(mem, geo)
    assert art.layout.pad == (4,)                      # 60 -> 64
    assert art.n_banks * art.bank_volume > 60          # padded slots exist
    x = jnp.asarray(np.random.default_rng(0).normal(size=(60, 3)),
                    jnp.float32)
    assert (np.asarray(art.unpack(art.pack(x))) == np.asarray(x)).all()


def test_batched_gather_matches_per_rowset_gathers():
    """A stacked (T, R) index matrix -- one kernel launch -- returns
    exactly what T separate per-row-set gathers return, on both
    backends."""
    import jax.numpy as jnp

    plan = BankingPlanner().plan(_reader_program(), "table")
    rng = np.random.default_rng(0)
    flat = jnp.asarray(rng.normal(size=(256, 8)), jnp.float32)
    idx = rng.integers(0, 256, size=(5, 7)).astype(np.int32)
    for backend in ("jax", "numpy"):
        art = plan.compile(backend=backend)
        table = art.pack(flat) if backend == "jax" else \
            np.asarray(plan.compile(backend="jax").pack(flat))
        got = np.asarray(art.gather(table, idx))
        assert got.shape == (5, 7, 8)
        for t in range(idx.shape[0]):
            row = np.asarray(art.gather(table, idx[t]))
            np.testing.assert_array_equal(got[t], row)


def test_scatter_writes_rows_through_the_resolution_circuit():
    """scatter(table, rows, values) is the write-path analogue of the
    batched gather: rows land exactly where pack's reference layout
    places them, untouched slots carry over, and duplicates resolve
    last-write-wins -- on both backends."""
    import jax.numpy as jnp

    plan = BankingPlanner().plan(_reader_program(), "table")
    rng = np.random.default_rng(1)
    flat = rng.normal(size=(256, 8)).astype(np.float32)
    rows = np.asarray([3, 77, 3, 200, 41], np.int64)   # 3 duplicated
    vals = rng.normal(size=(5, 8)).astype(np.float32)
    want = flat.copy()
    for r, v in zip(rows, vals):                       # last write wins
        want[r] = v
    for backend in ("jax", "numpy"):
        art = plan.compile(backend=backend)
        table = (art.pack(jnp.asarray(flat)) if backend == "jax" else
                 np.asarray(plan.compile(backend="jax").pack(flat)))
        out = art.scatter(table, rows, vals)
        np.testing.assert_array_equal(np.asarray(art.unpack(out)), want,
                                      err_msg=backend)


def test_scatter_single_column_element_writes():
    """scatter(..., col=...) writes one element per row -- the serving
    runtime's batched per-slot token-record write -- without touching
    the rest of the row."""
    import jax.numpy as jnp

    plan = BankingPlanner().plan(_reader_program(), "table")
    flat = np.zeros((256, 4), np.int32)
    rows = np.asarray([0, 17, 99, 17], np.int64)
    cols = np.asarray([1, 3, 0, 2], np.int64)
    vals = np.asarray([11, 22, 33, 44], np.int32)
    want = flat.copy()
    for r, c, v in zip(rows, cols, vals):
        want[r, c] = v
    for backend in ("jax", "numpy"):
        art = plan.compile(backend=backend)
        table = (art.pack(jnp.asarray(flat)) if backend == "jax" else
                 np.asarray(plan.compile(backend="jax").pack(flat)))
        out = art.scatter(table, rows, vals, col=cols)
        np.testing.assert_array_equal(np.asarray(art.unpack(out)), want,
                                      err_msg=backend)


def test_ops_scatter_banked_gather_round_trip():
    """ops.scatter_banked then ops.gather_banked round-trips rows
    through the same compiled artifact (kernel-to-kernel agreement)."""
    import jax.numpy as jnp

    from repro.kernels import ops

    plan = BankingPlanner().plan(_reader_program(), "table")
    art = plan.compile()
    rng = np.random.default_rng(2)
    table = art.pack(jnp.asarray(rng.normal(size=(256, 8)), jnp.float32))
    rows = jnp.asarray([5, 120, 250], jnp.int32)
    vals = jnp.asarray(rng.normal(size=(3, 8)), jnp.float32)
    table = ops.scatter_banked(table, rows, vals, art)
    got = ops.gather_banked(table, rows, art)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(vals))


def test_trivial_fallback_artifact_is_single_bank_rowmajor():
    from repro.core import compile_trivial

    mem = MemorySpec("m", dims=(60,), word_bits=32, ports=1)
    art = compile_trivial(mem, backend="numpy")
    assert art.n_banks == 1 and art.bank_volume == 60
    A = art.layout.logical_size
    ba, bo = art.resolve(np.arange(A, dtype=np.int64))
    assert (np.broadcast_to(np.asarray(ba), (A,)) == 0).all()
    np.testing.assert_array_equal(np.broadcast_to(np.asarray(bo), (A,)),
                                  np.arange(A))
    # 2-D memories flatten row-major
    mem2 = MemorySpec("m", dims=(6, 10), word_bits=32, ports=1)
    art2 = compile_trivial(mem2, backend="numpy")
    assert art2.n_banks == 1 and art2.layout.pad == (0, 0)
    _, bo2 = art2.resolve(np.arange(60, dtype=np.int64))
    np.testing.assert_array_equal(np.broadcast_to(np.asarray(bo2), (60,)),
                                  np.arange(60))


def test_jax_and_numpy_backends_agree():
    plan = BankingPlanner().plan(_reader_program(), "table")
    aj = plan.compile(backend="jax")
    an = plan.compile(backend="numpy")
    addr = np.arange(256, dtype=np.int64)
    np.testing.assert_array_equal(np.asarray(aj.resolve(addr)[0]),
                                  an.resolve(addr)[0])
    np.testing.assert_array_equal(np.asarray(aj.resolve(addr)[1]),
                                  an.resolve(addr)[1])


# ---------------------------------------------------------------------------
# Compile cache + durability
# ---------------------------------------------------------------------------


@pytest.mark.compile
def test_artifact_roundtrip_compile_save_load_gather(tmp_path):
    """compile -> save -> load -> gather: the serialization path CI gates."""
    import jax.numpy as jnp
    from repro.kernels import ref

    planner = BankingPlanner(cache_dir=tmp_path)
    plan = planner.plan(_reader_program(), "table")
    art = plan.compile()
    files = list(tmp_path.glob("*.compiled.json"))   # persisted next to plan
    assert len(files) == 1
    loaded = CompiledBankingPlan.load(files[0])
    assert loaded.signature == art.signature == plan.signature
    assert loaded.layout == art.layout
    assert loaded.kind == art.kind and loaded.geometry == art.geometry
    flat = jnp.asarray(np.random.default_rng(0).normal(size=(256, 4)),
                       jnp.float32)
    idx = jnp.asarray([3, 77, 130, 255], jnp.int32)
    got = loaded.gather(loaded.pack(flat), idx)
    assert (np.asarray(got) ==
            np.asarray(ref.banked_gather_reference(flat, idx))).all()


@pytest.mark.compile
def test_compile_cache_and_warm_start_skip_relowering(tmp_path):
    planner = BankingPlanner(cache_dir=tmp_path)
    plan = planner.plan(_reader_program(), "table")
    a1 = planner.compile(plan)
    a2 = plan.compile()                    # plan routes through its planner
    assert a2 is a1
    assert planner.stats.compiles == 1 and planner.stats.compile_hits == 1
    # a fresh planner warm-starts plans AND artifacts: no solve, no lower
    warm = BankingPlanner(cache_dir=tmp_path)
    assert warm.warm_start(tmp_path) == 2  # one plan + one artifact
    p = warm.plan(_reader_program(), "table")
    assert p.status == "cached"
    warm.compile(p)
    assert warm.stats.compiles == 0 and warm.stats.compile_hits == 1
    # and even without warm_start(), compile() consults the disk cache
    cold = BankingPlanner(cache_dir=tmp_path)
    cold.compile(cold.plan(_reader_program(), "table"))
    assert cold.stats.compiles == 0 and cold.stats.compile_disk_hits == 1


def test_detached_plan_compiles_standalone():
    plan = BankingPlanner().plan(_reader_program(), "table")
    art = compile_plan(plan)
    assert art.signature == plan.signature
    assert art.n_banks == plan.best.num_banks


def test_plan_without_solution_refuses_to_compile():
    from repro.core.planner import BankingPlan
    empty = BankingPlan(memory="m", signature="", best=None, status="timeout")
    with pytest.raises(ValueError, match="no solution"):
        empty.compile()


# ---------------------------------------------------------------------------
# Downstream bridges: PartitionSpec + KV page pool
# ---------------------------------------------------------------------------


def test_to_partition_spec_places_banked_dims():
    from jax.sharding import PartitionSpec as P

    mem = MemorySpec("m", dims=(64,), ports=1)
    geo = FlatGeometry(N=8, B=1, alpha=(1,), P=propose_P(mem, 8, 1, (1,))[0])
    assert compile_geometry(mem, geo).to_partition_spec("model") == P("model")

    mem2 = MemorySpec("m", dims=(8, 12), ports=1)
    md = MultiDimGeometry(Ns=(2, 3), Bs=(1, 1), alphas=(1, 1))
    assert compile_geometry(mem2, md).to_partition_spec(("x", "y")) == \
        P("x", "y")
    md1 = MultiDimGeometry(Ns=(1, 3), Bs=(1, 1), alphas=(1, 1))
    assert compile_geometry(mem2, md1).to_partition_spec("y") == P(None, "y")

    diag = FlatGeometry(N=4, B=1, alpha=(1, 1),
                        P=propose_P(mem2, 4, 1, (1, 1))[0])
    with pytest.raises(ValueError, match="diagonal"):
        compile_geometry(mem2, diag).to_partition_spec("model")


def test_kv_page_pool_reads_layout_off_artifact():
    from repro.runtime.server import KVPagePool, page_solution

    art = page_solution(None, max_len=64, page=16, readers=4)
    pool = KVPagePool(art, slots=4)
    assert pool.page_size == art.layout.bank_volume
    assert pool.pages_per_slot == art.layout.n_banks
    # each slot's pages cover the (padded) per-sequence pool
    assert pool.page_size * pool.pages_per_slot >= 64
    assert pool.total_pages == 4 * art.layout.n_banks
    assert pool.try_alloc(0, 17)
    assert pool.used_pages == pool.pages_for(17)
    assert not pool.try_alloc(0, 17)       # slot already owned
    # a request that can never fit one slot is rejected, not queued forever
    assert not pool.fits(pool.pages_per_slot * pool.page_size + 1)
    assert not pool.try_alloc(1, pool.pages_per_slot * pool.page_size + 1)
    pool.release(0)
    assert pool.used_pages == 0


def test_lane_artifact_bridge():
    from repro.parallel import sharding as shd

    art = shd.lane_artifact(64, 16)
    assert art is not None and art.n_banks % 16 == 0
    assert art.max_fan_out == 1
    assert art.to_partition_spec("model")[0] == "model"
    assert shd.lane_artifact(8, 16) is None
