"""Property tests: the conflict-window oracle ``delta_can_hit_window``
(the solver's sumset DP) and the certifier's independent
``decide_delta`` both agree with *brute-force enumeration* of reachable
residues over randomized affine access pairs -- bounded, unbounded, and
undeclared iterators, plus uninterpreted ``Sym`` terms that cancel (or
fail to cancel) in deltas."""

import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import assume, given, settings, strategies as st

from repro.analysis import decide_delta
from repro.core.polytope import Affine, Iterator, delta_can_hit_window


def brute_force_conflict(delta, iters, N, B):
    """Ground truth by exhaustive residue enumeration: every generator's
    value set is walked outright (period of any term divides M, so M
    steps always suffice), no subgroup/sumset shortcuts."""
    M = N * B
    if M <= 1:
        return True
    residues = {delta.const % M}
    for name, coeff in delta.terms:
        it = iters.get(name)
        if it is None:                       # undeclared: any integer
            vals = range(M)
        elif it.count is None:               # unbounded counter
            vals = [it.start + it.step * t for t in range(M)]
        else:
            vals = [it.start + it.step * t for t in range(it.count)]
        residues = {(r + coeff * v) % M for r in residues for v in vals}
    for _, coeff in delta.syms:              # uninterpreted: any integer
        residues = {(r + coeff * v) % M
                    for r in residues for v in range(M)}
    if B == 1:
        return 0 in residues
    return any(r <= B - 1 or r >= M - B + 1 for r in residues)


_coeff = st.integers(-5, 5).filter(lambda c: c != 0)


@st.composite
def delta_cases(draw):
    N = draw(st.integers(1, 8))
    B = draw(st.sampled_from([1, 2, 3, 4]))
    assume(N * B <= 16)
    terms, iters = [], {}
    for t in range(draw(st.integers(0, 3))):
        name = f"i{t}"
        terms.append((name, draw(_coeff)))
        kind = draw(st.sampled_from(["bounded", "unbounded", "missing"]))
        if kind == "bounded":
            iters[name] = Iterator(name, draw(st.integers(-3, 3)),
                                   draw(st.integers(1, 3)),
                                   draw(st.integers(1, 6)))
        elif kind == "unbounded":
            iters[name] = Iterator(name, draw(st.integers(-3, 3)),
                                   draw(st.integers(1, 3)), None)
    syms = ()
    if draw(st.booleans()):
        syms = (("f(i)@site", draw(_coeff)),)
    delta = Affine(terms=tuple(terms), syms=syms,
                   const=draw(st.integers(-8, 8)))
    return delta, iters, N, B


@settings(max_examples=40, deadline=None)
@given(delta_cases())
def test_oracle_matches_brute_force(case):
    delta, iters, N, B = case
    want = brute_force_conflict(delta, iters, N, B)
    assert bool(delta_can_hit_window(delta, iters, N, B)) == want


@settings(max_examples=40, deadline=None)
@given(delta_cases())
def test_certifier_decision_matches_brute_force(case):
    """The certifier's independent lattice/residue path reaches the same
    verdict as exhaustive enumeration -- so solver and certifier can
    only agree on the truth, not on a shared bug."""
    delta, iters, N, B = case
    want = brute_force_conflict(delta, iters, N, B)
    dec = decide_delta(delta, iters, N, B)
    assert dec.conflict == want
    if dec.conflict and dec.witness is not None:
        M = N * B
        r = delta.evaluate(dec.witness) % M
        assert M <= 1 or r <= B - 1 or r >= M - B + 1


@st.composite
def access_pairs(draw):
    """Two affine accesses over shared iterators; the pair shares a Sym
    whose coefficients either match (cancels in the delta) or differ
    (a residual uninterpreted term survives)."""
    iters = {}
    for t in range(draw(st.integers(1, 2))):
        name = f"i{t}"
        count = draw(st.one_of(st.none(), st.integers(1, 6)))
        iters[name] = Iterator(name, draw(st.integers(-2, 2)),
                               draw(st.integers(1, 3)), count)

    def expr():
        terms = tuple((n, draw(st.integers(-4, 4)))
                      for n in iters if draw(st.booleans()))
        return Affine(terms=tuple((n, c) for n, c in terms if c),
                      const=draw(st.integers(-5, 5)))

    ca = draw(_coeff)
    cancels = draw(st.booleans())
    cb = ca if cancels else draw(_coeff.filter(lambda c: c != ca))
    a = expr().with_sym("Q(x)@0", ca)
    b = expr().with_sym("Q(x)@0", cb)
    N = draw(st.integers(1, 6))
    B = draw(st.sampled_from([1, 2, 3]))
    assume(N * B <= 12)
    return a, b, cancels, iters, N, B


@settings(max_examples=40, deadline=None)
@given(access_pairs())
def test_access_pair_deltas_cancel_syms_and_match_brute_force(pair):
    a, b, cancels, iters, N, B = pair
    delta = a - b
    # same key, same coefficient: the unknown value cancels exactly
    assert (delta.syms == ()) == cancels
    want = brute_force_conflict(delta, iters, N, B)
    assert bool(delta_can_hit_window(delta, iters, N, B)) == want
    assert decide_delta(delta, iters, N, B).conflict == want
