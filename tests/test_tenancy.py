"""Multi-tenant planning plane: QoS classes, admission control, fair
share (repro.runtime.tenancy + PlanService integration).

Covers the FairShareQueue discipline (deterministic FIFO tie-break
within a band -- the regression the bare heap never guaranteed --
weighted stride interleave, strict band ordering), the
AdmissionController quota cycle (acquire -> defer -> shed -> release),
the service-level story (deferral is honest and the fallback still
executes; shed submits fail with a concrete AdmissionError, never a
silent drop; a saturating batch tenant cannot starve the interactive
band; QoS shard budgets cap fan-out), and the acceptance property that
``stats.for_tenant`` slices reconcile EXACTLY with the global counters
-- including under N threads submitting across 3 tenants on one shared
DirectoryStore.
"""

import itertools
import threading
import time

import jax.numpy  # noqa: F401  (fallback pack/gather import jax lazily;
# importing up front -- single-threaded, like the other suites -- keeps
# the first import away from live service worker threads)
import numpy as np
import pytest

from repro.core import (AccessDecl, AdmissionError, BankingPlanner,
                        Counter, Ctrl, MemorySpec, PlanService, Program,
                        QoSClass, Sched, TenantRegistry)
from repro.core.polytope import Affine
from repro.core.store import DirectoryStore
from repro.runtime.tenancy import (AdmissionController, FairShareQueue,
                                   QOS_CLASSES, resolve_qos)


_UID = itertools.count()


def _program(tag, i):
    """A unique banking problem per CALL: plan identity is structural
    (the memory name is excluded from the signature), so uniqueness
    comes from distinct memory dims.  Reuse the returned Program to get
    an intentional dedup / cache hit."""
    name = f"{tag}{i}"
    mem = MemorySpec(name, dims=(256 + 8 * next(_UID),), word_bits=32,
                     ports=1)
    return Program(
        root=Ctrl("reader", Sched.INNER,
                  counters=[Counter("i", 0, 1, 32, par=8)],
                  accesses=[AccessDecl(name, (Affine.of(i=1),))]),
        memories={name: mem},
    ), name


@pytest.fixture
def solve_gate(monkeypatch):
    """Blocks the FIRST cold solve until .set(); records memory names in
    claim order (the universal chokepoint every cold solve enters)."""
    gate = threading.Event()
    order = []
    real = BankingPlanner.build_space

    def gated(self, prep):
        order.append(prep.mem.name)
        if len(order) == 1:
            gate.wait(30)
        return real(self, prep)

    monkeypatch.setattr(BankingPlanner, "build_space", gated)
    gate.order = order
    yield gate
    gate.set()


@pytest.fixture
def slow_solves(monkeypatch):
    """Every cold solve takes >= 50 ms: quota windows become
    deterministic (submits are microseconds, slots release only when a
    solve really finishes)."""
    real = BankingPlanner.build_space

    def slowed(self, prep):
        time.sleep(0.05)
        return real(self, prep)

    monkeypatch.setattr(BankingPlanner, "build_space", slowed)


class _T:
    def __init__(self, tenant):
        self.tenant = tenant


# ---------------------------------------------------------------------------
# FairShareQueue discipline
# ---------------------------------------------------------------------------


def test_fifo_tie_break_within_band():
    """Regression (satellite): equal-priority entries of one tenant MUST
    drain in submit order -- the seq tie-break, not arbitrary heap
    order -- and a lower band always preempts a higher one."""
    q = FairShareQueue()
    t = _T("default")
    # interleave two bands; within each band, seq is the submit order
    q.put((1, 0, "b0", t))
    q.put((0, 1, "a0", t))
    q.put((1, 2, "b1", t))
    q.put((0, 3, "a1", t))
    q.put((0, 4, "a2", t))
    assert [q.get()[2] for _ in range(5)] == ["a0", "a1", "a2", "b0", "b1"]
    assert q.qsize() == 0


def test_weighted_stride_interleave_is_deterministic():
    """Within one band, a weight-3 tenant wins ~3 pops per weight-1 pop,
    with pass ties broken by head seq -- the exact drain order is
    reproducible."""
    reg = TenantRegistry()
    reg.register("heavy", QoSClass("heavy", weight=3.0))
    reg.register("light", QoSClass("light", weight=1.0))
    q = FairShareQueue(reg)
    th, tl = _T("heavy"), _T("light")
    seq = 0
    for i in range(6):
        q.put((0, seq, f"h{i}", th))
        seq += 1
    for i in range(2):
        q.put((0, seq, f"l{i}", tl))
        seq += 1
    pops = [q.get()[2] for _ in range(8)]
    assert pops == ["h0", "l0", "h1", "h2", "h3", "l1", "h4", "h5"]


def test_bands_are_strict_across_tenants():
    """An interactive-band entry drains before a batch-band one no
    matter the weights or push order."""
    reg = TenantRegistry()
    reg.register("vip", QOS_CLASSES["interactive"])
    reg.register("bulk", QOS_CLASSES["batch"])
    q = FairShareQueue(reg)
    bulk, vip = _T("bulk"), _T("vip")
    for i in range(3):           # bulk pushed FIRST, at its band 10
        q.put((10, i, f"bulk{i}", bulk))
    for i in range(2):
        q.put((0, 3 + i, f"vip{i}", vip))
    assert [q.get()[2] for _ in range(5)] == \
        ["vip0", "vip1", "bulk0", "bulk1", "bulk2"]


def test_idle_tenant_reactivates_at_the_pass_floor():
    """A long-idle tenant must not monopolize the queue on return: its
    pass re-enters at the active minimum, not at its stale zero."""
    reg = TenantRegistry()
    reg.register("a", QoSClass("a", weight=1.0))
    reg.register("b", QoSClass("b", weight=1.0))
    q = FairShareQueue(reg)
    ta, tb = _T("a"), _T("b")
    for i in range(4):
        q.put((0, i, f"a{i}", ta))
    assert [q.get()[2] for _ in range(3)] == ["a0", "a1", "a2"]
    q.put((0, 10, "b0", tb))     # b arrives late, pass floor = a's pass
    q.put((0, 11, "b1", tb))
    # equal passes now: FIFO on head seq alternates fairly, no b-burst
    assert q.get()[2] == "a3"
    assert [q.get()[2] for _ in range(2)] == ["b0", "b1"]


# ---------------------------------------------------------------------------
# AdmissionController quota cycle
# ---------------------------------------------------------------------------


def test_admission_quota_cycle():
    reg = TenantRegistry()
    reg.register("t", QoSClass("t", max_inflight=2, max_deferred=2))
    ac = AdmissionController(reg)
    assert ac.try_acquire("t") and ac.try_acquire("t")
    assert not ac.try_acquire("t")           # at max_inflight
    assert ac.defer("t", "a") and ac.defer("t", "b")
    assert not ac.defer("t", "c")            # backlog full: caller sheds
    assert ac.pending() == 2
    assert ac.release("t") == ["a"]          # oldest promoted, slot held
    assert ac.inflight("t") == 2 and ac.pending_for("t") == 1
    assert ac.release("t") == ["b"]
    assert ac.release("t") == [] and ac.pending() == 0


def test_default_tenant_is_unbounded():
    ac = AdmissionController(TenantRegistry())
    assert all(ac.try_acquire("default") for _ in range(100))
    assert resolve_qos("default").max_inflight is None
    with pytest.raises(ValueError, match="unknown QoS class"):
        resolve_qos("platinum")


# ---------------------------------------------------------------------------
# Service integration: bands, FIFO, deferral, shedding
# ---------------------------------------------------------------------------


def test_service_fifo_within_band_regression(solve_gate):
    """Equal-priority same-tenant submits are claimed in submit order."""
    svc = PlanService(workers=1)
    svc.submit(*_program("blk", 0))          # occupies the only worker
    while not solve_gate.order:
        time.sleep(0.001)
    tickets = [svc.submit(*_program("m", i)) for i in range(4)]
    solve_gate.set()
    for t in tickets:
        t.result(timeout=60)
    claimed = [n for n in solve_gate.order if n.startswith("m")]
    assert claimed == [f"m{i}" for i in range(4)]


def test_interactive_band_preempts_saturating_batch(solve_gate):
    """The starvation scenario: a batch tenant floods the queue first,
    yet every interactive solve is claimed before any batch solve."""
    reg = TenantRegistry()
    reg.register("fast", "interactive")
    reg.register("bulk", "batch")
    svc = PlanService(workers=1, tenants=reg)
    svc.submit(*_program("blk", 0))          # gate-blocked: queue builds
    while not solve_gate.order:
        time.sleep(0.001)
    bulk = [svc.submit(*_program("s", i), tenant="bulk") for i in range(3)]
    fast = [svc.submit(*_program("f", i), tenant="fast") for i in range(2)]
    solve_gate.set()
    for t in fast + bulk:
        t.result(timeout=60)
    order = solve_gate.order[1:]
    f_pos = [i for i, n in enumerate(order) if n.startswith("f")]
    s_pos = [i for i, n in enumerate(order) if n.startswith("s")]
    assert max(f_pos) < min(s_pos), order
    # the bands came from the QoS classes, not the callers
    assert all(t.priority == 0 for t in fast)
    assert all(t.priority == 10 for t in bulk)


def test_over_quota_submits_defer_honestly_and_still_serve(solve_gate):
    reg = TenantRegistry()
    reg.register("lim", QoSClass("lim", max_inflight=2))
    svc = PlanService(workers=1, tenants=reg)
    pairs = [_program("d", i) for i in range(5)]
    tickets = [svc.submit(p, m, tenant="lim") for p, m in pairs]
    deferred = [t for t in tickets if t.deferred]
    assert len(deferred) == 3 and svc.stats.deferred == 3
    t = deferred[0]
    assert t.status == "deferred" and not t.done()
    # deferral is honest, not a denial: the fallback executes NOW
    prog, mem = pairs[tickets.index(t)]
    n = prog.memories[mem].dims[0]
    fb = t.fallback(backend="numpy")
    flat = np.arange(n * 2, dtype=np.float32).reshape(n, 2)
    got = fb.gather(fb.pack(flat), np.asarray([0, 3, n - 1]))
    np.testing.assert_array_equal(got, flat[[0, 3, n - 1]])
    solve_gate.set()
    for t in tickets:            # released solves run to completion
        assert t.result(timeout=60).status == "solved"
        assert not t.deferred
    s = svc.stats.for_tenant("lim")
    assert s.solved == 5 and s.deferred == 3 and s.queued == 5
    assert svc.stats.shed == 0


def test_full_backlog_sheds_with_concrete_error(solve_gate):
    reg = TenantRegistry()
    reg.register("tiny", QoSClass("tiny", max_inflight=1, max_deferred=1))
    svc = PlanService(workers=1, tenants=reg)
    t1 = svc.submit(*_program("x", 0), tenant="tiny")
    t2 = svc.submit(*_program("x", 1), tenant="tiny")
    t3 = svc.submit(*_program("x", 2), tenant="tiny")
    assert not t1.deferred and t2.deferred
    # never a silent drop: the shed ticket is done, loud, and specific
    assert t3.status == "shed" and t3.done()
    with pytest.raises(AdmissionError, match="over quota"):
        t3.result(timeout=1)
    fb = t3.fallback(backend="numpy")        # ...and still executable
    assert fb.n_banks == 1
    assert svc.stats.shed == 1
    solve_gate.set()
    assert t1.result(timeout=60).status == "solved"
    assert t2.result(timeout=60).status == "solved"


def test_dedup_upgrade_of_deferred_ticket_keeps_it_out_of_queue(
        solve_gate):
    """A higher-priority duplicate of a DEFERRED ticket must upgrade its
    priority without enqueueing it (it has no admission slot yet)."""
    reg = TenantRegistry()
    reg.register("lim", QoSClass("lim", max_inflight=1))
    svc = PlanService(workers=1, tenants=reg)
    svc.submit(*_program("y", 0), tenant="lim")      # holds the slot
    prog, mem = _program("y", 1)
    t2 = svc.submit(prog, mem, tenant="lim", priority=5)
    assert t2.deferred
    dup = svc.submit(prog, mem, tenant="lim", priority=-5)
    assert dup is t2 and t2.priority == -5 and t2.deferred
    assert svc.stats.deduped == 1
    solve_gate.set()
    assert t2.result(timeout=60).status == "solved"


def test_qos_shard_budget_caps_fan_out():
    """A capped tenant's cold solve may not fan across the whole pool;
    the same problem from the default tenant still does."""
    from repro.core import problems
    reg = TenantRegistry()
    reg.register("capped", QoSClass("capped", shard_budget=1))
    svc = PlanService(workers=4, tenants=reg)
    prog = problems.build("sobel")
    memname = list(prog.memories)[0]
    svc.submit(prog, memname, use_cache=False,
               tenant="capped").result(timeout=60)
    assert svc.stats.for_tenant("capped").shards_spawned == 1
    svc.submit(prog, memname, use_cache=False).result(timeout=60)
    assert svc.stats.for_tenant("default").shards_spawned > 1


# ---------------------------------------------------------------------------
# Per-tenant stats slices reconcile exactly
# ---------------------------------------------------------------------------


def _assert_slices_reconcile(svc):
    g = svc.stats.as_dict()
    slices = g.pop("tenants", {})
    for k, v in g.items():
        total = sum(s.get(k, 0) for s in slices.values())
        assert v == total, f"{k}: global {v} != slice sum {total}"


def test_stats_slices_reconcile_over_mixed_workload():
    reg = TenantRegistry()
    reg.register("a", "interactive")
    reg.register("b", "batch")
    svc = PlanService(workers=2, tenants=reg)
    prog, mem = _program("w", 0)
    svc.submit(prog, mem, tenant="a").result(timeout=60)
    svc.submit(prog, mem, tenant="b").result(timeout=60)   # sync hit
    svc.submit(*_program("w", 1), tenant="b").result(timeout=60)
    svc.submit(*_program("w", 2)).result(timeout=60)       # default
    assert svc.stats.sync_hits == 1 and svc.stats.solved == 3
    assert svc.stats.for_tenant("b").sync_hits == 1
    _assert_slices_reconcile(svc)
    # as_dict stays JSON-serializable with the nested slices
    import json
    json.dumps(svc.stats.as_dict())


def test_concurrent_three_tenant_contention_on_shared_store(
        tmp_path, slow_solves):
    """Satellite: N threads submitting across 3 tenants on ONE shared
    DirectoryStore -- quotas enforced, the high-QoS tenant not starved,
    per-tenant stats summing exactly to the global counters."""
    reg = TenantRegistry()
    reg.register("interactive", "interactive")
    reg.register("batch", "batch")
    reg.register("best_effort", "best_effort")
    store = DirectoryStore(tmp_path / "plans")
    svc = PlanService(store=store, workers=2, tenants=reg)
    counts = {"interactive": 3, "batch": 6, "best_effort": 4}
    tickets = {name: [] for name in counts}

    def submitter(name, n):
        for i in range(n):
            tickets[name].append(
                svc.submit(*_program(name[0], i), tenant=name))

    threads = [threading.Thread(target=submitter, args=(n, k))
               for n, k in counts.items()]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for name, ts in tickets.items():
        for t in ts:
            assert t.result(timeout=120).status == "solved", name
    assert svc.drain(timeout=120)
    # quota enforcement: best_effort (max_inflight=2) submitted 4 solves
    # in microseconds against >=50ms solves -- it MUST have deferred
    be = svc.stats.for_tenant("best_effort")
    assert be.deferred >= 1 and be.solved == 4 and be.shed == 0
    # no starvation: every interactive solve landed before the batch
    # flood finished (strict band ordering under saturation)
    last = {name: max(t.resolved_at for t in ts)
            for name, ts in tickets.items()}
    assert last["interactive"] < last["batch"]
    _assert_slices_reconcile(svc)
    # one shared store really served the whole fleet
    assert svc.planner.store is store


# ---------------------------------------------------------------------------
# CI smoke: 2 tenants, saturated queue, no starvation (fast)
# ---------------------------------------------------------------------------


def test_smoke_two_tenant_saturation_no_starvation(slow_solves):
    """CI smoke: one noisy batch tenant saturates a 1-worker service;
    the interactive tenant's submits all resolve before the flood's
    last, and the stats slices reconcile."""
    reg = TenantRegistry()
    reg.register("vip", "interactive")
    reg.register("noisy", "batch")
    svc = PlanService(workers=1, tenants=reg)
    flood = [svc.submit(*_program("n", i), tenant="noisy")
             for i in range(5)]
    vips = [svc.submit(*_program("v", i), tenant="vip") for i in range(2)]
    for t in vips + flood:
        assert t.result(timeout=120).status == "solved"
    assert (max(t.resolved_at for t in vips)
            < max(t.resolved_at for t in flood))
    _assert_slices_reconcile(svc)
