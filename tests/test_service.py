"""PlanService front door: submit -> ticket -> compile -> execute, warm
stores answering before the ticket returns, fallback-first serving with
hot-swap, stale-while-revalidate, priority, dedup, error propagation."""

import dataclasses
import threading

import numpy as np
import pytest

from repro.core import (AccessDecl, BankingPlanner, Counter, Ctrl,
                        MemorySpec, PlanService, Program, Sched,
                        SolverOptions, StaleWhileRevalidate)
from repro.core.polytope import Affine
from repro.core.store import DirectoryStore


def _reader_program(stride=1, count=32, par=8, dims=(256,), name="table"):
    mem = MemorySpec(name, dims=dims, word_bits=32, ports=1)
    return Program(
        root=Ctrl("reader", Sched.INNER,
                  counters=[Counter("i", 0, 1, count, par=par)],
                  accesses=[AccessDecl(name, (Affine.of(i=stride),))]),
        memories={name: mem},
    )


@pytest.fixture
def solve_counter(monkeypatch):
    """Counts cold solves at the universal chokepoint: every path about
    to do solver work (sharded service workers, blocking plan()) starts
    by enumerating its candidate space via BankingPlanner.build_space."""
    calls = []
    real = BankingPlanner.build_space

    def counting(self, prep):
        calls.append(1)
        return real(self, prep)

    monkeypatch.setattr(BankingPlanner, "build_space", counting)
    return calls


@pytest.fixture
def solve_gate(monkeypatch):
    """Blocks the FIRST cold solve until .set(); records memory names."""
    gate = threading.Event()
    order = []
    real = BankingPlanner.build_space

    def gated(self, prep):
        order.append(prep.mem.name)
        if len(order) == 1:
            gate.wait(30)
        return real(self, prep)

    monkeypatch.setattr(BankingPlanner, "build_space", gated)
    gate.order = order
    yield gate
    gate.set()   # never leave a worker blocked past the test


# ---------------------------------------------------------------------------
# Ticket lifecycle
# ---------------------------------------------------------------------------


def test_submit_returns_ticket_and_fallback_before_solve(solve_gate):
    svc = PlanService(workers=1)
    ticket = svc.submit(_reader_program(), "table")
    assert not ticket.done() and ticket.status in ("queued", "solving")
    # fallback is available immediately: trivial single-bank scheme
    fb = ticket.fallback(backend="numpy")
    assert fb.n_banks == 1 and fb.layout.logical_size == 256
    flat = np.arange(256 * 4, dtype=np.float32).reshape(256, 4)
    got = fb.gather(fb.pack(flat), np.asarray([0, 5, 255]))
    np.testing.assert_array_equal(got, flat[[0, 5, 255]])
    solve_gate.set()
    plan = ticket.result(timeout=30)
    assert plan.status == "solved" and ticket.done()
    art = ticket.artifact(backend="numpy")
    assert art.n_banks == plan.best.num_banks
    # solved ticket's fallback IS the solved artifact now
    assert ticket.fallback(backend="numpy").n_banks == art.n_banks


def test_result_timeout_raises(solve_gate):
    svc = PlanService(workers=1)
    ticket = svc.submit(_reader_program(), "table")
    with pytest.raises(TimeoutError):
        ticket.result(timeout=0.05)
    solve_gate.set()
    assert ticket.result(timeout=30).best is not None


def test_submit_time_errors_raise_synchronously():
    svc = PlanService(workers=1)
    with pytest.raises(KeyError):
        svc.submit(_reader_program(), "no_such_memory")
    with pytest.raises(ValueError, match="unknown scorer"):
        svc.submit(_reader_program(), "table", scorer="nope")


def test_worker_errors_propagate_through_result(monkeypatch):
    def boom(self, prep):
        raise RuntimeError("solver exploded")

    monkeypatch.setattr(BankingPlanner, "build_space", boom)
    svc = PlanService(workers=1)
    ticket = svc.submit(_reader_program(), "table")
    with pytest.raises(RuntimeError, match="solver exploded"):
        ticket.result(timeout=30)
    assert ticket.status == "error" and svc.stats.errors == 1
    # the fallback still serves even though the solve failed
    assert ticket.fallback(backend="numpy").n_banks == 1


def test_inflight_submits_share_one_ticket(solve_gate):
    svc = PlanService(workers=1)
    t1 = svc.submit(_reader_program(), "table")
    t2 = svc.submit(_reader_program(), "table")   # same signature, in flight
    assert t2 is t1 and svc.stats.deduped == 1
    solve_gate.set()
    t1.result(timeout=30)
    assert len(solve_gate.order) == 1             # ONE solve for both
    # after completion, a resubmit is a sync cache hit, not the old ticket
    t3 = svc.submit(_reader_program(), "table")
    assert t3 is not t1 and t3.done()


def test_priority_orders_the_queue(solve_gate):
    svc = PlanService(workers=1)
    svc.submit(_reader_program(name="first"), "first")       # occupies worker
    svc.submit(_reader_program(stride=2, name="low"), "low", priority=5)
    svc.submit(_reader_program(stride=3, name="high"), "high", priority=0)
    solve_gate.set()
    assert svc.drain(timeout=30)
    assert solve_gate.order == ["first", "high", "low"]


def test_dedup_upgrades_priority(solve_gate):
    """A hotter resubmit of an in-flight problem pulls it forward in the
    queue (the stale lower-priority entry becomes a no-op)."""
    svc = PlanService(workers=1)
    svc.submit(_reader_program(name="first"), "first")       # occupies worker
    a1 = svc.submit(_reader_program(stride=2, name="a"), "a", priority=5)
    svc.submit(_reader_program(stride=3, name="b"), "b", priority=2)
    a2 = svc.submit(_reader_program(stride=2, name="a"), "a", priority=0)
    assert a2 is a1 and a1.priority == 0 and svc.stats.deduped == 1
    solve_gate.set()
    assert svc.drain(timeout=30)
    assert solve_gate.order == ["first", "a", "b"]   # a jumped ahead of b


# ---------------------------------------------------------------------------
# Sharded solves: stats counters + progressive best-so-far tickets
# ---------------------------------------------------------------------------


def test_sharded_solve_stats_and_monotone_best(solve_counter):
    """A cold ticket fans its candidate space across the worker pool:
    ServiceStats counts shards spawned/completed and best-so-far
    promotions, and ``best_so_far()`` never regresses in score as the
    shard streams land -- ending exactly at the plan's winner."""
    svc = PlanService(workers=2, shard_budget=4)
    ticket = svc.submit(_reader_program(stride=3, count=64), "table")
    scores = []
    while not ticket.wait(0.001):
        best = ticket.best_so_far()
        if best is not None:
            scores.append(best.score)
    plan = ticket.result(30)
    assert plan.status == "solved" and len(solve_counter) == 1
    st = svc.stats
    assert st.shards_spawned == 4          # one space, four shards
    assert st.shards_completed == st.shards_spawned
    assert st.best_promotions >= 1
    assert st.dedup_hits >= 0
    assert st.solved == 1
    scores.append(plan.best.score)          # the winner caps the series
    assert all(a >= b for a, b in zip(scores, scores[1:]))
    assert ticket.best_so_far() is plan.best
    assert ticket.best_version() >= 1


def test_shard_budget_one_still_resolves(solve_counter):
    svc = PlanService(workers=1, shard_budget=1)
    plan = svc.submit(_reader_program(), "table").result(timeout=30)
    assert plan.best is not None and svc.stats.shards_spawned == 1


def test_sharded_result_matches_blocking_plan():
    """ticket.result() after a 4-way sharded solve chooses the same
    scheme as a fresh blocking (single-path) planner."""
    svc = PlanService(workers=2, shard_budget=4)
    sharded = svc.submit(_reader_program(stride=2), "table").result(30)
    blocking = BankingPlanner().plan(_reader_program(stride=2), "table")
    assert sharded.best.geometry == blocking.best.geometry
    assert sharded.signature == blocking.signature


# ---------------------------------------------------------------------------
# Warm stores: tickets born done
# ---------------------------------------------------------------------------


def test_warm_directory_store_returns_done_ticket(tmp_path, solve_counter):
    """ISSUE acceptance: a warm DirectoryStore makes submit() return an
    already-done ticket -- zero solver calls, asserted via counter."""
    svc1 = PlanService(store=DirectoryStore(tmp_path), workers=1)
    svc1.submit(_reader_program(), "table").result(timeout=30)
    assert len(solve_counter) == 1
    # a different service + planner ("another process") on the same dir
    svc2 = PlanService(store=DirectoryStore(tmp_path), workers=1)
    ticket = svc2.submit(_reader_program(), "table")
    assert ticket.done()                          # answered inside submit
    assert len(solve_counter) == 1                # NO solver call
    plan = ticket.result()
    assert plan.status == "cached-disk"
    assert svc2.stats.sync_hits == 1 and svc2.stats.queued == 0
    # the artifact comes straight off the shared store too
    art = ticket.artifact()
    assert art.n_banks == plan.best.num_banks


def test_use_cache_false_always_resolves(solve_counter):
    svc = PlanService(workers=1)
    svc.submit(_reader_program(), "table").result(timeout=30)
    t = svc.submit(_reader_program(), "table", use_cache=False)
    t.result(timeout=30)
    assert len(solve_counter) == 2


# ---------------------------------------------------------------------------
# Stale-while-revalidate: near-match serves, exact solve runs speculatively
# ---------------------------------------------------------------------------


def test_stale_near_match_serves_while_revalidating(tmp_path, solve_gate):
    store = DirectoryStore(tmp_path)
    warm = PlanService(store=store, workers=1)
    solve_gate.set()   # base solve may run immediately
    base = warm.submit(_reader_program(), "table",
                       opts=SolverOptions(n_budget=8)).result(timeout=30)
    # fresh planner, same store, drifted solver options -> near match
    gate2 = threading.Event()
    real = BankingPlanner.build_space
    seen = []

    def gated2(self, prep):
        seen.append(1)
        gate2.wait(30)
        return real(self, prep)

    BankingPlanner.build_space = gated2
    try:
        svc = PlanService(store=DirectoryStore(tmp_path), workers=1)
        ticket = svc.submit(_reader_program(), "table",
                            opts=SolverOptions(n_budget=16))
        assert ticket.status in ("revalidating", "solving")
        assert ticket.stale_plan is not None
        assert ticket.stale_plan.signature == base.signature
        # the provisional artifact is the near-match scheme, NOT trivial
        fb = ticket.fallback(backend="numpy")
        assert fb.n_banks == base.best.num_banks > 1
        assert svc.stats.revalidations == 1
        gate2.set()
        fresh = ticket.result(timeout=30)
        # the speculative re-plan really solved under the new options
        assert fresh.status == "solved" and len(seen) == 1
        assert fresh.signature != base.signature
        assert fresh.family == base.family
    finally:
        gate2.set()
        BankingPlanner.build_space = real


def test_revalidate_can_be_disabled(tmp_path, solve_gate):
    store = DirectoryStore(tmp_path)
    solve_gate.set()
    PlanService(store=store, workers=1).submit(
        _reader_program(), "table",
        opts=SolverOptions(n_budget=8)).result(timeout=30)
    svc = PlanService(store=DirectoryStore(tmp_path), workers=1,
                      revalidate=StaleWhileRevalidate(enabled=False))
    ticket = svc.submit(_reader_program(), "table",
                        opts=SolverOptions(n_budget=16))
    assert ticket.stale_plan is None
    assert ticket.fallback(backend="numpy").n_banks == 1   # trivial
    ticket.result(timeout=30)


# ---------------------------------------------------------------------------
# Fallback-first serving with hot swap (the ISSUE acceptance test)
# ---------------------------------------------------------------------------


def _tiny_model():
    from repro.configs import get_arch
    from repro.models import get_model

    cfg = get_arch("qwen2_7b").reduced()
    cfg = dataclasses.replace(cfg, n_layers=1, d_model=32, d_ff=64,
                              vocab=64, n_heads=2, n_kv_heads=2, head_dim=16)
    return get_model(cfg)


def test_server_first_tick_from_fallback_then_hot_swap(solve_gate):
    """The server serves its first tick from the fallback artifact without
    waiting on the solver, then hot-swaps to the solved artifact between
    ticks -- with identical gather results across the swap."""
    from repro.runtime.server import Request, Server, page_ticket

    svc = PlanService(workers=1)
    ticket = page_ticket(None, max_len=32, page=8, readers=4, service=svc)
    assert not ticket.done()                  # solver is gated shut
    server = Server(_tiny_model(), max_batch=2, max_len=32, kv_plan=ticket)
    # first-tick layout IS the trivial fallback: one bank = one page
    assert server.pager.pages_per_slot == 1
    assert server.pager.page_size >= 32
    server.submit(Request(uid=0, prompt=np.asarray([3, 4, 5], np.int32),
                          max_new=6))
    server.tick()
    assert server.ticks == 1 and not ticket.done()   # served pre-solve
    assert len(server.active[0].out) == 1            # a real token came out
    fb_art, fb_table = server._kv_art, server.kv_records
    idx = np.asarray([[0, 1, 2], [1, 2, 3]], np.int32)
    before = np.asarray(fb_art.gather(fb_table, idx))
    # release the solver; the swap happens between ticks
    solve_gate.set()
    assert ticket.wait(30)
    server._maybe_swap_kv()
    assert server.swaps == 1
    solved = server.pager.artifact
    assert solved.n_banks > 1                        # real banking now
    assert server.pager.pages_per_slot == solved.n_banks
    # identical gather results through the solved resolution circuit
    after = np.asarray(server._kv_art.gather(server.kv_records, idx))
    np.testing.assert_array_equal(before, after)
    # and the whole logical record table survived the swap
    np.testing.assert_array_equal(
        np.asarray(fb_art.unpack(fb_table)),
        np.asarray(server._kv_art.unpack(server.kv_records)))
    server.tick()
    assert server.swaps == 1                         # swap is one-shot
    server.run(max_ticks=50)
    assert not server.active and not server.queue
    assert server.pager.used_pages == 0              # pages released


def test_server_promotes_to_best_so_far_mid_search(monkeypatch):
    """Before the full search drains, the server adopts the ticket's
    best-so-far scheme between ticks (a *promotion*, not the final
    swap) -- and the logical record table survives both the promotion
    and the eventual solved swap."""
    from repro.core import service as service_mod
    from repro.runtime.server import Request, Server, page_ticket

    real = service_mod.evaluate
    reached = threading.Event()    # one valid scheme has streamed
    release = threading.Event()    # let the search finish

    def paced(shard, gate=None):
        for ev in real(shard, gate=gate):
            yield ev
            if ev.solutions and not reached.is_set():
                reached.set()
                assert release.wait(30)

    monkeypatch.setattr(service_mod, "evaluate", paced)
    try:
        svc = PlanService(workers=1, shard_budget=1)
        ticket = page_ticket(None, max_len=32, page=8, readers=4,
                             service=svc)
        server = Server(_tiny_model(), max_batch=2, max_len=32,
                        kv_plan=ticket)
        assert server.pager.pages_per_slot == 1      # trivial fallback
        assert reached.wait(30)
        assert not ticket.done()
        best = ticket.best_so_far()
        assert best is not None and ticket.best_version() >= 1
        server.submit(Request(uid=0,
                              prompt=np.asarray([3, 4, 5], np.int32),
                              max_new=6))
        server.tick()          # _maybe_swap_kv promotes, then serves
        assert server.promotions == 1 and server.swaps == 0
        assert server.pager.pages_per_slot > 1       # real banking now
        assert len(server.active[0].out) == 1
        promoted_art, promoted_tab = server._kv_art, server.kv_records
        idx = np.asarray([[0, 1, 2], [1, 2, 3]], np.int32)
        before = np.asarray(promoted_art.gather(promoted_tab, idx))
        server._maybe_swap_kv()
        assert server.promotions == 1                # same version: no-op
        release.set()
        assert ticket.wait(30)
        server._maybe_swap_kv()      # the final solved swap (a no-op if
        # the promotion already landed the winning layout)
        assert server._kv_art.layout == ticket.artifact().layout
        assert server.swaps == (0 if promoted_art.layout
                                == ticket.artifact().layout else 1)
        after = np.asarray(server._kv_art.gather(server.kv_records, idx))
        np.testing.assert_array_equal(before, after)
        server.run(max_ticks=50)
        assert not server.active and not server.queue
    finally:
        release.set()


def test_server_with_done_ticket_and_with_raw_artifact_agree(solve_gate):
    """A ticket that resolved before the server starts behaves exactly
    like the legacy solved-artifact path."""
    from repro.runtime.server import Request, Server, page_ticket

    solve_gate.set()
    svc = PlanService(workers=1)
    ticket = page_ticket(None, max_len=32, page=8, readers=4, service=svc)
    ticket.wait(30)
    model = _tiny_model()
    s_ticket = Server(model, max_batch=2, max_len=32, kv_plan=ticket)
    s_art = Server(model, max_batch=2, max_len=32,
                   kv_plan=ticket.artifact())
    assert s_ticket.swaps == 0 and s_ticket._kv_ticket is None
    assert (s_ticket.pager.pages_per_slot == s_art.pager.pages_per_slot
            == ticket.artifact().n_banks)
    for s in (s_ticket, s_art):
        s.submit(Request(uid=0, prompt=np.asarray([5, 6], np.int32),
                         max_new=4))
        s.run(max_ticks=20)
    assert s_ticket.active == {} and s_art.active == {}


def test_batched_tick_gather_is_one_call(monkeypatch, solve_gate):
    """Server.tick issues exactly ONE banked gather per tick, covering
    every active slot (stacked (slots, W) index matrix)."""
    from repro.core.artifact import CompiledBankingPlan
    from repro.runtime.server import Request, Server, page_ticket

    solve_gate.set()
    svc = PlanService(workers=1)
    ticket = page_ticket(None, max_len=32, page=8, readers=4, service=svc)
    ticket.result(30)
    server = Server(_tiny_model(), max_batch=2, max_len=32, kv_plan=ticket)
    calls = []
    real = CompiledBankingPlan.gather

    def spying(self, table, rows, **kw):
        calls.append(np.asarray(rows).shape)
        return real(self, table, rows, **kw)

    monkeypatch.setattr(CompiledBankingPlan, "gather", spying)
    for uid in range(2):
        server.submit(Request(uid=uid,
                              prompt=np.asarray([3 + uid, 4], np.int32),
                              max_new=3))
    server.tick()
    assert len(calls) == 1                      # one pallas_call per tick
    assert calls[0] == (2, server._gather_window)   # both slots, stacked
    server.tick()
    assert len(calls) == 2
