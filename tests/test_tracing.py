"""Observability plane: Tracer spans, MetricsRegistry, FlightRecorder
(repro.core.tracing + PlanService/SolveFabric integration).

Covers the metrics registry write paths (counters with labels, gauges,
bounded histogram quantiles, Prometheus text exposition), the tracer
span lifecycle (begin/end nesting, retroactive record, finish popping
the live trace into the recorder), the flight recorder's bounded ring
and anomaly dumps, Chrome ``trace_event`` required keys, the traced
1-shard solve + /metrics HTTP smoke the CI step runs (``-k smoke``),
and the fabric stories: a 2-worker solve whose merged trace contains
worker-side lease/eval spans sharing the driver's ``trace_id``, and a
worker kill whose requeue shows up as a span in the same trace.
"""

import itertools
import json
import signal
import threading
import time
import urllib.request

import pytest

from repro.core import (AccessDecl, CandidateSpace, Counter, Ctrl,
                        FlightRecorder, MemorySpec, MetricsRegistry,
                        PlanService, Program, QoSClass, Sched,
                        SolutionReducer, SolveFabric, SolverOptions,
                        TenantRegistry, Tracer, build_groups,
                        chrome_trace_events, new_trace_id,
                        spawn_local_workers,
                        start_observability_server, unroll)
from repro.core import problems
from repro.core.planner import BankingPlanner
from repro.core.polytope import Affine

_UID = itertools.count()


def _program(tag):
    """A unique banking problem per call (identity is structural, so
    uniqueness comes from distinct memory dims)."""
    name = f"{tag}{next(_UID)}"
    mem = MemorySpec(name, dims=(256 + 8 * next(_UID),), word_bits=32,
                     ports=1)
    return Program(
        root=Ctrl("reader", Sched.INNER,
                  counters=[Counter("i", 0, 1, 32, par=8)],
                  accesses=[AccessDecl(name, (Affine.of(i=1),))]),
        memories={name: mem},
    ), name


class _Cluster:
    """A fabric plus n local worker subprocesses, cleaned up reliably."""

    def __init__(self, n, **kw):
        self.fabric = SolveFabric(**kw)
        self.procs = spawn_local_workers(self.fabric.address, n) if n else []
        if n:
            assert self.fabric.wait_for_workers(n, timeout=60), \
                f"{n} workers did not attach"

    def kill(self, i):
        self.procs[i].send_signal(signal.SIGKILL)

    def close(self):
        for p in self.procs:
            p.terminate()
        for p in self.procs:
            p.wait(timeout=10)
        self.fabric.shutdown()


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------


def test_metrics_counters_and_gauges_with_labels():
    m = MetricsRegistry()
    m.inc("solves")
    m.inc("solves", 2, tenant="a")
    m.inc("solves", tenant="a")
    m.set_gauge("queue_depth", 7)
    m.set_gauge("queue_depth", 3, tenant="a")
    assert m.counter("solves") == 1
    assert m.counter("solves", tenant="a") == 3
    assert m.counter("never_bumped") == 0
    assert m.gauge("queue_depth") == 7
    assert m.gauge("queue_depth", tenant="a") == 3
    snap = m.snapshot()
    assert snap["counters"]['solves{tenant="a"}'] == 3
    assert snap["gauges"]["queue_depth"] == 7


def test_metrics_histogram_quantiles_stay_bounded():
    m = MetricsRegistry(histogram_cap=64)
    for v in range(1000):            # way past cap: reservoir must bound
        m.observe("lat_ms", float(v))
    h = m.histogram("lat_ms")
    assert h["count"] == 1000
    assert len(m._hists[("lat_ms", ())].samples) <= 64
    assert h["min"] <= h["p50"] <= h["p95"] <= h["p99"] <= h["max"]
    assert h["max"] == 999.0
    # a fresh single-sample histogram degenerates sanely
    m.observe("one", 5.0)
    h1 = m.histogram("one")
    assert h1["p50"] == h1["p99"] == 5.0 and h1["count"] == 1


def test_prometheus_exposition_format():
    m = MetricsRegistry()
    m.inc("plan_submits", 4, tenant="acme")
    m.set_gauge("queue_depth", 2)
    m.observe("ticket_ms", 12.5)
    text = m.prometheus()
    lines = text.splitlines()
    assert 'plan_submits{tenant="acme"} 4' in lines
    assert "queue_depth 2.0" in lines
    assert any(ln.startswith("ticket_ms_count 1") for ln in lines)
    assert any('ticket_ms{quantile="0.5"}' in ln for ln in lines)
    assert any(ln.startswith("# TYPE plan_submits counter")
               for ln in lines)


# ---------------------------------------------------------------------------
# Tracer + FlightRecorder
# ---------------------------------------------------------------------------


def test_tracer_span_lifecycle_and_finish():
    rec = FlightRecorder(capacity=8)
    tr = Tracer(recorder=rec)
    tid = new_trace_id()
    root = tr.begin(tid, "ticket", memory="m0")
    with tr.span(tid, "lint"):
        pass
    t0 = time.perf_counter()
    time.sleep(0.002)
    tr.record(tid, "queue-wait", t0, time.perf_counter())
    tr.instant(tid, "requeue", worker=3)
    tr.end(root, status="ok")
    assert tid in [t.trace_id for t in tr.live_traces()]
    trace = tr.finish(tid, status="ok")
    assert tid not in [t.trace_id for t in tr.live_traces()]
    names = [s.name for s in trace.spans]
    assert sorted(names) == ["lint", "queue-wait", "requeue", "ticket"]
    waited = next(s for s in trace.spans if s.name == "queue-wait")
    assert waited.duration_ms >= 2.0
    assert trace.status == "ok"
    assert rec.traces()[-1] is trace


def test_flight_recorder_ring_bound_and_anomaly_dump(tmp_path):
    rec = FlightRecorder(capacity=4, trace_dir=str(tmp_path))
    tr = Tracer(recorder=rec)
    tids = []
    for i in range(10):
        tid = new_trace_id()
        tids.append(tid)
        with tr.span(tid, "work", i=i):
            pass
        tr.finish(tid, status="ok")
    kept = rec.traces()
    assert len(kept) == 4                       # ring stays bounded
    assert [t.trace_id for t in kept] == tids[-4:]
    # an anomaly dumps the implicated trace to the trace dir
    tid = new_trace_id()
    with tr.span(tid, "work"):
        tr.note_anomaly("cert-rejection", detail="deadbeef")
    tr.finish(tid, status="ok")
    dumps = list(tmp_path.glob("*.json"))
    assert dumps, "anomaly produced no dump"
    payload = json.loads(dumps[0].read_text())
    assert payload["traceEvents"]
    assert any(("cert-rejection", "deadbeef") == (kind, detail)
               for _, kind, detail in rec.anomalies())


def test_slo_breach_counts_as_anomaly():
    rec = FlightRecorder(capacity=4, slo_ms=0.0)     # everything breaches
    tr = Tracer(recorder=rec)
    tid = new_trace_id()
    with tr.span(tid, "work"):
        time.sleep(0.001)
    tr.finish(tid, status="ok")
    assert any(kind == "slo-exceeded" for _, kind, _ in rec.anomalies())


def test_chrome_trace_events_required_keys():
    tr = Tracer()
    tid = new_trace_id()
    root = tr.begin(tid, "ticket")
    with tr.span(tid, "lease"):
        pass
    tr.end(root)
    trace = tr.finish(tid, status="ok")
    events = chrome_trace_events([trace])
    assert events
    for e in events:
        for key in ("name", "ph", "ts", "pid", "tid"):
            assert key in e, f"{key} missing from {e}"
        if e["ph"] == "X":
            assert "dur" in e and e["dur"] >= 0
    assert any(e["ph"] == "M" for e in events)   # process/thread names
    assert min(e["ts"] for e in events if e["ph"] == "X") == 0


def test_remote_span_rebasing():
    """Wire spans from another clock domain land inside the driver's
    timeline, offset from the supplied base timestamp."""
    from repro.core.tracing import spans_to_wire
    tr = Tracer()
    tid = new_trace_id()
    base = time.perf_counter()
    wire = spans_to_wire(
        [{"name": "w-eval", "start": base + 0.010, "end": base + 0.030,
          "attrs": {"evaluated": 5}}], base)
    tr.add_remote_spans(tid, wire, base=base, origin="worker-9")
    (span,) = tr.spans(tid)
    assert span.origin == "worker-9"
    assert span.start == pytest.approx(base + 0.010, abs=1e-5)
    assert span.duration_ms == pytest.approx(20.0, abs=0.1)
    assert span.attrs["evaluated"] == 5 and span.attrs["clock"] == "rebased"


# ---------------------------------------------------------------------------
# Service integration
# ---------------------------------------------------------------------------


def test_smoke_traced_solve_and_metrics_endpoint():
    """The CI observability gate: a traced 1-shard cold solve produces a
    valid Chrome trace and a scrapeable /metrics endpoint."""
    svc = PlanService(workers=1)
    svc.enable_tracing()
    prog, mem = _program("sm")
    ticket = svc.submit(prog, mem, use_cache=False, shard_budget=1)
    plan = ticket.result(timeout=120)
    assert plan.best is not None
    trace = next(t for t in svc.recorder.traces()
                 if t.trace_id == ticket.trace_id)
    names = [s.name for s in trace.spans]
    for expected in ("prepare", "queue-wait", "enumerate", "shard-eval",
                     "reduce", "ticket"):
        assert expected in names, f"{expected} not in {names}"
    chrome = svc.recorder.chrome_trace()
    for e in chrome["traceEvents"]:
        for key in ("name", "ph", "ts", "pid", "tid"):
            assert key in e
    assert svc.metrics.counter("plan_solved", tenant="default") == 1
    assert svc.metrics.histogram("ticket_ms")["count"] == 1
    server = start_observability_server(svc.metrics, svc.recorder,
                                        tracer=svc.tracer, port=0)
    try:
        host, port = server.server_address[:2]
        body = urllib.request.urlopen(
            f"http://{host}:{port}/metrics", timeout=10).read().decode()
        assert "plan_solved" in body and "ticket_ms" in body
        traces = json.loads(urllib.request.urlopen(
            f"http://{host}:{port}/traces", timeout=10).read())
        assert traces["traceEvents"]
    finally:
        server.shutdown()
    svc.shutdown()


def test_ticket_as_dict_reports_queue_and_deferred_ms():
    """A ticket deferred by admission then queued reports both waits in
    as_dict(), and its trace carries the matching span chain."""
    reg = TenantRegistry()
    reg.register("lim", QoSClass("lim", max_inflight=1))
    gate = threading.Event()
    real = BankingPlanner.build_space
    calls = []

    def gated(self, prep):
        calls.append(prep.mem.name)
        if len(calls) == 1:
            gate.wait(30)
        return real(self, prep)

    BankingPlanner.build_space = gated
    try:
        svc = PlanService(workers=1, tenants=reg)
        svc.enable_tracing()
        t1 = svc.submit(*_program("q"), tenant="lim")   # holds the slot
        t2 = svc.submit(*_program("q"), tenant="lim")
        assert t2.deferred
        time.sleep(0.01)                   # accrue measurable deferral
        gate.set()
        assert t1.result(timeout=120) is not None
        assert t2.result(timeout=120) is not None
        d = t2.as_dict()
        assert d["deferred_ms"] > 0
        assert d["queue_ms"] >= 0
        trace = next(t for t in svc.recorder.traces()
                     if t.trace_id == t2.trace_id)
        names = [s.name for s in trace.spans]
        assert "admission-deferred" in names
        assert "deferred-wait" in names
        assert "queue-wait" in names
        waited = next(s for s in trace.spans if s.name == "deferred-wait")
        assert waited.duration_ms == pytest.approx(d["deferred_ms"],
                                                   rel=0.5)
        svc.shutdown()
    finally:
        BankingPlanner.build_space = real
        gate.set()


def test_tracing_disabled_leaves_no_observable_state():
    """With tracing off (the default), tickets carry no trace_id and the
    service keeps no recorder/metrics -- the hooks are inert."""
    svc = PlanService(workers=1)
    prog, mem = _program("off")
    ticket = svc.submit(prog, mem, use_cache=False)
    assert ticket.result(timeout=120) is not None
    assert ticket.trace_id is None
    assert svc.tracer is None and svc.recorder is None \
        and svc.metrics is None
    d = ticket.as_dict()
    assert d["queue_ms"] >= 0 and d["deferred_ms"] == 0.0
    svc.shutdown()


# ---------------------------------------------------------------------------
# Fabric integration: stitched worker spans, requeue chains
# ---------------------------------------------------------------------------


def test_fabric_trace_stitches_worker_spans():
    """A 2-worker fabric solve merges worker-side lease/eval spans into
    the DRIVER's trace: same trace_id, per-worker origins, rebased
    clocks."""
    c = _Cluster(2, chunk=16)
    try:
        svc = PlanService(executor="fabric", fabric=c.fabric)
        svc.enable_tracing()
        prog = problems.build("sobel")
        memname = list(prog.memories)[0]
        ticket = svc.submit(prog, memname, use_cache=False)
        assert ticket.result(timeout=120) is not None
        trace = next(t for t in svc.recorder.traces()
                     if t.trace_id == ticket.trace_id)
        names = [s.name for s in trace.spans]
        assert "serialize" in names and "fabric-solve" in names
        assert "lease" in names
        worker_spans = [s for s in trace.spans
                        if s.origin.startswith("worker-")]
        assert any(s.name == "w-lease" for s in worker_spans)
        assert any(s.name == "w-eval" for s in worker_spans)
        assert all(s.attrs.get("clock") == "rebased"
                   for s in worker_spans)
        # every span really is ONE trace: chrome events share one pid
        events = chrome_trace_events([trace])
        assert len({e["pid"] for e in events}) == 1
        lanes = {e["args"]["name"] for e in events
                 if e["ph"] == "M" and e["name"] == "thread_name"}
        assert {"worker-0", "worker-1"} <= lanes or \
            len([ln for ln in lanes if ln.startswith("worker-")]) >= 1
        svc.shutdown()
    finally:
        c.close()


def test_worker_kill_requeue_appears_in_trace():
    """SIGKILLing a worker mid-solve leaves a requeue span chain in the
    trace: the lost lease's unit re-issues and the solve converges."""
    c = _Cluster(2, chunk=8, lease_window=2)
    try:
        tr = Tracer(recorder=FlightRecorder(capacity=4))
        tid = new_trace_id()
        prog = problems.build("sobel")
        memname = list(prog.memories)[0]
        up = unroll(prog)
        space = CandidateSpace(prog.memories[memname],
                               build_groups(up, memname),
                               up.iterators, SolverOptions())
        red = SolutionReducer(space)
        done = {}

        def run():
            done["report"] = c.fabric.solve(space, reducer=red,
                                            trace=(tr, tid))

        th = threading.Thread(target=run)
        th.start()
        deadline = time.monotonic() + 60
        while (c.fabric.stats.results_frames < 1
               and time.monotonic() < deadline):
            time.sleep(0.001)
        assert c.fabric.stats.results_frames >= 1, "no results before kill"
        c.kill(0)
        th.join(timeout=120)
        assert not th.is_alive(), "solve hung after the worker died"
        assert done["report"].requeues >= 1
        spans = tr.spans(tid)
        requeues = [s for s in spans if s.name == "requeue"]
        assert len(requeues) >= 1
        assert requeues[0].attrs["units"] >= 1
        # the re-issued unit produced lease spans AFTER the requeue
        assert any(s.name == "lease" and s.start >= requeues[0].start
                   for s in spans)
    finally:
        c.close()
