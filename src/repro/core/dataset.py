"""Training corpus for the resource estimator (paper Sec 3.5.2).

The paper collected 831 samples by running PnR on Spatial's regression
suite.  No Vivado exists in this container, so labels come from a *synthetic
place-and-route emulator*: the structural proxy of core/resources.py plus
the deterministic nonlinear effects real PnR exhibits (LUT packing and
routing-pressure inflation for wide crossbars, retiming register
duplication proportional to datapath depth, carry-chain discounts, BRAM
quantization) and a small seeded lognormal noise.  This is stated openly in
EXPERIMENTS.md: the ML-pipeline comparison (GBT-vs-MLP, Fig. 11) is
reproduced against this synthetic PnR.

A second label source is REAL: for each scheme we lower its transformed
bank-resolution graph through JAX/XLA and count the compiled HLO scalar ops
(core/dataset.py:hlo_label) -- that target is used for the TPU-side scheme
ranking in the LM framework.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import problems
from .controller import Program
from .features import extract_features
from .planner import BankingPlanner
from .solver import BankingSolution, SolverOptions


# ---------------------------------------------------------------------------
# Synthetic PnR emulator
# ---------------------------------------------------------------------------


def _seed_from(x: np.ndarray) -> int:
    return int.from_bytes(hashlib.sha256(x.tobytes()).digest()[:4], "little")


def synthetic_pnr(sol: BankingSolution, noise: float = 0.05) -> Dict[str, float]:
    r = sol.resources
    feats = extract_features(sol)
    rng = np.random.default_rng(_seed_from(feats))

    lut = r.crossbar.lut + r.resolution.lut + r.storage.lut
    # routing-pressure inflation: wide crossbars pack badly
    if r.crossbar.lut > 400:
        lut += 0.35 * (r.crossbar.lut - 400)
    # carry-chain discount: adder trees pack into CARRY4 slices
    lut -= 0.2 * min(r.resolution.lut, 300)
    # control overhead per bank
    lut += 24 + 4.0 * sol.num_banks * sol.duplicates

    ff = r.total.ff
    # retiming duplicates registers along deep resolution pipelines
    depth_proxy = max(1.0, r.resolution.lut / 64.0)
    ff *= 1.0 + 0.08 * depth_proxy
    ff += 16 + 2.0 * sol.num_banks

    bram = float(r.total.bram)
    dsp = float(r.total.dsp)

    lut *= float(np.exp(rng.normal(0, noise)))
    ff *= float(np.exp(rng.normal(0, noise)))
    return {"lut": max(lut, 8.0), "ff": max(ff, 4.0), "bram": bram, "dsp": dsp}


def hlo_label(sol: BankingSolution) -> float:
    """REAL label: scalar-op count of the compiled bank-resolution HLO."""
    import jax
    import jax.numpy as jnp

    from .transforms import lower_jnp

    graphs = []
    ba = sol.resolution_ba
    graphs.extend(ba if isinstance(ba, tuple) else (ba,))
    graphs.append(sol.resolution_bo)
    n = sol.memory.n

    def fn(xs):
        env = {f"x{i}": xs[i] for i in range(n)}
        outs = []
        for g in graphs:
            outs.append(lower_jnp(g)(**{k: env[k] for k in env}))
        return sum(jnp.asarray(o, jnp.int32).sum() for o in outs)

    xs = [jnp.zeros((8,), jnp.int32) for _ in range(n)]
    jaxpr = jax.make_jaxpr(fn)(xs)
    return float(len(jaxpr.jaxpr.eqns))


# ---------------------------------------------------------------------------
# Corpus generation
# ---------------------------------------------------------------------------


def corpus_programs(seed: int = 0) -> List[Tuple[str, Program]]:
    """The benchmark suite plus randomized variants (sizes, pars, ports)."""
    rng = np.random.default_rng(seed)
    progs: List[Tuple[str, Program]] = []
    for name in problems.STENCILS:
        progs.append((name, problems.stencil_program(name)))
    progs.append(("sw", problems.sw_program()))
    progs.append(("spmv", problems.spmv_program()))
    progs.append(("sgd", problems.sgd_program()))
    progs.append(("md_grid", problems.md_grid_program()))
    # randomized variants
    for name in problems.STENCILS:
        for _ in range(2):
            H = int(rng.choice([64, 128, 256]))
            W = int(rng.choice([64, 128, 256]))
            par = int(rng.choice([1, 2, 4]))
            ports = int(rng.choice([1, 2]))
            progs.append(
                (f"{name}/H{H}W{W}p{par}k{ports}",
                 problems.stencil_program(name, H=H, W=W, par=par, ports=ports))
            )
    for _ in range(4):
        progs.append((f"sw/p{_}", problems.sw_program(
            H=int(rng.choice([32, 64])), W=int(rng.choice([32, 64])),
            par=int(rng.choice([2, 4, 8])))))
        progs.append((f"sgd/p{_}", problems.sgd_program(
            par_a=int(rng.choice([2, 4])), par_b=int(rng.choice([2, 3])))))
    return progs


@dataclass
class Dataset:
    X: np.ndarray
    y: Dict[str, np.ndarray]  # per-resource labels
    names: List[str]          # sample provenance


def build_dataset(seed: int = 0, opts: Optional[SolverOptions] = None,
                  max_per_program: int = 40) -> Dataset:
    opts = opts or SolverOptions(max_solutions=24, n_budget=24)
    planner = BankingPlanner(opts=opts)
    rows, names = [], []
    labels: Dict[str, List[float]] = {"lut": [], "ff": [], "bram": [], "dsp": []}
    for pname, prog in corpus_programs(seed):
        for memname in prog.memories:
            plan = planner.plan(prog, memname)
            for s in plan.solutions[:max_per_program]:
                rows.append(extract_features(s, plan.groups))
                lab = synthetic_pnr(s)
                for k in labels:
                    labels[k].append(lab[k])
                names.append(f"{pname}:{memname}")
    X = np.asarray(rows)
    y = {k: np.asarray(v) for k, v in labels.items()}
    return Dataset(X=X, y=y, names=names)
