"""Comparison systems for Tables 2-3 (paper Sec 4).

* ``baseline``  -- the generalized hyperplane partitioning of Wang/Li/Cong
  (FPGA'14) [33]: flat hyperplane schemes only, first-order cost rules
  (minimize bank count, then fan-out), NO Sec-3.4 transforms (mul/div/mod
  stay as DSP/IP calls).
* ``spatial``   -- unmodified Spatial [18]: takes the FIRST valid scheme its
  naive enumeration finds (alpha = row-major weights, B = 1, N counting up
  from the group size); no search, no transforms, no cost model.
* ``merlin``    -- emulation of the Merlin compiler behaviour the paper
  observed on F1: pattern-matches accesses to a bounding-box stencil
  template (banking denoise/bicubic 'as sobel-like patterns': a full
  bbox_h x bbox_w cyclic multidim scheme) with raw resolution arithmetic.
  This is an emulation from the paper's description, not Merlin itself.
* ``ours``      -- the full system: flat + multidim + duplication search,
  transforms, ML (or proxy) ranking.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from .controller import Program, unroll
from .planner import BankingPlan, BankingPlanner
from .geometry import ConflictCache, FlatGeometry, MultiDimGeometry, \
    flat_conflict_edges, multidim_conflict_edges, _max_conflict_clique
from .grouping import build_groups
from .polytope import linearize
from .solver import (
    BankingSolution,
    SolverOptions,
    _attach_flat,
    _attach_multidim,
    solve
)

import time


def _as_plan(memory: str, groups, sols, dt: float, opts: SolverOptions,
             system: str) -> BankingPlan:
    """Wrap a comparison system's schemes as a (detached) BankingPlan so
    every system yields the same artifact type; ``plan.compile()`` lowers
    the emulated system's choice exactly like ours."""
    return BankingPlan(
        memory=memory, signature="", best=sols[0] if sols else None,
        solve_seconds=dt, num_candidates=len(sols), scorer_name=system,
        status="solved", created_at=time.time(), opts=opts,
        solutions=list(sols), groups=list(groups))


def run_ours(program: Program, memory: str, scorer=None) -> BankingPlan:
    opts = SolverOptions(transform_level="full")
    planner = BankingPlanner(opts=opts)
    return planner.plan(program, memory, scorer=scorer)


def run_baseline_wang14(program: Program, memory: str) -> BankingPlan:
    """Flat-only, raw arithmetic, first-order (min-N then min-FO) selection."""
    t0 = time.perf_counter()
    up = unroll(program)
    groups = build_groups(up, memory)
    mem = program.memories[memory]
    opts = SolverOptions(
        transform_level="basic", allow_multidim=False, allow_duplication=False,
        max_solutions=24,
    )
    sols = solve(mem, groups, up.iterators, opts)
    # first-order rules: fewest banks, then smallest max fan-out
    sols.sort(key=lambda s: (s.num_banks,
                             max(s.fan_outs) if s.fan_outs else 1,
                             s.bank_volume))
    for s in sols:
        s.score = s.num_banks
    dt = time.perf_counter() - t0
    return _as_plan(memory, groups, sols, dt, opts, "baseline")


def run_spatial_firstvalid(program: Program, memory: str) -> BankingPlan:
    """Unmodified Spatial: FIRST valid flat scheme in naive order."""
    t0 = time.perf_counter()
    up = unroll(program)
    groups = build_groups(up, memory)
    mem = program.memories[memory]
    cache = ConflictCache(up.iterators)
    sizes = [len(g) for g in groups]
    naive_opts = SolverOptions(transform_level="basic")
    found: Optional[BankingSolution] = None
    ell = max(sizes) if sizes else 1
    for alpha in (linearize(mem.dims),) + tuple(
        tuple(1 if i == d else 0 for i in range(mem.n)) for d in range(mem.n)
    ):
        for N in range(max(1, -(-ell // mem.ports)), 8 * ell + 2):
            geo = FlatGeometry(N=N, B=1, alpha=alpha, P=(1,) * mem.n)
            ok = True
            worst = 1
            for g in groups:
                edges = flat_conflict_edges(list(g), geo, cache)
                clique = _max_conflict_clique(len(g), edges)
                worst = max(worst, clique)
                if clique > mem.ports:
                    ok = False
                    break
            if ok:
                from .geometry import propose_P
                P = propose_P(mem, N, 1, alpha)[0]
                geoP = FlatGeometry(N=N, B=1, alpha=alpha, P=P)
                found = _attach_flat(groups, mem, geoP, P, up.iterators,
                                     worst, naive_opts)
                break
        if found:
            break
    dt = time.perf_counter() - t0
    sols = [found] if found else []
    return _as_plan(memory, groups, sols, dt, naive_opts, "spatial")


def run_merlin_emulation(program: Program, memory: str) -> BankingPlan:
    """Bounding-box stencil template with raw arithmetic (see module doc)."""
    t0 = time.perf_counter()
    up = unroll(program)
    groups = build_groups(up, memory)
    mem = program.memories[memory]
    cache = ConflictCache(up.iterators)
    naive_opts = SolverOptions(transform_level="basic")
    # bounding box of constant offsets per dimension across the largest group
    big = max(groups, key=len) if groups else None
    spans = []
    for d in range(mem.n):
        consts = sorted({a.exprs[d].const for a in big} if big else {0})
        spans.append(max(2 if mem.n > 1 else 1, consts[-1] - consts[0] + 1))
    found = None
    for scale in range(0, 4):
        Ns = tuple(min(mem.dims[d], spans[d] + scale) for d in range(mem.n))
        if int(np.prod(Ns)) < 1:
            continue
        geo = MultiDimGeometry(Ns=Ns, Bs=(1,) * mem.n, alphas=(1,) * mem.n)
        ok = True
        worst = 1
        for g in groups:
            edges = multidim_conflict_edges(list(g), geo, cache)
            clique = _max_conflict_clique(len(g), edges)
            worst = max(worst, clique)
            if clique > mem.ports:
                ok = False
                break
        if ok:
            found = _attach_multidim(groups, mem, geo, up.iterators, worst,
                                     naive_opts, note="merlin-bbox")
            break
    if found is None:
        # fall back to whatever first-valid finds
        return run_spatial_firstvalid(program, memory)
    dt = time.perf_counter() - t0
    return _as_plan(memory, groups, [found], dt, naive_opts, "merlin")


SYSTEMS = {
    "baseline": run_baseline_wang14,
    "spatial": run_spatial_firstvalid,
    "merlin": run_merlin_emulation,
    "ours": run_ours,
}
