"""Resource-saving datapath transforms for bank-resolution arithmetic (Sec 3.4).

The bank-resolution equations (Eq. 1-2) are built from ``*C``, ``/C``, ``%C``
with solver-chosen constants.  Because the solver is free to steer toward
friendly constants, these rewrites remove multipliers / dividers entirely:

* power-of-two:       shift / mask                                   (free)
* Crandall:           ``x % (2^n - 1)`` as shift-add folds           (adders)
* Eq. 6 extension:    ``x % M2`` with ``M2 * k = 2^n - 1`` via Crandall on
                      the Mersenne then a k-wide one-hot mux          (mux)
* binary decomposition: ``x * C`` as a signed-digit (NAF) sum of shifts when
                      the decomposition has at most R nonzero digits

Each rewrite produces a node graph in a tiny expression IR that can be
(1) cost-annotated with an FPGA resource proxy (LUT/FF/DSP) *and* a TPU
scalar-op count, (2) interpreted for exactness testing, and (3) lowered to
``jnp`` ops so the very same transformed arithmetic runs inside our Pallas
kernels.  TPU relevance: the VPU has no integer divide -- XLA lowers
``//C``/``%C`` to long magic-multiply sequences -- so Crandall/NAF rewrites
shorten the hot index-arithmetic path on TPU too, not only on FPGAs.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Expression IR
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Node:
    op: str                      # var|const|add|sub|shl|shr|and|mul|div|mod|ge|select
    args: Tuple["Node", ...] = ()
    value: int = 0               # const value / shift amount / mask / divisor
    name: str = ""
    width: int = 0               # datapath bits (0 = inherit the call width)

    def w(self, bits: int) -> "Node":
        object.__setattr__(self, "width", int(bits))  # frozen-safe annotate
        return self

    def __add__(self, o):  return Node("add", (self, _n(o)))
    def __sub__(self, o):  return Node("sub", (self, _n(o)))
    def __lshift__(self, k): return Node("shl", (self,), value=int(k))
    def __rshift__(self, k): return Node("shr", (self,), value=int(k))
    def __and__(self, m):  return Node("and", (self,), value=int(m))


def _n(x) -> Node:
    return x if isinstance(x, Node) else Node("const", value=int(x))


def var(name: str) -> Node:
    return Node("var", name=name)


def const(v: int) -> Node:
    return Node("const", value=int(v))


def ge(a: Node, b: Node) -> Node:
    return Node("ge", (a, _n(b)))


def select(c: Node, t: Node, f: Node) -> Node:
    return Node("select", (c, _n(t), _n(f)))


def raw_mul(a: Node, c: int) -> Node:
    return Node("mul", (a,), value=int(c))


def raw_div(a: Node, c: int) -> Node:
    return Node("div", (a,), value=int(c))


def raw_mod(a: Node, c: int) -> Node:
    return Node("mod", (a,), value=int(c))


# ---------------------------------------------------------------------------
# Constant classification (the solver steers toward these -- Sec 3.3/3.4)
# ---------------------------------------------------------------------------


def is_pow2(c: int) -> bool:
    return c > 0 and (c & (c - 1)) == 0


def mersenne_exp(c: int) -> Optional[int]:
    """n if c == 2^n - 1 (n >= 1), else None."""
    if c < 1:
        return None
    n = c.bit_length()
    return n if (1 << n) - 1 == c else None


def mersenne_multiple(c: int, R: int = 16) -> Optional[Tuple[int, int]]:
    """(n, k) with c * k == 2^n - 1 for 1 < k < R (paper Eq. 6), else None."""
    for n in range(2, 40):
        M = (1 << n) - 1
        if M % c == 0:
            k = M // c
            if 1 < k < R:
                return n, k
    return None


def naf_digits(c: int) -> List[Tuple[int, int]]:
    """Non-adjacent-form signed-digit decomposition: c = sum s_i * 2^{e_i}."""
    digits = []
    e = 0
    while c != 0:
        if c & 1:
            s = 2 - (c % 4)  # +1 if c%4==1 else -1
            digits.append((s, e))
            c -= s
        c >>= 1
        e += 1
    return digits


def transform_friendliness(c: int, R_mul: int = 2, R_mod: int = 16) -> int:
    """Priority score for solver constants (lower = cheaper in hardware)."""
    if c <= 1 or is_pow2(c):
        return 0
    if mersenne_exp(c) is not None:
        return 1
    if len(naf_digits(c)) <= R_mul:
        return 1
    if mersenne_multiple(c, R_mod) is not None:
        return 2
    return 5


# ---------------------------------------------------------------------------
# Rewrites
# ---------------------------------------------------------------------------


def mul_const(x: Node, c: int, R: int = 4, level: str = "full") -> Node:
    """x * c via signed-digit shift-adds when the NAF has <= R digits.

    ``level='basic'`` models ordinary codegen: power-of-two strength
    reduction only (every HLS tool does this); the NAF/Mersenne rewrites are
    the paper's Sec-3.4 contribution and need ``level='full'``.
    """
    if c == 0:
        return const(0)
    neg = c < 0
    c = abs(c)
    if c == 1:
        out = x
    elif is_pow2(c):
        out = x << int(math.log2(c))
    elif level != "full":
        out = raw_mul(x, c)
    else:
        digits = naf_digits(c)
        if len(digits) <= R:
            out = None
            for s, e in digits:
                term = x << e if e else x
                if out is None:
                    out = term if s > 0 else const(0) - term
                else:
                    out = out + term if s > 0 else out - term
        else:
            out = raw_mul(x, c)
    return const(0) - out if neg else out


def _crandall_mod_mersenne(x: Node, n: int, in_bits: int = 32) -> Node:
    """x mod (2^n - 1) by folding high bits into low bits (Crandall)."""
    M = (1 << n) - 1
    r = x
    bits = in_bits
    while bits > n + 1:
        # r < 2^bits  ->  (r & M) + (r >> n) < 2^n + 2^(bits-n)
        new_bits = max(n, bits - n) + 1
        r = ((r & M) + (r >> n)).w(new_bits)
        bits = new_bits
    if bits > n:
        r = ((r & M) + (r >> n)).w(n + 1)  # now r <= 2^n
    # one conditional subtract handles r in {M, 2^n}
    return select(ge(r, const(M)).w(n + 1), (r - M).w(n), r).w(n)


def mod_const(x: Node, c: int, in_bits: int = 32, R: int = 16,
              level: str = "full") -> Node:
    if c == 1:
        return const(0)
    if is_pow2(c):
        return x & (c - 1)
    if level != "full":
        return raw_mod(x, c)
    n = mersenne_exp(c)
    if n is not None:
        return _crandall_mod_mersenne(x, n, in_bits)
    nk = mersenne_multiple(c, R)
    if nk is not None:
        n, k = nk
        # Eq. 6:  x mod c == (x mod (2^n - 1)) mod c, then the inner value is
        # < 2^n so the outer mod is a k-wide one-hot subtract-mux.  Ascending
        # j so the largest satisfied threshold wins.
        r = _crandall_mod_mersenne(x, n, in_bits)
        out = r
        for j in range(1, k):
            out = select(ge(r, const(j * c)), r - (j * c), out)
        return out
    return raw_mod(x, c)


def div_const(x: Node, c: int, in_bits: int = 32, R: int = 16,
              level: str = "full") -> Node:
    if c == 1:
        return x
    if is_pow2(c):
        return x >> int(math.log2(c))
    if level != "full":
        return raw_div(x, c)
    n = mersenne_exp(c)
    if n is not None:
        # x div (2^n - 1): geometric-series estimate q0 = sum_i (x >> i*n)
        # undershoots floor(x/M) by at most (#terms + 1); fix with that many
        # conditional subtract/increment stages.  q*M == (q<<n) - q: no DSPs.
        q = x >> n
        shift = 2 * n
        terms = 1
        while shift < in_bits:
            q = q + (x >> shift)
            shift += n
            terms += 1
        r = x - ((q << n) - q)
        for _ in range(terms + 1):
            cond = ge(r, const(c))
            q = select(cond, q + 1, q)
            r = select(cond, r - c, r)
        return q
    nk = mersenne_multiple(c, R)
    if nk is not None:
        # x div c = (x div M) * k + (x mod M) div c   with M = c*k Mersenne
        n, k = nk
        M = (1 << n) - 1
        qM = div_const(x, M, in_bits, R)
        rM = mod_const(x, M, in_bits, R)
        qk = const(0)
        for j in range(1, k):
            qk = select(ge(rM, const(j * c)), const(j), qk)
        return mul_const(qM, k, R=4) + qk
    return raw_div(x, c)


# ---------------------------------------------------------------------------
# Interpreters: evaluate / cost / lower-to-jnp
# ---------------------------------------------------------------------------


def evaluate(node: Node, env: Dict[str, int],
             _memo: Optional[Dict[int, int]] = None) -> int:
    """DAG interpreter (memoized: rewrites share subexpressions heavily)."""
    memo = _memo if _memo is not None else {}
    key = id(node)
    if key in memo:
        return memo[key]
    op = node.op
    if op == "var":
        out = int(env[node.name])
    elif op == "const":
        out = node.value
    else:
        a = evaluate(node.args[0], env, memo)
        if op == "shl":
            out = a << node.value
        elif op == "shr":
            out = a >> node.value
        elif op == "and":
            out = a & node.value
        elif op == "mul":
            out = a * node.value
        elif op == "div":
            out = a // node.value
        elif op == "mod":
            out = a % node.value
        else:
            b = evaluate(node.args[1], env, memo)
            if op == "add":
                out = a + b
            elif op == "sub":
                out = a - b
            elif op == "ge":
                out = int(a >= b)
            elif op == "select":
                out = b if a else evaluate(node.args[2], env, memo)
            else:
                raise ValueError(op)
    memo[key] = out
    return out


@dataclass
class Cost:
    """FPGA proxy + TPU scalar-op cost of an op graph."""

    lut: float = 0.0
    ff: float = 0.0
    dsp: int = 0
    tpu_ops: int = 0

    def __add__(self, o: "Cost") -> "Cost":
        return Cost(self.lut + o.lut, self.ff + o.ff, self.dsp + o.dsp,
                    self.tpu_ops + o.tpu_ops)


_W = 16  # default address-path width for costing


def _op_cost(op: str, w: int = _W) -> Cost:
    if op in ("var", "const", "shl", "shr"):
        return Cost(0, 0, 0, 0 if op in ("var", "const") else 1)
    if op == "and":
        return Cost(0, 0, 0, 1)  # const mask == wiring on FPGA
    if op in ("add", "sub"):
        return Cost(w, w, 0, 1)
    if op == "ge":
        return Cost(w / 2, 0, 0, 1)
    if op == "select":
        return Cost(w / 2, 0, 0, 1)
    if op == "mul":  # un-transformed constant multiply -> DSP
        return Cost(w, w, max(1, (w + 17) // 18), 2)
    if op in ("div", "mod"):  # vendor divider IP / XLA magic-number sequence
        return Cost(4 * w, 2 * w, max(1, (w + 17) // 18), 8)
    raise ValueError(op)


def cost(node: Node, w: int = _W,
         _seen: Optional[Dict[int, Cost]] = None) -> Cost:
    seen = _seen if _seen is not None else {}
    key = id(node)
    if key in seen:
        return Cost()  # shared subexpression counted once (CSE)
    seen[key] = _op_cost(node.op, node.width or w)
    total = seen[key]
    for a in node.args:
        total = total + cost(a, w, seen)
    return total


def _lower_graph(node: Node, const_fn: Callable,
                 where_fn: Callable) -> Callable:
    """Shared DAG interpreter behind ``lower_jnp`` / ``lower_np``: one op
    dispatch, parameterized by the backend's const constructor and select.

    Memoized over the DAG so shared subexpressions trace once (the rewrites
    produce heavy sharing; naive recursion is exponential)."""

    def run(n: Node, env, memo):
        key = id(n)
        if key in memo:
            return memo[key]
        op = n.op
        if op == "var":
            out = env[n.name]
        elif op == "const":
            out = const_fn(n.value)
        else:
            a = run(n.args[0], env, memo)
            if op == "shl":
                out = a << n.value
            elif op == "shr":
                out = a >> n.value
            elif op == "and":
                out = a & n.value
            elif op == "mul":
                out = a * n.value
            elif op == "div":
                out = a // n.value
            elif op == "mod":
                out = a % n.value
            else:
                b = run(n.args[1], env, memo)
                if op == "add":
                    out = a + b
                elif op == "sub":
                    out = a - b
                elif op == "ge":
                    out = a >= b
                elif op == "select":
                    out = where_fn(a, b, run(n.args[2], env, memo))
                else:
                    raise ValueError(op)
        memo[key] = out
        return out

    def fn(**env):
        return run(node, env, {})

    return fn


def lower_jnp(node: Node) -> Callable:
    """Compile the op graph to a jnp-traceable python function f(**vars)."""
    import jax.numpy as jnp

    return _lower_graph(node, jnp.int32, jnp.where)


def lower_np(node: Node) -> Callable:
    """Compile the op graph to a vectorized numpy function f(**vars)."""
    import numpy as np

    return _lower_graph(node, np.int64, np.where)


def count_raw_ops(node: Node) -> Dict[str, int]:
    """Histogram of untransformed mul/div/mod left in a graph."""
    out: Dict[str, int] = {"mul": 0, "div": 0, "mod": 0}
    seen = set()

    def walk(n: Node):
        if id(n) in seen:
            return
        seen.add(id(n))
        if n.op in out:
            out[n.op] += 1
        for a in n.args:
            walk(a)

    walk(node)
    return out


# ---------------------------------------------------------------------------
# Bank-resolution circuit builder (Eq. 1-2 under the transforms)
# ---------------------------------------------------------------------------


def build_flat_resolution(
    N: int, B: int, alpha: Tuple[int, ...], P: Tuple[int, ...],
    dims: Tuple[int, ...], in_bits: int = 32, level: str = "full",
) -> Tuple[Node, Node]:
    """(BA, BO) op graphs for a flat geometry, inputs x0..x{n-1}."""
    xs = [var(f"x{i}") for i in range(len(dims))]
    y = None
    for xi, a in zip(xs, alpha):
        if a == 0:
            continue
        t = mul_const(xi, a, level=level)
        y = t if y is None else y + t
    if y is None:
        y = const(0)
    ba = mod_const(div_const(y, B, in_bits, level=level), N, in_bits, level=level)
    off = None
    for i in range(len(dims)):
        stride = 1
        for j in range(i + 1, len(dims)):
            stride *= -(-dims[j] // P[j])
        term = mul_const(div_const(xs[i], P[i], in_bits, level=level), stride,
                         level=level)
        off = term if off is None else off + term
    bo = mul_const(off, B, level=level) + mod_const(y, B, in_bits, level=level)
    return ba, bo


def build_multidim_resolution(
    Ns: Tuple[int, ...], Bs: Tuple[int, ...], alphas: Tuple[int, ...],
    dims: Tuple[int, ...], in_bits: int = 32, level: str = "full",
) -> Tuple[Tuple[Node, ...], Node]:
    """(per-dim BA nodes, BO node) for a multidimensional geometry."""
    bas = []
    coords = []
    sizes = []
    for d, (n_, b_, a_) in enumerate(zip(Ns, Bs, alphas)):
        x = var(f"x{d}")
        y = mul_const(x, a_, level=level)
        bas.append(mod_const(div_const(y, b_, in_bits, level=level), n_,
                             in_bits, level=level))
        blocks = -(-dims[d] * a_ // b_)
        per_bank = -(-blocks // n_)
        block = div_const(y, b_ * n_, in_bits, level=level)
        within = mod_const(y, b_, in_bits, level=level)
        coords.append(mul_const(block, b_, level=level) + within)
        sizes.append(per_bank * b_)
    bo = None
    for c, s in zip(coords, sizes):
        bo = c if bo is None else mul_const(bo, s, level=level) + c
    return tuple(bas), bo
