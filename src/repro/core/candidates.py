"""Shardable candidate-space solver pipeline (paper Sec 3.3-3.5).

The paper's headline win is searching a large (N, B, alpha, P) candidate
space quickly; the monolithic ``solve`` did enumeration, validity
checking, and scheme evaluation in one nested loop, so one cold solve
was one unsplittable unit of work.  This module separates the three:

* :class:`CandidateSpace` **enumerates** pruned candidate descriptors --
  flat (alpha, B, N) tuples, multidimensional Ns-combos, and per-factor
  bank-by-duplication sub-searches -- *without* evaluating any of them.
  Enumeration is cheap (loop headers only) and deterministic; every
  candidate gets a global enumeration index.
* ``space.shards(k)`` **partitions** enumeration into ``k``
  self-contained :class:`SolveShard` s.  A shard carries its candidate
  slice plus the shared conflict-analysis inputs; shards of one space
  share one :class:`~repro.core.geometry.ConflictCache` in-process and
  pickle cleanly for cross-process evaluation (the cache is rebuilt on
  the other side).
* :func:`evaluate` turns a shard into a **SolutionStream**: a generator
  of :class:`EvaluatedCandidate` s, yielding scored
  :class:`~repro.core.solver.BankingSolution` s incrementally instead of
  returning only at the end.
* :class:`SolutionReducer` **merges** streams from any number of shards:
  it keeps a ranked best-so-far (monotone in score -- what
  ``PlanTicket.best_so_far`` serves), dedupes identical schemes, and its
  ``finalize()`` reproduces the monolithic search's truncation budgets
  *exactly*, so the merged result for any shard count equals the
  pre-redesign ``solve`` output (the shard-equivalence property).

Truncation equivalence: the monolithic loops stopped early -- flat and
multidim searches after ``max_solutions`` emitted schemes, duplication
sub-searches after their own sub-budget.  Each contiguous run of
candidates sharing one such budget is a :class:`Section`; the reducer
walks every section in enumeration order, admitting a candidate's batch
iff the emitted count *before* it is below the section cap -- precisely
the monolithic rule.  Shards stop a section early once their own
emissions alone prove the global cap is reached (their later candidates
are provably beyond the cut), and an in-process reducer additionally
publishes the exact cut so concurrent shards skip dead work.
"""

from __future__ import annotations

import itertools
import math
import pickle
import threading
import time
import zlib
from dataclasses import dataclass, field
from functools import reduce
from typing import Callable, Dict, Iterator as TIterator, List, Optional, \
    Sequence, Tuple

import numpy as np

from .geometry import (
    ConflictCache,
    FlatGeometry,
    MultiDimGeometry,
    _max_conflict_clique,
    flat_conflict_edges,
    multidim_conflict_edges,
    propose_P,
)
from .polytope import AccessGroup, Iterator, MemorySpec


@dataclass(frozen=True)
class Candidate:
    """One un-evaluated point of the search space.

    ``index`` is the global enumeration order (the monolithic loop
    order); ``section`` names the truncation budget it falls under.
    Flat and duplication candidates carry (alpha, B, N); multidim
    candidates carry the per-dimension Ns (both blocking variants of one
    Ns-combo evaluate together, mirroring the monolithic inner loop).
    """

    index: int
    section: int
    kind: str                       # "flat" | "multidim"
    alpha: Optional[Tuple[int, ...]] = None
    B: int = 1
    N: int = 0
    Ns: Optional[Tuple[int, ...]] = None


@dataclass(frozen=True)
class Section:
    """A contiguous candidate run sharing one truncation budget.

    ``cap`` bounds emitted solutions (the monolithic early exit);
    ``keep`` additionally bounds *validated* solutions (duplication:
    the sub-search emits up to ``cap`` but only the first ``keep``
    fully-duplicate-valid schemes survive); ``D`` > 1 marks a
    bank-by-duplication section evaluated against its own group split.
    """

    name: str
    start: int
    stop: int
    cap: int
    keep: Optional[int] = None
    D: int = 1


@dataclass
class EvaluatedCandidate:
    """One candidate's evaluation: the attached solutions (in proposal
    order) and, for duplication candidates, whether the geometry is
    conflict-free for every duplicate's subset.  Invalid candidates
    yield an empty batch -- the reducer needs them to advance its
    in-order walk."""

    index: int
    solutions: List = field(default_factory=list)
    valid_mask: Tuple[bool, ...] = ()


class CandidateSpace:
    """Enumerated, pruned candidate descriptors for one banking problem.

    Construction runs only the paper's *pruning* (Sec 3.3): alpha
    normalization, (alpha, B) co-primality, N-ordering heuristics, the
    multidim combo budget, and the duplication applicability gates.  No
    conflict analysis, no resolution lowering -- candidates are tuples.
    """

    def __init__(self, mem: MemorySpec, groups: List[AccessGroup],
                 iters: Dict[str, Iterator], opts=None):
        from .solver import SolverOptions

        self.mem = mem
        self.groups = groups
        self.iters = iters
        self.opts = opts or SolverOptions()
        self.candidates: List[Candidate] = []
        self.sections: List[Section] = []
        # per-section evaluation context: (groups, opts, note, dup subsets)
        self._section_groups: List[List[AccessGroup]] = []
        self._section_opts: List = []
        self._section_subsets: List[Optional[List[AccessGroup]]] = []
        self._cache: Optional[ConflictCache] = None
        self._enumerate()

    # -- shared conflict analysis ------------------------------------------------
    @property
    def cache(self) -> ConflictCache:
        """The conflict cache every in-process shard of this space shares
        (lazily rebuilt after pickling -- caches don't cross processes)."""
        if self._cache is None:
            self._cache = ConflictCache(self.iters)
        return self._cache

    def __getstate__(self):
        state = dict(self.__dict__)
        state["_cache"] = None
        return state

    def __len__(self) -> int:
        return len(self.candidates)

    # -- enumeration -------------------------------------------------------------
    def _add_section(self, name: str, groups, opts, cap: int,
                     keep: Optional[int] = None, D: int = 1,
                     subsets=None) -> int:
        sid = len(self.sections)
        self.sections.append(Section(name=name, start=len(self.candidates),
                                     stop=len(self.candidates), cap=cap,
                                     keep=keep, D=D))
        self._section_groups.append(groups)
        self._section_opts.append(opts)
        self._section_subsets.append(subsets)
        return sid

    def _close_section(self, sid: int) -> None:
        sec = self.sections[sid]
        self.sections[sid] = Section(name=sec.name, start=sec.start,
                                     stop=len(self.candidates), cap=sec.cap,
                                     keep=sec.keep, D=sec.D)

    def _enumerate(self) -> None:
        from .solver import SolverOptions, alpha_candidates, n_candidates

        mem, groups, opts = self.mem, self.groups, self.opts

        def flat_tuples(for_groups, for_opts):
            sizes = [len(g) for g in for_groups]
            for alpha in alpha_candidates(mem, for_groups, for_opts):
                a_gcd = reduce(math.gcd, [abs(x) for x in alpha if x], 0)
                for B in for_opts.b_candidates:
                    if B > 1 and math.gcd(a_gcd, B) != 1:
                        continue  # co-primality pruning (paper Sec 3.3)
                    for N in n_candidates(sizes, mem.ports, for_opts):
                        yield tuple(alpha), B, N

        # flat hyperplane section (always present)
        sid = self._add_section("flat", groups, opts, cap=opts.max_solutions)
        for alpha, B, N in flat_tuples(groups, opts):
            self.candidates.append(Candidate(
                index=len(self.candidates), section=sid, kind="flat",
                alpha=alpha, B=B, N=N))
        self._close_section(sid)

        # multidimensional (orthogonal-lattice) section
        if opts.allow_multidim and mem.n >= 2:
            sid = self._add_section("multidim", groups, opts,
                                    cap=opts.max_solutions)
            for Ns in self._multidim_combos():
                self.candidates.append(Candidate(
                    index=len(self.candidates), section=sid,
                    kind="multidim", Ns=Ns))
            self._close_section(sid)

        # bank-by-duplication sections: one flat sub-search per factor D
        if opts.allow_duplication and groups:
            read_groups = [g for g in groups
                           if not any(a.is_write for a in g)]
            big = max(read_groups, key=len) if read_groups else None
            if big is not None and len(big) >= 4:
                others = [g for g in groups if g is not big]
                for D in opts.duplication_factors:
                    if len(big) < 2 * D:
                        continue
                    subsets = [AccessGroup(list(big)[i::D])
                               for i in range(D)]
                    worst_subset = max(subsets, key=len)
                    sub_groups = others + [worst_subset]
                    sub_opts = SolverOptions(
                        max_solutions=8, n_budget=24,
                        transform_level=opts.transform_level,
                        allow_multidim=False, allow_duplication=False,
                    )
                    sid = self._add_section(
                        f"dup x{D}", sub_groups, sub_opts,
                        cap=sub_opts.max_solutions, keep=2, D=D,
                        subsets=subsets)
                    for alpha, B, N in flat_tuples(sub_groups, sub_opts):
                        self.candidates.append(Candidate(
                            index=len(self.candidates), section=sid,
                            kind="flat", alpha=alpha, B=B, N=N))
                    self._close_section(sid)

    def _multidim_combos(self) -> List[Tuple[int, ...]]:
        """Ns combos in monolithic order, under the combo budget; the
        static product-range filter runs here (it needs no evaluation),
        and *skipped combos still count against the budget* -- exactly
        the monolithic accounting."""
        from .solver import _dim_value_counts

        mem, groups, opts = self.mem, self.groups, self.opts
        ell = max((len(g) for g in groups), default=1)
        cap = max(4 * ell, 8)
        per_dim: List[List[int]] = []
        for d in range(mem.n):
            k = _dim_value_counts(groups, d)
            cands = {1, k}
            cands.add(1 << max(0, (k - 1)).bit_length())
            if k + 1 <= mem.dims[d]:
                cands.add(k + 1)
            per_dim.append(sorted(c for c in cands
                                  if 1 <= c <= max(mem.dims[d], 1)))
        out: List[Tuple[int, ...]] = []
        combos = 0
        for Ns in itertools.product(*per_dim):
            combos += 1
            if combos > opts.multidim_combo_budget:
                break
            prod = int(np.prod(Ns))
            if prod > cap or prod < 2:
                continue
            out.append(tuple(Ns))
        return out

    # -- partitioning ------------------------------------------------------------
    def shards(self, k: int, *, interleave: bool = True) -> List["SolveShard"]:
        """Split enumeration into ``k`` self-contained shards.

        ``interleave=True`` (default) deals candidates round-robin so
        every shard sees early -- typically denser -- regions of the
        space: the right shape for a fixed worker pool.
        ``interleave=False`` cuts contiguous index ranges: the right
        shape for many small work units fed to a pool with early
        termination (see :func:`evaluate_parallel`).
        Every candidate lands in exactly one shard.
        """
        k = max(1, min(int(k), max(1, len(self.candidates))))
        if interleave:
            slices = [self.candidates[i::k] for i in range(k)]
        else:
            n = len(self.candidates)
            bounds = [round(i * n / k) for i in range(k + 1)]
            slices = [self.candidates[bounds[i]:bounds[i + 1]]
                      for i in range(k)]
        return [SolveShard(space=self, candidates=s, shard_index=i,
                           num_shards=k)
                for i, s in enumerate(slices) if s]

    # -- adaptive fan-out --------------------------------------------------------
    def estimated_evaluations(self) -> int:
        """Expected evaluation work, from enumeration counts alone.

        Each section's walk stops once ``cap`` solutions are emitted, and
        a valid flat candidate emits up to two P-proposals -- so a
        section costs at most its full length, and rarely much more than
        a few times its cap.  The estimate is
        ``sum(min(len(section), 4 * cap))``: cheap (no evaluation), and
        the quantity the per-ticket fan-out should be sized from.
        """
        return sum(min(s.stop - s.start, 4 * max(s.cap, 1))
                   for s in self.sections)

    def suggested_shards(self, max_shards: int, *,
                         min_per_shard: int = 48) -> int:
        """Adaptive fan-out: how many shards this space is worth.

        Sized from :meth:`estimated_evaluations` so a shard amortizes its
        dispatch overhead over at least ``min_per_shard`` candidate
        evaluations; small spaces return 1 and skip fan-out entirely.
        """
        est = self.estimated_evaluations()
        return max(1, min(int(max_shards), est // max(1, min_per_shard)))


@dataclass
class SolveShard:
    """A self-contained slice of one candidate space.

    Carries its candidates plus (via ``space``) the shared problem
    inputs -- memory, groups, iterators, options -- so it can be
    evaluated on any worker, in or out of process.  In-process shards
    share the space's :class:`ConflictCache`; a pickled shard rebuilds
    its own on first use.
    """

    space: CandidateSpace
    candidates: List[Candidate]
    shard_index: int = 0
    num_shards: int = 1

    def __len__(self) -> int:
        return len(self.candidates)


# ---------------------------------------------------------------------------
# Evaluation: shard -> SolutionStream
# ---------------------------------------------------------------------------


def _eval_flat(space: CandidateSpace, cand: Candidate,
               cache: ConflictCache) -> EvaluatedCandidate:
    from .solver import _attach_flat

    sec = space.sections[cand.section]
    groups = space._section_groups[cand.section]
    opts = space._section_opts[cand.section]
    mem, iters = space.mem, space.iters
    geo = FlatGeometry(N=cand.N, B=cand.B, alpha=cand.alpha,
                       P=(1,) * mem.n)
    worst = 1
    for g in groups:
        edges = flat_conflict_edges(list(g), geo, cache)
        clique = _max_conflict_clique(len(g), edges)
        worst = max(worst, clique)
        if clique > mem.ports:
            return EvaluatedCandidate(index=cand.index)
    note = f"dup x{sec.D}" if sec.D > 1 else ""
    sols = []
    for P in propose_P(mem, cand.N, cand.B, cand.alpha)[:2]:
        geoP = FlatGeometry(N=cand.N, B=cand.B, alpha=cand.alpha, P=P)
        sols.append(_attach_flat(groups, mem, geoP, P, iters, worst, opts,
                                 duplicates=sec.D, note=note))
    if sec.D <= 1:
        return EvaluatedCandidate(index=cand.index, solutions=sols,
                                  valid_mask=(True,) * len(sols))
    # bank-by-duplication: the SAME geometry must be conflict-free for
    # EVERY duplicate's subset (writes broadcast to all duplicates).  The
    # non-duplicated groups were verified once above -- only the subsets
    # need checking, and validity depends on (N, B, alpha) alone, so one
    # verdict covers every P proposal.
    dup_ok = True
    for sub in space._section_subsets[cand.section]:
        edges = flat_conflict_edges(list(sub), geo, cache)
        if _max_conflict_clique(len(sub), edges) > mem.ports:
            dup_ok = False
            break
    return EvaluatedCandidate(index=cand.index, solutions=sols,
                              valid_mask=(dup_ok,) * len(sols))


def _eval_multidim(space: CandidateSpace, cand: Candidate,
                   cache: ConflictCache) -> EvaluatedCandidate:
    from .solver import _attach_multidim

    mem, groups, iters, opts = (space.mem, space.groups, space.iters,
                                space.opts)
    sols = []
    for Bs in ((1,) * mem.n, (2,) + (1,) * (mem.n - 1)):
        geo = MultiDimGeometry(Ns=cand.Ns, Bs=Bs, alphas=(1,) * mem.n)
        worst = 1
        ok = True
        for g in groups:
            edges = multidim_conflict_edges(list(g), geo, cache)
            clique = _max_conflict_clique(len(g), edges)
            worst = max(worst, clique)
            if clique > mem.ports:
                ok = False
                break
        if ok:
            sols.append(_attach_multidim(groups, mem, geo, iters, worst,
                                         opts))
    return EvaluatedCandidate(index=cand.index, solutions=sols,
                              valid_mask=(True,) * len(sols))


def evaluate(shard: SolveShard,
             gate: Optional["SolutionReducer"] = None
             ) -> TIterator[EvaluatedCandidate]:
    """Evaluate one shard, yielding an :class:`EvaluatedCandidate` per
    candidate in ascending index order -- a *SolutionStream*.

    Two early exits keep sharded work close to the monolithic search's:

    * **local stop**: once this shard alone has emitted a section's full
      ``cap``, the global emission count at that index is >= cap too, so
      all later candidates of the section are provably beyond the final
      cut -- skip them (no yield; the reducer never needs them).
    * **gate stop**: an in-process :class:`SolutionReducer` passed as
      ``gate`` publishes each section's exact cut as its in-order walk
      reaches the cap; candidates past a published cut are skipped.
    """
    space = shard.space
    cache = space.cache
    emitted: Dict[int, int] = {}
    for cand in shard.candidates:
        if gate is not None and gate.cancelled:
            return
        sec = space.sections[cand.section]
        if emitted.get(cand.section, 0) >= sec.cap:
            continue                       # local stop: beyond the cut
        if gate is not None:
            cut = gate.stop_index(cand.section)
            if cut is not None and cand.index > cut:
                continue                   # gate stop: exact cut known
        if cand.kind == "flat":
            ev = _eval_flat(space, cand, cache)
        else:
            ev = _eval_multidim(space, cand, cache)
        if ev.solutions:
            emitted[cand.section] = (emitted.get(cand.section, 0)
                                     + len(ev.solutions))
        yield ev


# ---------------------------------------------------------------------------
# Reduction: merge SolutionStreams, rank best-so-far, finalize
# ---------------------------------------------------------------------------


class _SectionState:
    __slots__ = ("idx", "sec", "next", "count", "kept", "cut", "done")

    def __init__(self, idx: int, sec: Section):
        self.idx = idx
        self.sec = sec
        self.next = sec.start
        self.count = 0
        self.kept = 0
        self.cut: Optional[int] = None
        self.done = sec.start >= sec.stop


class SolutionReducer:
    """Merges evaluation streams from any number of shards.

    Thread-safe ``add()`` accepts :class:`EvaluatedCandidate` s in any
    order; an in-order walk per section admits solutions under the
    monolithic truncation rule (batch admitted iff the section's emitted
    count *before* it is below the cap), dedupes identical schemes,
    scores each admitted solution, and keeps a monotone best-so-far.
    ``finalize()`` returns the admitted list -- for any shard count,
    byte-for-byte the monolithic ``solve`` output order (minus exact
    duplicates, which a stable rank would never prefer anyway).

    The reducer doubles as the evaluation *gate*: once a section's walk
    reaches its cap the exact cut index is published, letting concurrent
    shards skip provably-dead candidates.
    """

    def __init__(self, space: CandidateSpace,
                 scorer: Optional[Callable] = None):
        self.space = space
        self.scorer = scorer
        self._lock = threading.Lock()
        self._results: Dict[int, EvaluatedCandidate] = {}
        self._sections = [_SectionState(i, s)
                          for i, s in enumerate(space.sections)]
        # per-section admitted lists: arrival order may interleave
        # sections, but the final order must be the monolithic one
        # (sections concatenated, index order within each)
        self._admitted: List[List] = [[] for _ in space.sections]
        self._seen: Dict[Tuple, bool] = {}
        self._best = None
        self._best_score = float("inf")
        self._version = 0
        self.promotions = 0
        self.dedup_hits = 0
        self.evaluated = 0
        self._created = time.perf_counter()
        self.first_best_seconds: Optional[float] = None
        self._cancelled = False

    # -- gate protocol (read by evaluate()) --------------------------------------
    @property
    def cancelled(self) -> bool:
        return self._cancelled

    def cancel(self) -> None:
        self._cancelled = True

    def stop_index(self, section: int) -> Optional[int]:
        return self._sections[section].cut

    def cuts(self) -> Dict[int, int]:
        """Snapshot of every published section cut (section index ->
        exact cut index).  A cut is published at most once and never
        moves, so snapshots are monotone -- what the distributed fabric
        broadcasts to in-flight remote workers."""
        with self._lock:
            return {s.idx: s.cut for s in self._sections
                    if s.cut is not None}

    # -- stream intake -----------------------------------------------------------
    def add(self, ev: EvaluatedCandidate) -> None:
        with self._lock:
            self.evaluated += 1
            self._results[ev.index] = ev
            self._advance()

    def _advance(self) -> None:
        for s in self._sections:
            while not s.done:
                ev = self._results.pop(s.next, None)
                if ev is None:
                    break
                if ev.solutions:           # admit: count-before < cap here
                    s.count += len(ev.solutions)
                    for sol, ok in zip(ev.solutions, ev.valid_mask):
                        if not ok:
                            continue
                        if s.sec.keep is not None:
                            if s.kept >= s.sec.keep:
                                continue
                            s.kept += 1
                        self._admit(sol, s)
                    if s.count >= s.sec.cap:
                        s.cut = s.next     # publish the exact cut
                        s.done = True
                s.next += 1
                if s.next >= s.sec.stop:
                    s.done = True

    def _admit(self, sol, s: _SectionState) -> None:
        key = (sol.kind, sol.geometry, sol.duplicates)
        if key in self._seen:
            self.dedup_hits += 1
            return
        self._seen[key] = True
        if self.scorer is not None:
            sol.score = float(self.scorer(sol))
        elif sol.resources is not None:
            sol.score = sol.resources.total.weighted()
        self._admitted[s.idx].append(sol)
        if sol.score < self._best_score:
            self._best = sol
            self._best_score = sol.score
            self._version += 1
            self.promotions += 1
            if self.first_best_seconds is None:
                self.first_best_seconds = (time.perf_counter()
                                           - self._created)

    # -- progressive results -----------------------------------------------------
    def best(self):
        """Best-scored admitted solution so far (never regresses)."""
        return self._best

    def best_with_version(self):
        with self._lock:
            return self._best, self._version

    @property
    def version(self) -> int:
        """Bumps every time best() improves -- consumers poll it to
        promote without re-comparing schemes."""
        return self._version

    def complete(self) -> bool:
        """True once every section's walk is done (cap cut or
        exhausted) -- no further candidate can change the result."""
        with self._lock:
            return all(s.done for s in self._sections)

    def finalize(self) -> List:
        """The merged, truncated, deduped solution list in monolithic
        order.  Call after every shard's stream has drained; sections
        stalled on never-delivered candidates (a shard skipped them past
        a cut) are flushed defensively."""
        with self._lock:
            progress = True
            while progress:
                self._advance()
                progress = False
                for s in self._sections:
                    if not s.done and s.next not in self._results:
                        s.next += 1        # skipped-beyond-cut candidate
                        if s.next >= s.sec.stop:
                            s.done = True
                        progress = True
            self._results.clear()   # beyond-cut leftovers: dead weight
            return [sol for sec in self._admitted for sol in sec]


# ---------------------------------------------------------------------------
# Parallel drivers
# ---------------------------------------------------------------------------


def solve_space(space: CandidateSpace,
                scorer: Optional[Callable] = None,
                reducer: Optional[SolutionReducer] = None) -> List:
    """Single-shard (in-thread) pipeline: enumerate -> evaluate ->
    reduce.  Work-equivalent to the monolithic search thanks to the
    reducer gate publishing each section's cut as it is reached."""
    red = reducer or SolutionReducer(space, scorer=scorer)
    (shard,) = space.shards(1) or [SolveShard(space, [], 0, 1)]
    for ev in evaluate(shard, gate=red):
        red.add(ev)
    return red.finalize()


_POOL_SPACE: Optional[CandidateSpace] = None


def _pool_init(space: CandidateSpace) -> None:
    global _POOL_SPACE
    _POOL_SPACE = space


def _pool_eval(idxs: List[int]) -> List[EvaluatedCandidate]:
    """Evaluate the given candidate indices of the per-process space copy.

    The space (and its conflict cache) persists for the worker process's
    lifetime, so memoized residue analyses carry across work units."""
    return list(evaluate(shard_from_indices(_POOL_SPACE, idxs)))


def evaluate_parallel(space: CandidateSpace, workers: int, *,
                      scorer: Optional[Callable] = None,
                      chunk: int = 24,
                      reducer: Optional[SolutionReducer] = None
                      ) -> SolutionReducer:
    """Evaluate ``space`` across ``workers`` processes, merging into one
    reducer.  Work units are small runs of candidate indices handed out
    in enumeration order, *filtered against the reducer's published
    section cuts at hand-out time*: once a section's cap is provably
    reached, none of its remaining candidates are ever dispatched.
    Total work therefore stays close to the monolithic search's while
    the evaluation wall-clock divides across processes.  Falls back to
    :func:`solve_space` when ``workers <= 1`` or the platform cannot
    fork.
    """
    red = reducer or SolutionReducer(space, scorer=scorer)
    if workers <= 1 or len(space) == 0:
        solve_space(space, reducer=red)
        return red
    import multiprocessing as mp
    from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
    try:
        ctx = mp.get_context("fork")
    except ValueError:             # no fork (non-POSIX): stay in-process
        solve_space(space, reducer=red)
        return red
    cursor = 0

    def next_chunk() -> List[int]:
        """Next ``chunk`` candidate indices still worth evaluating.
        Racy reads of the section states are safe: a cut only ever
        *appears*, so the filter is merely conservative."""
        nonlocal cursor
        idxs: List[int] = []
        while cursor < len(space) and len(idxs) < chunk:
            cand = space.candidates[cursor]
            st = red._sections[cand.section]
            if not (st.done or (st.cut is not None
                                and cand.index > st.cut)):
                idxs.append(cursor)
            cursor += 1
        return idxs

    with ProcessPoolExecutor(max_workers=workers, mp_context=ctx,
                             initializer=_pool_init,
                             initargs=(space,)) as ex:
        pending = set()
        while True:
            while len(pending) < workers * 2:
                idxs = next_chunk()
                if not idxs:
                    break
                pending.add(ex.submit(_pool_eval, idxs))
            if not pending:
                break
            done, pending = wait(pending, return_when=FIRST_COMPLETED)
            for fut in done:
                for ev in fut.result():
                    red.add(ev)
            if red.complete():
                for fut in pending:
                    fut.cancel()
                break
    return red


# ---------------------------------------------------------------------------
# Wire codecs + remote gate (the distributed work-unit/cut protocol)
# ---------------------------------------------------------------------------
#
# A remote solve ships the candidate space ONCE per worker, then leases
# tiny work units (candidate index lists) against it; scored evaluation
# streams flow back and published section cuts flow out.  The codecs are
# pickle-based (solve workers are trusted peers of the service -- do not
# point them at untrusted networks) with zlib framing for the space,
# which dominates the bytes on the wire.

_WIRE_PROTO = pickle.HIGHEST_PROTOCOL


def space_to_wire(space: CandidateSpace) -> bytes:
    """Encode a candidate space for one-shot shipment to a remote
    worker.  The conflict cache is stripped (``__getstate__``); the
    worker rebuilds its own on first use and keeps it for the solve's
    lifetime, so memoized residue analyses span that worker's leases."""
    return zlib.compress(pickle.dumps(space, protocol=_WIRE_PROTO))


def space_from_wire(blob: bytes) -> CandidateSpace:
    return pickle.loads(zlib.decompress(blob))


def events_to_wire(events: Sequence[EvaluatedCandidate]) -> bytes:
    """Encode a batch of evaluation results (scored solutions attached)
    for the worker -> reducer stream."""
    return pickle.dumps(list(events), protocol=_WIRE_PROTO)


def events_from_wire(blob: bytes) -> List[EvaluatedCandidate]:
    return pickle.loads(blob)


def shard_from_indices(space: CandidateSpace,
                       indices: Sequence[int]) -> SolveShard:
    """Materialize a leased work unit (candidate indices) as a
    :class:`SolveShard` over a locally-held space."""
    return SolveShard(space=space,
                      candidates=[space.candidates[i] for i in indices])


class CutGate:
    """``evaluate()`` gate fed by externally published cuts.

    The remote counterpart of passing the :class:`SolutionReducer`
    itself as the gate: the service broadcasts ``reducer.cuts()``
    snapshots over the wire and the worker merges them here, so an
    in-flight remote shard prunes beyond-cut candidates exactly like a
    local one.  Cuts only ever appear (never move), so lock-free reads
    are merely conservative.
    """

    def __init__(self) -> None:
        self._cuts: Dict[int, int] = {}
        self._cancelled = False

    def update(self, cuts: Dict[int, int]) -> None:
        self._cuts.update(cuts)

    def cancel(self) -> None:
        self._cancelled = True

    @property
    def cancelled(self) -> bool:
        return self._cancelled

    def stop_index(self, section: int) -> Optional[int]:
        return self._cuts.get(section)

    def snapshot(self) -> Dict[int, int]:
        return dict(self._cuts)


__all__ = [
    "Candidate",
    "CandidateSpace",
    "CutGate",
    "EvaluatedCandidate",
    "Section",
    "SolutionReducer",
    "SolveShard",
    "evaluate",
    "evaluate_parallel",
    "events_from_wire",
    "events_to_wire",
    "shard_from_indices",
    "solve_space",
    "space_from_wire",
    "space_to_wire",
]
