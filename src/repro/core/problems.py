"""Benchmark banking problems (paper Sec 4, Tables 2-3, Fig. 12).

Eight stencil patterns plus three real-world applications (Smith-Waterman
GACT, SpMV, minibatch SGD), each expressed as a controller-tree Program.
The paper's pattern glyphs are images; the point geometries below follow the
names and the paper's prose (denoise/bicubic are '4-point accesses', sobel is
the full 3x3, motion-* are line patterns, denoise-ur is the unrolled variant).

These drive (a) the Table 2/3 comparisons and (b) the training corpus for
the ML resource estimator (Sec 3.5.2 uses Spatial's regression suite; our
corpus is this suite plus randomized variants -- see core/dataset.py).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from .controller import AccessDecl, Counter, Ctrl, Program, Sched
from .polytope import Affine, MemorySpec

# ---------------------------------------------------------------------------
# Stencils: image SRAM of shape (H, W); row loop r, column loop c (par P).
# One access per pattern point at (r+dr, c+dc); vectorization by P adds
# lane offsets on c.  Ports=2 (true-dual-ported BRAM18).
# ---------------------------------------------------------------------------

STENCIL_POINTS: Dict[str, List[Tuple[int, int]]] = {
    "denoise":   [(0, 0), (-1, 0), (1, 0), (0, -1)],          # 4-point
    "deconv":    [(0, 0), (0, -1), (0, -2), (-1, 0), (-2, 0)],
    "denoise-ur": [(0, 0), (-1, 0), (1, 0), (0, -1)],          # + par 2
    "bicubic":   [(0, 0), (0, 1), (1, 0), (1, 1)],             # 4-point
    "sobel":     [(dr, dc) for dr in (-1, 0, 1) for dc in (-1, 0, 1)],
    "motion-lv": [(-1, 0), (0, 0), (1, 0)],
    "motion-lh": [(0, -2), (0, -1), (0, 0), (0, 1), (0, 2)],
    "motion-c":  [(0, 0), (0, 1), (1, 0), (1, 1)],
}

STENCIL_PAR: Dict[str, int] = {
    "denoise": 1, "deconv": 1, "denoise-ur": 2, "bicubic": 1, "sobel": 1,
    "motion-lv": 2, "motion-lh": 2, "motion-c": 1,
}


def stencil_program(name: str, H: int = None, W: int = 128,
                    par: int = None, ports: int = 2) -> Program:
    """Line-buffer stencil: the on-chip memory holds only the bbox rows of
    the pattern (row rotation abstracted away, as Spatial's LineBuffer
    does), so dim-0 indices are pattern constants and dim-1 slides with c."""
    pts = STENCIL_POINTS[name]
    P = STENCIL_PAR[name] if par is None else par
    rows = [dr for dr, _ in pts]
    cols = [dc for _, dc in pts]
    r0, c0 = min(rows), min(cols)
    n_rows = max(rows) - r0 + 1 if H is None else H
    mem = MemorySpec("img", dims=(n_rows, W), word_bits=16, ports=ports)
    accesses = [
        AccessDecl(
            "img",
            (Affine.const_(dr - r0), Affine.of(const=dc - c0, c=1)),
            label=f"{name}[{dr},{dc}]",
        )
        for dr, dc in pts
    ]
    span = max(cols) - c0
    inner = Ctrl(
        "cols", Sched.INNER,
        counters=[Counter("c", 0, 1, W - span, par=P)],
        accesses=accesses,
    )
    root = Ctrl(
        "rows", Sched.PIPELINED,
        counters=[Counter("r", 0, 1, 128)],
        children=[inner],
    )
    return Program(root=root, memories={"img": mem})


# ---------------------------------------------------------------------------
# Smith-Waterman (GACT): wavefront DP, cell (i,j) reads N/W/NW, par 4 on the
# anti-diagonal (Fig. 12a).
# ---------------------------------------------------------------------------


def sw_program(H: int = 64, W: int = 64, par: int = 4, ports: int = 2) -> Program:
    mem = MemorySpec("tile", dims=(H, W), word_bits=16, ports=ports)
    # wavefront: lanes advance along the anti-diagonal; lane l handles row
    # i*par+l, column j-l => accesses are skewed reads + one write.
    accesses = []
    for (dr, dc, w, tag) in [(-1, 0, False, "n"), (0, -1, False, "w"),
                             (-1, -1, False, "nw"), (0, 0, True, "self")]:
        accesses.append(
            AccessDecl(
                "tile",
                (Affine.of(const=dr + 1, i=1), Affine.of(const=dc + 1, j=1, i=-1)),
                is_write=w, label=f"sw.{tag}",
            )
        )
    inner = Ctrl(
        "cell", Sched.INNER,
        counters=[Counter("i", 0, 1, H - 1, par=par)],
        accesses=accesses,
    )
    root = Ctrl(
        "diag", Sched.PIPELINED,
        counters=[Counter("j", 0, 1, W - 1)],
        children=[inner],
    )
    return Program(root=root, memories={"tile": mem})


# ---------------------------------------------------------------------------
# SpMV: edge-list over dense regions; par 4 rows x 3 cols; each row's strided
# pattern has a data-dependent ('random') column offset (Fig. 12b) -- modelled
# with an uninterpreted per-row symbol.  Projection regrouping makes the
# offset disappear on the row dimension (paper Sec 4, 'good candidate for
# multidimensional banking').
# ---------------------------------------------------------------------------


def spmv_program(R: int = 64, C: int = 64, par_r: int = 4, par_c: int = 3,
                 ports: int = 2) -> Program:
    mem = MemorySpec("mat", dims=(R, C), word_bits=32, ports=ports)
    col = Affine.of(c=1)
    accesses = [
        AccessDecl("mat", (Affine.of(r=1), col), label="spmv.rd"),
    ]
    inner = Ctrl(
        "cols", Sched.INNER,
        counters=[
            Counter("c", 0, 1, None, par=par_c, start_sym="row_off"),
        ],
        accesses=accesses,
    )
    rows = Ctrl(
        "rows", Sched.FORKJOIN,
        counters=[Counter("r", 0, 1, R, par=par_r)],
        children=[inner],
    )
    return Program(root=rows, memories={"mat": mem})


# ---------------------------------------------------------------------------
# Minibatch SGD: on-chip (R, C) data matrix, two never-concurrent access
# modes (two groups): column-major predict reads and row-major gradient
# reads, each 12-wide (Fig. 12c).
# ---------------------------------------------------------------------------


def sgd_program(R: int = 48, C: int = 48, par_a: int = 4, par_b: int = 3,
                ports: int = 2) -> Program:
    mem = MemorySpec("data", dims=(R, C), word_bits=32, ports=ports)
    predict = Ctrl(
        "predict", Sched.INNER,
        counters=[
            Counter("pi", 0, 1, R, par=par_a),
            Counter("pj", 0, 1, C, par=par_b),
        ],
        accesses=[AccessDecl("data", (Affine.of(pi=1), Affine.of(pj=1)),
                             label="sgd.predict")],
    )
    grad = Ctrl(
        "grad", Sched.INNER,
        counters=[
            Counter("gi", 0, 1, R, par=par_b),
            Counter("gj", 0, 1, C, par=par_a),
        ],
        accesses=[AccessDecl("data", (Affine.of(gi=1), Affine.of(gj=1)),
                             label="sgd.grad")],
    )
    root = Ctrl("epoch", Sched.SEQUENTIAL,
                counters=[Counter("e", 0, 1, 8)],
                children=[predict, grad])
    return Program(root=root, memories={"data": mem})


# ---------------------------------------------------------------------------
# MD-grid running example (Fig. 7/9): 4-D dvec_sram with PL-wide writes and
# PX*PY*PZ*PQ readers whose q loop has data-dependent bounds.
# ---------------------------------------------------------------------------


def md_grid_program(W: int = 4, Nmax: int = 8, PL: int = 2, PX: int = 2,
                    PY: int = 1, PZ: int = 1, PQ: int = 2,
                    ports: int = 2) -> Program:
    mem = MemorySpec("dvec", dims=(W, W, W, Nmax), word_bits=32, ports=ports)
    writer = Ctrl(
        "load", Sched.INNER,
        counters=[
            Counter("d0", 0, 1, W), Counter("d1", 0, 1, W),
            Counter("d2", 0, 1, W), Counter("d3", 0, 1, Nmax, par=PL),
        ],
        accesses=[AccessDecl(
            "dvec",
            (Affine.of(d0=1), Affine.of(d1=1), Affine.of(d2=1), Affine.of(d3=1)),
            is_write=True, label="md.wr")],
    )
    reader = Ctrl(
        "compute", Sched.INNER,
        counters=[
            Counter("x", 0, 1, W, par=PX), Counter("y", 0, 1, W, par=PY),
            Counter("z", 0, 1, W, par=PZ),
            Counter("q", 0, 1, None, par=PQ),  # Q_RNG(x,y,z): data-dependent
        ],
        accesses=[AccessDecl(
            "dvec",
            (Affine.of(x=1), Affine.of(y=1), Affine.of(z=1), Affine.of(q=1)),
            label="md.rd")],
    )
    root = Ctrl("main", Sched.SEQUENTIAL,
                counters=[Counter("t", 0, 1, 4)],
                children=[writer, reader])
    return Program(root=root, memories={"dvec": mem})


STENCILS = list(STENCIL_POINTS)
APPS = ["sw", "spmv", "sgd"]


def build(name: str, **kw) -> Program:
    if name in STENCIL_POINTS:
        return stencil_program(name, **kw)
    if name == "sw":
        return sw_program(**kw)
    if name == "spmv":
        return spmv_program(**kw)
    if name == "sgd":
        return sgd_program(**kw)
    if name == "md_grid":
        return md_grid_program(**kw)
    raise KeyError(name)
