"""Hierarchically-nested state-machine program model (paper Sec 2.4).

Programs are trees of *controllers*.  Outer controllers contain controllers;
inner controllers contain a scheduled dataflow block with memory accesses.
Parallelizing an inner controller vectorizes its accesses; parallelizing an
outer controller unrolls its subtree into lanes, each tagged with an
unroll-ID (UID).  The unroller below reproduces both strategies of Sec 2.4.3:

* FoP (ForkJoin-of-Pipelines): fork-join injected per child stage; all lanes
  of each child begin simultaneously.
* PoF (Pipeline-of-ForkJoins): each lane is a structurally complete clone;
  a single fork-join is injected above, lanes drift freely afterwards.

Iterator-synchronization analysis (Sec 3.2) decides, per iterator and lane
pair, whether the lanes observe the same iterator value each cycle
(*synchronized*; possibly offset by a constant = *partially synchronized*) or
not (*unsynchronized*), in which case the lanes get independent fresh
iterator variables -- the conservative widening the paper applies.

The rule implemented here (the paper's prose example has an FoP/PoF label
inconsistency with its own Fig. 6 definitions; we implement the semantics of
Fig. 6, conservatively):

* lanes below an unroll point stay in lockstep iff every controller in the
  unrolled subtree has static bounds and static initiation timing;
* the unrolled counter itself is shared across lanes iff the strategy is
  stage-synchronized (FoP) or the subtree is static.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional, Sequence, Tuple

from .polytope import Access, Affine, Iterator, MemorySpec


class Sched(Enum):
    SEQUENTIAL = "Sequential"
    PIPELINED = "Pipelined"
    FORKJOIN = "ForkJoin"
    FORK = "Fork"
    STREAM = "Stream"
    INNER = "Inner"


class Unroll(Enum):
    FOP = "ForkJoin-of-Pipelines"  # stage-synchronized lanes
    POF = "Pipeline-of-ForkJoins"  # lane-synchronized start only


@dataclass
class Counter:
    """One level of a multi-level counter chain.

    ``count=None`` marks a data-dependent bound (e.g. ``Q_RNG(x,y,z)``);
    ``start_sym`` marks a data-dependent start value.
    """

    name: str
    start: int = 0
    step: int = 1
    count: Optional[int] = None
    par: int = 1
    start_sym: Optional[str] = None  # uninterpreted start (data-dependent)

    @property
    def static(self) -> bool:
        return self.count is not None and self.start_sym is None


@dataclass
class AccessDecl:
    """A logical access written against the *declared* iterator names."""

    memory: str
    exprs: Tuple[Affine, ...]
    is_write: bool = False
    cycle: int = 0  # schedule slot inside the inner controller
    label: str = ""


@dataclass
class Ctrl:
    name: str
    sched: Sched
    counters: List[Counter] = field(default_factory=list)
    children: List["Ctrl"] = field(default_factory=list)
    accesses: List[AccessDecl] = field(default_factory=list)
    ii: int = 1        # initiation interval (inner controllers)
    latency: int = 1   # datapath latency (inner controllers)

    @property
    def is_inner(self) -> bool:
        return self.sched is Sched.INNER

    def subtree(self) -> List["Ctrl"]:
        out = [self]
        for c in self.children:
            out.extend(c.subtree())
        return out

    def subtree_static(self) -> bool:
        return all(
            cnt.static for node in self.subtree() for cnt in node.counters
        )

    @property
    def width(self) -> int:
        return len(self.children)


@dataclass
class Program:
    root: Ctrl
    memories: Dict[str, MemorySpec]
    unroll_strategy: Unroll = Unroll.FOP


# ---------------------------------------------------------------------------
# Unrolled form
# ---------------------------------------------------------------------------


@dataclass
class UnrolledProgram:
    accesses: List[Access]
    iterators: Dict[str, Iterator]
    # controller path (tuple of ctrl names root->leaf) for each access index
    paths: List[Tuple[str, ...]]
    ctrl_by_name: Dict[str, Ctrl]
    # names of ancestors of each access that are ForkJoin-like due to unroll
    unroll_forks: List[Tuple[str, ...]]


def _qualify(name: str, uid: Tuple[int, ...]) -> str:
    return f"{name}@{'.'.join(map(str, uid))}" if uid else name


def unroll(program: Program) -> UnrolledProgram:
    """Expand all parallelization into per-lane accesses with UIDs."""
    accesses: List[Access] = []
    iterators: Dict[str, Iterator] = {}
    paths: List[Tuple[str, ...]] = []
    forks: List[Tuple[str, ...]] = []
    ctrl_by_name: Dict[str, Ctrl] = {c.name: c for c in program.root.subtree()}
    strategy = program.unroll_strategy

    def visit(
        node: Ctrl,
        uid: Tuple[int, ...],
        subst: Dict[str, Affine],
        path: Tuple[str, ...],
        lockstep: bool,
        fork_ancestors: Tuple[str, ...],
    ) -> None:
        path = path + (node.name,)
        # Expand this controller's counters lane-by-lane.
        lane_spaces = [range(c.par) for c in node.counters]
        subtree_static = node.subtree_static()
        for lanes in itertools.product(*lane_spaces):
            lane_subst = dict(subst)
            lane_uid = uid + tuple(lanes)
            lane_lockstep = lockstep
            lane_forks = fork_ancestors
            for ci, (c, lane) in enumerate(zip(node.counters, lanes)):
                unrolled = c.par > 1
                if unrolled:
                    lane_forks = lane_forks + (node.name,)
                # does the base counter stay shared across lanes?
                shared = (not unrolled) or (strategy is Unroll.FOP) or subtree_static
                if unrolled and not subtree_static:
                    lane_lockstep = False
                # the counter base is one physical counter: always shared
                # across its OWN vectorization lanes; across OUTER lanes it
                # is shared only in lockstep (else per-outer-lane fresh).
                base_uid = () if (shared and lockstep) else uid + tuple(lanes[:ci])
                base_name = _qualify(c.name, base_uid)
                eff_step = c.step * c.par
                eff_count = None if c.count is None else -(-c.count // c.par)
                iterators.setdefault(
                    base_name,
                    Iterator(base_name, start=c.start, step=eff_step, count=eff_count),
                )
                # iterator value for this lane: base + lane*step (+ data-dep start)
                val = Affine.of(const=lane * c.step, **{base_name: 1})
                if c.start_sym is not None:
                    # the data-dependent start belongs to the counter BASE:
                    # it varies with enclosing lanes (e.g. the row) but is
                    # shared across this counter's own vectorization lanes,
                    # so those lanes' symbols cancel in deltas (Sec 2.2).
                    sym_uid = uid + tuple(lanes[:ci])
                    val = val.with_sym(_qualify(c.start_sym, sym_uid))
                lane_subst[c.name] = val
            if node.is_inner:
                for decl in node.accesses:
                    exprs = []
                    for e in decl.exprs:
                        out = e
                        for nm, val in lane_subst.items():
                            out = out.subst(nm, val)
                        # any leftover RAW syms in the expr: qualify per lane
                        # (counter-injected syms already carry their '@' uid)
                        if out.syms and not lane_lockstep:
                            out = Affine(
                                terms=out.terms,
                                syms=tuple(
                                    ((k if "@" in k else _qualify(k, lane_uid)), v)
                                    for k, v in out.syms
                                ),
                                const=out.const,
                            )
                        exprs.append(out)
                    accesses.append(
                        Access(
                            memory=decl.memory,
                            exprs=tuple(exprs),
                            uid=lane_uid,
                            is_write=decl.is_write,
                            ctrl=node.name,
                            sched_cycle=decl.cycle,
                            label=decl.label or f"{node.name}[{lane_uid}]",
                        )
                    )
                    paths.append(path)
                    forks.append(lane_forks)
            else:
                for child in node.children:
                    visit(child, lane_uid, lane_subst, path, lane_lockstep, lane_forks)

    visit(program.root, (), {}, (), True, ())
    return UnrolledProgram(accesses, iterators, paths, ctrl_by_name, forks)


# ---------------------------------------------------------------------------
# LCA + concurrency (Sec 3.2 / Fig 8 support)
# ---------------------------------------------------------------------------


def lca_name(path_a: Sequence[str], path_b: Sequence[str]) -> str:
    out = path_a[0]
    for x, y in zip(path_a, path_b):
        if x != y:
            break
        out = x
    return out


def is_concurrent(
    up: UnrolledProgram, ia: int, ib: int
) -> bool:
    """Paper's isConcurrent: may accesses ia and ib be live the same cycle?"""
    a, b = up.accesses[ia], up.accesses[ib]
    pa, pb = up.paths[ia], up.paths[ib]
    lca = lca_name(pa, pb)
    ctrl = up.ctrl_by_name[lca]

    if a.ctrl == b.ctrl and a.uid != b.uid:
        # lanes of the same (vectorized/unrolled) controller execute together
        return True
    if lca in up.unroll_forks[ia] or lca in up.unroll_forks[ib]:
        # unrolling injected a fork-join at this level (Sec 2.4.3)
        return True
    if ctrl.is_inner:
        return abs(a.sched_cycle - b.sched_cycle) < ctrl.ii
    if ctrl.sched in (Sched.FORKJOIN, Sched.STREAM):
        return True
    # Sequential / Fork: never concurrent.  Pipelined: concurrent in time but
    # routed to different buffers of an N-buffered memory (paper Sec 3.2), so
    # *not* part of the same banking group.
    return False
