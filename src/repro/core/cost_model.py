"""ML resource estimator (paper Sec 3.5): GBT pipeline vs MLP baseline.

Pipeline (Fig. 10):  raw features -> degree-2 polynomial combinations ->
gradient-boosted regression trees -> importance-based re-selection of the
top-36 generated features -> refit.  The baseline is the MLP of [19]
(Koeplinger et al., ISCA'16), grid-tuned as the paper describes.

Everything is pure numpy (no sklearn/xgboost in this container): shallow
regression trees split on quantile thresholds by variance reduction;
boosting is least-squares with shrinkage and row subsampling; the MLP is a
two-hidden-layer ReLU net trained with Adam + early stopping.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from .features import extract_features, poly2_expand

# ---------------------------------------------------------------------------
# Regression tree (depth-limited, quantile-threshold splits)
# ---------------------------------------------------------------------------


@dataclass
class _TreeNode:
    feature: int = -1
    threshold: float = 0.0
    left: Optional["_TreeNode"] = None
    right: Optional["_TreeNode"] = None
    value: float = 0.0

    @property
    def is_leaf(self) -> bool:
        return self.left is None

    def to_json(self) -> dict:
        if self.is_leaf:
            return {"v": self.value}
        return {"f": self.feature, "t": self.threshold,
                "l": self.left.to_json(), "r": self.right.to_json()}

    @staticmethod
    def from_json(d: dict) -> "_TreeNode":
        if "f" not in d:
            return _TreeNode(value=d["v"])
        return _TreeNode(feature=d["f"], threshold=d["t"],
                         left=_TreeNode.from_json(d["l"]),
                         right=_TreeNode.from_json(d["r"]))


def _fit_tree(X, y, depth, min_leaf, rng, n_thresholds=16, feature_frac=0.8):
    node = _TreeNode(value=float(y.mean()))
    if depth == 0 or len(y) < 2 * min_leaf or float(y.var()) < 1e-12:
        return node
    n, d = X.shape
    feats = rng.choice(d, size=max(1, int(d * feature_frac)), replace=False)
    best = (0.0, -1, 0.0)  # (gain, feature, threshold)
    base_sse = float(((y - y.mean()) ** 2).sum())
    for f in feats:
        col = X[:, f]
        qs = np.unique(np.quantile(col, np.linspace(0.05, 0.95, n_thresholds)))
        for t in qs:
            mask = col <= t
            nl = int(mask.sum())
            if nl < min_leaf or n - nl < min_leaf:
                continue
            yl, yr = y[mask], y[~mask]
            sse = float(((yl - yl.mean()) ** 2).sum() + ((yr - yr.mean()) ** 2).sum())
            gain = base_sse - sse
            if gain > best[0]:
                best = (gain, int(f), float(t))
    if best[1] < 0:
        return node
    _, f, t = best
    mask = X[:, f] <= t
    node.feature, node.threshold = f, t
    node.left = _fit_tree(X[mask], y[mask], depth - 1, min_leaf, rng,
                          n_thresholds, feature_frac)
    node.right = _fit_tree(X[~mask], y[~mask], depth - 1, min_leaf, rng,
                           n_thresholds, feature_frac)
    return node


def _tree_predict(node: _TreeNode, X: np.ndarray) -> np.ndarray:
    if node.is_leaf:
        return np.full(len(X), node.value)
    mask = X[:, node.feature] <= node.threshold
    out = np.empty(len(X))
    out[mask] = _tree_predict(node.left, X[mask])
    out[~mask] = _tree_predict(node.right, X[~mask])
    return out


def _tree_importance(node: _TreeNode, imp: np.ndarray) -> None:
    if node.is_leaf:
        return
    imp[node.feature] += 1.0  # split frequency (paper's definition)
    _tree_importance(node.left, imp)
    _tree_importance(node.right, imp)


# ---------------------------------------------------------------------------
# Gradient boosting
# ---------------------------------------------------------------------------


@dataclass
class GradientBoostedTrees:
    n_estimators: int = 150
    max_depth: int = 3
    learning_rate: float = 0.08
    subsample: float = 0.8
    min_leaf: int = 3
    seed: int = 0

    trees: List[_TreeNode] = field(default_factory=list)
    base: float = 0.0

    def fit(self, X: np.ndarray, y: np.ndarray) -> "GradientBoostedTrees":
        rng = np.random.default_rng(self.seed)
        self.base = float(y.mean())
        pred = np.full(len(y), self.base)
        self.trees = []
        for _ in range(self.n_estimators):
            resid = y - pred
            idx = rng.choice(len(y), size=max(2, int(len(y) * self.subsample)),
                             replace=False)
            tree = _fit_tree(X[idx], resid[idx], self.max_depth,
                             self.min_leaf, rng)
            self.trees.append(tree)
            pred = pred + self.learning_rate * _tree_predict(tree, X)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        pred = np.full(len(X), self.base)
        for t in self.trees:
            pred = pred + self.learning_rate * _tree_predict(t, X)
        return pred

    def feature_importance(self, d: int) -> np.ndarray:
        imp = np.zeros(d)
        for t in self.trees:
            _tree_importance(t, imp)
        return imp

    def to_json(self) -> dict:
        return {
            "params": dict(n_estimators=self.n_estimators,
                           max_depth=self.max_depth,
                           learning_rate=self.learning_rate,
                           subsample=self.subsample,
                           min_leaf=self.min_leaf, seed=self.seed),
            "base": self.base,
            "trees": [t.to_json() for t in self.trees],
        }

    @staticmethod
    def from_json(d: dict) -> "GradientBoostedTrees":
        gbt = GradientBoostedTrees(**d["params"])
        gbt.base = d["base"]
        gbt.trees = [_TreeNode.from_json(t) for t in d["trees"]]
        return gbt


# ---------------------------------------------------------------------------
# The paper's full pipeline: poly2 -> GBT -> top-36 reselect -> refit
# ---------------------------------------------------------------------------


@dataclass
class ResourcePipeline:
    n_selected: int = 36  # paper: 36 generated features suffice
    gbt_params: dict = field(default_factory=dict)

    mu: np.ndarray = None
    sd: np.ndarray = None
    selected: np.ndarray = None
    model: GradientBoostedTrees = None
    names: List[str] = field(default_factory=list)
    log_target: bool = True

    def _prep(self, Xraw: np.ndarray) -> Tuple[np.ndarray, List[str]]:
        Xp, names = poly2_expand(Xraw)
        return Xp, names

    def fit(self, Xraw: np.ndarray, y: np.ndarray) -> "ResourcePipeline":
        Xp, names = self._prep(Xraw)
        self.mu, self.sd = Xp.mean(0), Xp.std(0) + 1e-9
        Xs = (Xp - self.mu) / self.sd
        yt = np.log1p(np.maximum(y, 0)) if self.log_target else y
        stage1 = GradientBoostedTrees(**{**dict(seed=1), **self.gbt_params}).fit(Xs, yt)
        imp = stage1.feature_importance(Xs.shape[1])
        k = min(self.n_selected, Xs.shape[1])
        self.selected = np.argsort(-imp)[:k]
        self.names = [names[i] for i in self.selected]
        self.model = GradientBoostedTrees(**{**dict(seed=2), **self.gbt_params})
        self.model.fit(Xs[:, self.selected], yt)
        return self

    def predict(self, Xraw: np.ndarray) -> np.ndarray:
        Xp, _ = self._prep(Xraw)
        Xs = (Xp - self.mu) / self.sd
        p = self.model.predict(Xs[:, self.selected])
        return np.expm1(p) if self.log_target else p

    def to_json(self) -> dict:
        return {
            "n_selected": self.n_selected,
            "gbt_params": dict(self.gbt_params),
            "mu": self.mu.tolist(),
            "sd": self.sd.tolist(),
            "selected": np.asarray(self.selected).tolist(),
            "names": list(self.names),
            "log_target": self.log_target,
            "model": self.model.to_json(),
        }

    @staticmethod
    def from_json(d: dict) -> "ResourcePipeline":
        pipe = ResourcePipeline(n_selected=d["n_selected"],
                                gbt_params=dict(d["gbt_params"]))
        pipe.mu = np.asarray(d["mu"])
        pipe.sd = np.asarray(d["sd"])
        pipe.selected = np.asarray(d["selected"], dtype=np.int64)
        pipe.names = list(d["names"])
        pipe.log_target = d["log_target"]
        pipe.model = GradientBoostedTrees.from_json(d["model"])
        return pipe


# ---------------------------------------------------------------------------
# MLP baseline ([19]-style, as tuned in the paper's comparison)
# ---------------------------------------------------------------------------


@dataclass
class MLPBaseline:
    hidden: Tuple[int, int] = (64, 32)
    lr: float = 1e-3
    epochs: int = 400
    l2: float = 1e-4
    seed: int = 0
    log_target: bool = True

    def fit(self, X: np.ndarray, y: np.ndarray) -> "MLPBaseline":
        rng = np.random.default_rng(self.seed)
        self.mu, self.sd = X.mean(0), X.std(0) + 1e-9
        Xs = (X - self.mu) / self.sd
        yt = np.log1p(np.maximum(y, 0)) if self.log_target else y
        ymu, ysd = yt.mean(), yt.std() + 1e-9
        self.ymu, self.ysd = ymu, ysd
        yn = (yt - ymu) / ysd
        d = X.shape[1]
        h1, h2 = self.hidden
        params = {
            "W1": rng.normal(0, np.sqrt(2 / d), (d, h1)), "b1": np.zeros(h1),
            "W2": rng.normal(0, np.sqrt(2 / h1), (h1, h2)), "b2": np.zeros(h2),
            "W3": rng.normal(0, np.sqrt(2 / h2), (h2, 1)), "b3": np.zeros(1),
        }
        m = {k: np.zeros_like(v) for k, v in params.items()}
        v = {k: np.zeros_like(v_) for k, v_ in params.items()}
        t = 0
        n = len(Xs)
        for epoch in range(self.epochs):
            idx = rng.permutation(n)
            for start in range(0, n, 32):
                b = idx[start:start + 32]
                xb, yb = Xs[b], yn[b]
                # forward
                z1 = xb @ params["W1"] + params["b1"]; a1 = np.maximum(z1, 0)
                z2 = a1 @ params["W2"] + params["b2"]; a2 = np.maximum(z2, 0)
                out = (a2 @ params["W3"] + params["b3"]).ravel()
                g_out = 2 * (out - yb)[:, None] / len(b)
                grads = {}
                grads["W3"] = a2.T @ g_out + self.l2 * params["W3"]
                grads["b3"] = g_out.sum(0)
                g2 = (g_out @ params["W3"].T) * (z2 > 0)
                grads["W2"] = a1.T @ g2 + self.l2 * params["W2"]
                grads["b2"] = g2.sum(0)
                g1 = (g2 @ params["W2"].T) * (z1 > 0)
                grads["W1"] = xb.T @ g1 + self.l2 * params["W1"]
                grads["b1"] = g1.sum(0)
                t += 1
                for k in params:
                    m[k] = 0.9 * m[k] + 0.1 * grads[k]
                    v[k] = 0.999 * v[k] + 0.001 * grads[k] ** 2
                    mh = m[k] / (1 - 0.9 ** t)
                    vh = v[k] / (1 - 0.999 ** t)
                    params[k] -= self.lr * mh / (np.sqrt(vh) + 1e-8)
        self.params = params
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        Xs = (X - self.mu) / self.sd
        a1 = np.maximum(Xs @ self.params["W1"] + self.params["b1"], 0)
        a2 = np.maximum(a1 @ self.params["W2"] + self.params["b2"], 0)
        out = (a2 @ self.params["W3"] + self.params["b3"]).ravel()
        yt = out * self.ysd + self.ymu
        return np.expm1(yt) if self.log_target else yt


def r2_score(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    ss_res = float(((y_true - y_pred) ** 2).sum())
    ss_tot = float(((y_true - y_true.mean()) ** 2).sum()) + 1e-12
    return 1.0 - ss_res / ss_tot


# ---------------------------------------------------------------------------
# Scorer adapter for the solver (rank_solutions hook)
# ---------------------------------------------------------------------------


class MLScorer:
    """Wraps per-resource pipelines into a scalar scheme-ranking score."""

    def __init__(self, pipelines: dict, weights=None):
        self.pipelines = pipelines  # {"lut": ResourcePipeline, ...}
        self.weights = weights or {"lut": 1.0, "ff": 0.4, "bram": 200.0,
                                   "dsp": 400.0}

    def __call__(self, sol) -> float:
        x = extract_features(sol)[None, :]
        score = 0.0
        for res, pipe in self.pipelines.items():
            score += self.weights.get(res, 1.0) * float(pipe.predict(x)[0])
        return score

    def with_pipeline(self, name: str, pipeline,
                      weight: float = 1.0) -> "MLScorer":
        """A copy with one pipeline added/replaced -- how the telemetry
        refresh grafts a measured-latency resource onto the static model
        without mutating the shared instance."""
        pipes = dict(self.pipelines)
        pipes[name] = pipeline
        weights = dict(self.weights)
        weights[name] = float(weight)
        return MLScorer(pipes, weights=weights)

    def to_json(self) -> dict:
        return {
            "format": "ml-scorer/v1",
            "weights": dict(self.weights),
            "pipelines": {k: p.to_json() for k, p in self.pipelines.items()},
        }

    @staticmethod
    def from_json(d: dict) -> "MLScorer":
        if d.get("format") != "ml-scorer/v1":
            raise ValueError(f"not an ml scorer: format={d.get('format')!r}")
        pipes = {k: ResourcePipeline.from_json(p)
                 for k, p in d["pipelines"].items()}
        return MLScorer(pipes, weights=dict(d["weights"]))
