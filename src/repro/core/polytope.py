"""Polyhedral machinery for the banking system.

The paper (Defs 2.1-2.9) represents each logical access as an affine map from
an iterator polytope to the array polytope and reduces banking validity to
*conflict-polytope emptiness*: geometry (N, B, alpha) is conflict-free for a
pair of accesses iff applying the bank-address equation to the *delta* of the
two address patterns (Def 2.8) can never produce bank-address zero (same
bank) for any point of the iterator polytope.

The paper uses ISL; ISL is not available here.  Instead we implement an exact
decision procedure for the class of polytopes the solver actually emits:
deltas of affine accesses over box-bounded (possibly unbounded /
data-dependent) integer iterators.  For such deltas, reachability of
``delta . alpha  (mod N*B)`` is a *sumset* problem over Z_M which we solve
exactly by dynamic programming over residues.  Uninterpreted function symbols
(Sec 2.2, non-affine address components) either cancel in the delta (same
symbol, synchronized occurrence) or are treated as unbounded unknowns --
conservative, exactly as quantifier-free Presburger with uninterpreted
symbols behaves.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Iterators and uninterpreted symbols
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Iterator:
    """A loop iterator (Def 2.4 contributes one dimension of the iterator space).

    Values are ``start + step * t`` for ``t in [0, count)``.  ``count=None``
    models data-dependent bounds (e.g. ``Q_RNG(x,y,z)`` in the MD running
    example) -- the iterator is then *unbounded* for emptiness purposes.
    """

    name: str
    start: int = 0
    step: int = 1
    count: Optional[int] = None  # None => data-dependent / unbounded

    def values(self, cap: int) -> np.ndarray:
        n = cap if self.count is None else min(self.count, cap)
        return self.start + self.step * np.arange(n)


@dataclass(frozen=True)
class Sym:
    """An uninterpreted function symbol used in an address (Sec 2.2).

    ``key`` identifies the syntactic call site *and* the argument values it
    was instantiated with (e.g. ``f(i0)`` under lane 3).  Two occurrences with
    the same key denote the same (unknown) value and cancel in deltas.
    """

    key: str


# ---------------------------------------------------------------------------
# Affine expressions:  sum(coeff * iterator) + sum(coeff * sym) + const
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Affine:
    terms: Tuple[Tuple[str, int], ...] = ()       # (iterator name, coeff)
    syms: Tuple[Tuple[str, int], ...] = ()        # (sym key, coeff)
    const: int = 0

    # -- construction helpers ------------------------------------------------
    @staticmethod
    def of(const: int = 0, **coeffs: int) -> "Affine":
        return Affine(
            terms=tuple(sorted((k, int(v)) for k, v in coeffs.items() if v != 0)),
            const=int(const),
        )

    @staticmethod
    def const_(c: int) -> "Affine":
        return Affine(const=int(c))

    def with_sym(self, key: str, coeff: int = 1) -> "Affine":
        d = dict(self.syms)
        d[key] = d.get(key, 0) + coeff
        return replace(self, syms=tuple(sorted((k, v) for k, v in d.items() if v != 0)))

    # -- algebra --------------------------------------------------------------
    def __add__(self, other: "Affine") -> "Affine":
        t = dict(self.terms)
        for k, v in other.terms:
            t[k] = t.get(k, 0) + v
        s = dict(self.syms)
        for k, v in other.syms:
            s[k] = s.get(k, 0) + v
        return Affine(
            terms=tuple(sorted((k, v) for k, v in t.items() if v != 0)),
            syms=tuple(sorted((k, v) for k, v in s.items() if v != 0)),
            const=self.const + other.const,
        )

    def __neg__(self) -> "Affine":
        return Affine(
            terms=tuple((k, -v) for k, v in self.terms),
            syms=tuple((k, -v) for k, v in self.syms),
            const=-self.const,
        )

    def __sub__(self, other: "Affine") -> "Affine":
        return self + (-other)

    def scale(self, c: int) -> "Affine":
        if c == 0:
            return Affine()
        return Affine(
            terms=tuple((k, v * c) for k, v in self.terms),
            syms=tuple((k, v * c) for k, v in self.syms),
            const=self.const * c,
        )

    def subst(self, name: str, value: "Affine") -> "Affine":
        """Substitute iterator ``name`` with affine ``value``."""
        coeff = dict(self.terms).get(name, 0)
        if coeff == 0:
            return self
        rest = Affine(
            terms=tuple((k, v) for k, v in self.terms if k != name),
            syms=self.syms,
            const=self.const,
        )
        return rest + value.scale(coeff)

    def rename(self, mapping: Dict[str, str]) -> "Affine":
        t: Dict[str, int] = {}
        for k, v in self.terms:
            nk = mapping.get(k, k)
            t[nk] = t.get(nk, 0) + v
        return replace(
            self, terms=tuple(sorted((k, v) for k, v in t.items() if v != 0))
        )

    def coeff(self, name: str) -> int:
        return dict(self.terms).get(name, 0)

    @property
    def iterator_names(self) -> Tuple[str, ...]:
        return tuple(k for k, _ in self.terms)

    def is_const(self) -> bool:
        return not self.terms and not self.syms

    def evaluate(self, env: Dict[str, int]) -> int:
        v = self.const
        for k, c in self.terms:
            v += c * env[k]
        for k, c in self.syms:
            v += c * env[k]
        return v

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        parts = [f"{c}*{k}" for k, c in self.terms]
        parts += [f"{c}*<{k}>" for k, c in self.syms]
        if self.const or not parts:
            parts.append(str(self.const))
        return " + ".join(parts)


# ---------------------------------------------------------------------------
# Exact residue reachability over Z_M (the emptiness oracle)
# ---------------------------------------------------------------------------

_FULL_CAP = 4096  # enumeration guard; moduli in banking problems are small


def _term_residues(coeff: int, count: Optional[int], M: int) -> np.ndarray:
    """Residues mod M reachable by ``coeff * t`` for t in [0, count)."""
    if M == 1:
        return np.zeros(1, dtype=np.int64)
    c = coeff % M
    if c == 0:
        return np.zeros(1, dtype=np.int64)
    g = math.gcd(c, M)
    period = M // g
    if count is None or count >= period:
        # full cyclic subgroup generated by gcd(c, M)
        return (np.arange(period, dtype=np.int64) * g) % M
    return (np.arange(count, dtype=np.int64) * c) % M


def _sumset(a: np.ndarray, b: np.ndarray, M: int) -> np.ndarray:
    if len(a) * len(b) <= 65536:
        return np.unique((a[:, None] + b[None, :]) % M)
    # indicator-based set convolution for large sets
    ia = np.zeros(M, dtype=bool)
    ia[a % M] = True
    out = np.zeros(M, dtype=bool)
    for r in np.unique(b % M):
        out |= np.roll(ia, int(r))
    return np.nonzero(out)[0].astype(np.int64)


def reachable_residues(
    expr: Affine, iters: Dict[str, Iterator], M: int
) -> np.ndarray:
    """Exact set of residues mod M attainable by ``expr`` over its iterators.

    Iterators present in ``expr`` but missing from ``iters`` (and all
    uninterpreted symbols) are treated as unbounded integers -- conservative.
    """
    if M <= 1:
        return np.zeros(1, dtype=np.int64)
    acc = np.array([expr.const % M], dtype=np.int64)
    for name, coeff in expr.terms:
        it = iters.get(name)
        if it is None:
            res = _term_residues(coeff, None, M)
        else:
            # value = start + step*t  => coeff*value = coeff*start + coeff*step*t
            base = (coeff * it.start) % M
            res = (_term_residues(coeff * it.step, it.count, M) + base) % M
        acc = _sumset(acc, res, M)
        if len(acc) == M:
            return acc  # saturated
    for _key, coeff in expr.syms:
        res = _term_residues(coeff, None, M)
        acc = _sumset(acc, res, M)
        if len(acc) == M:
            return acc
    return acc


def delta_can_hit_window(
    delta: Affine,
    iters: Dict[str, Iterator],
    N: int,
    B: int,
) -> bool:
    """Conflict-polytope non-emptiness test (Def 2.8 / 2.9).

    Two accesses with address delta ``delta`` (already dotted with alpha)
    share a bank under geometry (N, B) iff ``delta`` can be congruent to a
    value in the open window (-B, B) modulo N*B:

        y1 = B q1 + r1,  y2 = B q2 + r2,  q1 === q2 (mod N)
        =>  y1 - y2 === r1 - r2 (mod N*B)  with |r1 - r2| < B.

    For B == 1 this degenerates to the exact test ``delta === 0 (mod N)``.
    """
    M = N * B
    if M <= 1:
        return True  # single bank: everything conflicts
    res = reachable_residues(delta, iters, M)
    if B == 1:
        return bool((res == 0).any())
    lo, hi = M - (B - 1), B - 1  # window residues: [0, B) and (M-B, M)
    return bool(((res <= hi) | (res >= lo)).any())


# ---------------------------------------------------------------------------
# Array / access-pattern containers (Defs 2.5-2.7)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MemorySpec:
    """An n-dimensional on-chip memory (Def 2.5)."""

    name: str
    dims: Tuple[int, ...]
    word_bits: int = 32
    ports: int = 1  # k in Def 2.9 (BRAMs are commonly true-dual-ported: 2)

    @property
    def n(self) -> int:
        return len(self.dims)

    @property
    def volume(self) -> int:
        return int(np.prod(self.dims))


@dataclass(frozen=True)
class Access:
    """A logical access (Def 2.6): per-dimension affine address expressions.

    ``uid`` is the unroll-ID (Sec 2.4.3): one integer per parallelized
    ancestor naming the lane this access copy belongs to.
    """

    memory: str
    exprs: Tuple[Affine, ...]
    uid: Tuple[int, ...] = ()
    is_write: bool = False
    ctrl: str = ""          # id of the innermost controller containing it
    sched_cycle: int = 0    # schedule slot within an inner controller
    label: str = ""

    @property
    def n(self) -> int:
        return len(self.exprs)

    def dot(self, alpha: Sequence[int]) -> Affine:
        out = Affine()
        for e, a in zip(self.exprs, alpha):
            out = out + e.scale(int(a))
        return out


@dataclass
class AccessGroup:
    """Accesses that may be live in the same cycle (Def 2.7)."""

    accesses: List[Access] = field(default_factory=list)

    def __len__(self) -> int:
        return len(self.accesses)

    def __iter__(self):
        return iter(self.accesses)


def linearize(dims: Sequence[int]) -> Tuple[int, ...]:
    """Row-major linearization weights for an array shape."""
    w = [1] * len(dims)
    for i in range(len(dims) - 2, -1, -1):
        w[i] = w[i + 1] * dims[i + 1]
    return tuple(w)
