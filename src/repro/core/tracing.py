"""Plan-plane tracing: spans, a metrics registry, and a flight recorder.

A cold solve crosses seven subsystems -- lint gate, admission control,
fair-share queue, shard/fabric lease, reducer, certifier, joint
co-selection, server promotion -- and aggregate counters cannot say
*which stage* ate the latency between ``submit()`` and the hot-swap.
This module is the observability plane the rest of the repo threads
through:

* :class:`Tracer` -- hierarchical spans with monotonic timestamps and a
  per-ticket ``trace_id``.  The id **propagates over the fabric wire
  protocol** (stamped on lease frames, returned on done frames), so a
  remote worker's lease/eval spans stitch into the driver's trace as
  one tree.  All hooks are guarded by a ``tracer is None`` check at the
  call site, so a service without tracing pays ~0.
* :class:`MetricsRegistry` -- counters, gauges, and bounded histograms
  (p50/p95/p99 over a fixed-size reservoir) behind one write path.
  ``ServiceStats.bump`` mirrors every increment here (as
  ``plan_<counter>`` with a ``tenant`` label), so the registry subsumes
  the ad-hoc stats arithmetic without breaking its exact per-tenant
  reconciliation.  Exposes Prometheus text exposition and JSON.
* :class:`FlightRecorder` -- a bounded ring buffer of the last N
  completed ticket traces.  Dumps Chrome ``trace_event`` JSON
  (``chrome://tracing`` / Perfetto loadable) on demand, and
  automatically on anomaly: a ticket exceeding the latency SLO, a
  certificate rejection, or a telemetry demotion.
* :func:`start_observability_server` -- a tiny stdlib HTTP thread
  serving ``/metrics`` (Prometheus text), ``/traces`` (Chrome trace
  JSON), and ``/stats`` (registry snapshot) for ``launch/serve.py
  --metrics-port``.

Clock discipline: spans carry ``time.perf_counter()`` timestamps local
to the recording process.  Worker-side spans travel as *relative*
offsets from lease receipt and are re-based onto the driver's monotonic
clock at the lease's issue time (attr ``clock="rebased"``) -- good for
attribution and visualization, honest about not being a distributed
clock sync.
"""

from __future__ import annotations

import itertools
import json
import os
import threading
import time
import uuid
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


def new_trace_id() -> str:
    """A fresh 16-hex-digit trace id (one per ticket / serve loop)."""
    return uuid.uuid4().hex[:16]


# ---------------------------------------------------------------------------
# Spans
# ---------------------------------------------------------------------------


_next_span_id = itertools.count(1).__next__


class Span:
    """One timed stage of a trace.  ``start``/``end`` are
    ``perf_counter`` seconds; ``origin`` names the recording process
    (``"driver"`` or ``"worker-<id>"``) and becomes the Chrome-trace
    thread lane."""

    __slots__ = ("trace_id", "span_id", "parent_id", "name", "start",
                 "end", "origin", "attrs")

    def __init__(self, trace_id: str, name: str, *,
                 parent_id: Optional[int] = None,
                 start: Optional[float] = None,
                 origin: str = "driver", attrs: Optional[dict] = None):
        self.trace_id = trace_id
        self.span_id = _next_span_id()
        self.parent_id = parent_id
        self.name = name
        self.start = time.perf_counter() if start is None else start
        self.end: Optional[float] = None
        self.origin = origin
        self.attrs = attrs or {}

    @property
    def duration_ms(self) -> float:
        end = self.end if self.end is not None else time.perf_counter()
        return (end - self.start) * 1e3

    def to_dict(self) -> dict:
        return {
            "trace_id": self.trace_id, "span_id": self.span_id,
            "parent_id": self.parent_id, "name": self.name,
            "start": self.start, "end": self.end, "origin": self.origin,
            "attrs": dict(self.attrs),
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"<Span {self.name} {self.duration_ms:.3f}ms "
                f"origin={self.origin}>")


def spans_to_wire(spans: List[dict], base: float) -> List[dict]:
    """Encode worker-local span dicts (``name``/``start``/``end``/
    ``attrs``) as relative offsets from ``base`` for the done frame."""
    out = []
    for s in spans:
        out.append({"n": s["name"], "s": s["start"] - base,
                    "d": (s["end"] - s["start"]),
                    "a": s.get("attrs") or {}})
    return out


class _NullSpan:
    """No-op stand-in so ``with tracer_or_none_span(...)`` sites stay
    branch-free; never allocated per call."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _LiveSpan:
    """Context manager wrapper closing a span on exit."""

    __slots__ = ("tracer", "span")

    def __init__(self, tracer: "Tracer", span: Span):
        self.tracer = tracer
        self.span = span

    def __enter__(self) -> Span:
        return self.span

    def __exit__(self, *exc):
        self.tracer.end(self.span)
        return False


# ---------------------------------------------------------------------------
# Ticket traces + the flight recorder
# ---------------------------------------------------------------------------


@dataclass
class TicketTrace:
    """One completed ticket lifecycle: every span that shares the
    ``trace_id``, driver- and worker-side."""

    trace_id: str
    label: str = ""
    status: str = "ok"
    anomaly: Optional[str] = None
    started: float = 0.0            # perf_counter of the earliest span
    finished: float = 0.0
    spans: List[Span] = field(default_factory=list)
    dropped_spans: int = 0

    @property
    def duration_ms(self) -> float:
        return (self.finished - self.started) * 1e3

    def span_names(self) -> List[str]:
        return [s.name for s in self.spans]

    def origins(self) -> List[str]:
        return sorted({s.origin for s in self.spans})


def chrome_trace_events(traces: List[TicketTrace]) -> List[dict]:
    """Chrome ``trace_event`` complete ("X") events for ``traces``.

    Every event carries the format's required keys -- ``name``,
    ``ph``, ``ts``, ``pid``, ``tid`` (plus ``dur`` for "X" events) --
    with timestamps in microseconds re-based so the earliest span of
    the earliest trace sits at ts=0.  One ``pid`` per trace, one
    ``tid`` lane per span origin, with metadata ("M") events naming
    both, so Perfetto renders one process per ticket and one thread
    per worker.
    """
    events: List[dict] = []
    if not traces:
        return events
    t0 = min(t.started for t in traces if t.spans) \
        if any(t.spans for t in traces) else 0.0
    for pid, trace in enumerate(traces):
        events.append({"name": "process_name", "ph": "M", "ts": 0,
                       "pid": pid, "tid": 0,
                       "args": {"name": f"{trace.label or 'ticket'} "
                                        f"{trace.trace_id}"}})
        tids = {o: i for i, o in enumerate(trace.origins())}
        for origin, tid in sorted(tids.items(), key=lambda kv: kv[1]):
            events.append({"name": "thread_name", "ph": "M", "ts": 0,
                           "pid": pid, "tid": tid,
                           "args": {"name": origin}})
        for s in trace.spans:
            end = s.end if s.end is not None else trace.finished
            events.append({
                "name": s.name, "cat": "plan", "ph": "X",
                "ts": round((s.start - t0) * 1e6, 3),
                "dur": round(max(0.0, (end - s.start)) * 1e6, 3),
                "pid": pid, "tid": tids.get(s.origin, 0),
                "args": {"trace_id": s.trace_id, **s.attrs},
            })
    return events


class FlightRecorder:
    """Bounded ring buffer of the last ``capacity`` completed ticket
    traces, plus the anomaly trigger: traces whose status/anomaly is
    bad or whose duration exceeds ``slo_ms`` are dumped to
    ``trace_dir`` immediately (when one is configured)."""

    def __init__(self, capacity: int = 64, *,
                 slo_ms: Optional[float] = None,
                 trace_dir: Optional[str] = None,
                 metrics: Optional["MetricsRegistry"] = None):
        self.capacity = max(1, int(capacity))
        self.slo_ms = slo_ms
        self.trace_dir = trace_dir
        self.metrics = metrics
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=self.capacity)
        self._anomalies: deque = deque(maxlen=256)
        self.recorded = 0
        self.anomaly_dumps = 0

    # -- intake ---------------------------------------------------------------
    def add(self, trace: TicketTrace) -> None:
        anomaly = trace.anomaly
        if anomaly is None and self.slo_ms is not None \
                and trace.duration_ms > self.slo_ms:
            anomaly = "slo-exceeded"
            trace.anomaly = anomaly
        with self._lock:
            self._ring.append(trace)
            self.recorded += 1
        if self.metrics is not None:
            self.metrics.inc("traces_recorded")
            self.metrics.observe("ticket_ms", trace.duration_ms)
        if anomaly is not None:
            self.note_anomaly(anomaly, detail=trace.trace_id,
                              dump=trace)

    def note_anomaly(self, kind: str, detail: str = "",
                     dump: Optional[TicketTrace] = None) -> None:
        """Record an anomaly (SLO breach, cert rejection, demotion) and
        -- when a ``trace_dir`` is configured -- dump the offending
        trace (or the whole ring) for post-mortem."""
        with self._lock:
            self._anomalies.append((time.time(), kind, detail))
        if self.metrics is not None:
            self.metrics.inc("anomalies", kind=kind)
        if self.trace_dir:
            with self._lock:
                n = self.anomaly_dumps
                self.anomaly_dumps += 1
            traces = [dump] if dump is not None else self.traces()
            path = os.path.join(self.trace_dir,
                                f"anomaly_{n:04d}_{kind}.json")
            try:
                self.dump(path, traces=traces)
            except OSError:
                pass                    # observability must never fail serving

    # -- readout --------------------------------------------------------------
    def traces(self) -> List[TicketTrace]:
        with self._lock:
            return list(self._ring)

    def anomalies(self) -> List[Tuple[float, str, str]]:
        with self._lock:
            return list(self._anomalies)

    def chrome_trace(self,
                     traces: Optional[List[TicketTrace]] = None) -> dict:
        """The ring (or ``traces``) as a Chrome-``trace_event`` JSON
        object -- load the dump in ``chrome://tracing`` or Perfetto."""
        return {"traceEvents": chrome_trace_events(
            self.traces() if traces is None else traces),
            "displayTimeUnit": "ms"}

    def dump(self, path: str,
             traces: Optional[List[TicketTrace]] = None) -> str:
        """Write the Chrome trace JSON to ``path``; returns the path."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.chrome_trace(traces), f, indent=1)
        return path


# ---------------------------------------------------------------------------
# The tracer
# ---------------------------------------------------------------------------


class Tracer:
    """Records hierarchical spans per ``trace_id`` and hands completed
    traces to the flight recorder.

    The service holds ``tracer = None`` until ``enable_tracing()``;
    every hook site guards with that check, so the disabled cost is one
    attribute load.  Enabled, a span is two ``perf_counter`` calls, a
    small object, and one lock-guarded list append."""

    def __init__(self, *, recorder: Optional[FlightRecorder] = None,
                 metrics: Optional["MetricsRegistry"] = None,
                 max_spans_per_trace: int = 4096):
        self.recorder = recorder
        self.metrics = metrics
        self.max_spans_per_trace = max(16, int(max_spans_per_trace))
        self._lock = threading.Lock()
        self._spans: Dict[str, List[Span]] = {}
        self._dropped: Dict[str, int] = {}
        self._labels: Dict[str, str] = {}

    # -- recording ------------------------------------------------------------
    def begin(self, trace_id: str, name: str, *,
              parent: Optional[Span] = None,
              origin: str = "driver", **attrs) -> Span:
        return Span(trace_id, name,
                    parent_id=parent.span_id if parent is not None else None,
                    origin=origin, attrs=attrs or None)

    def end(self, span: Span, **attrs) -> Span:
        span.end = time.perf_counter()
        if attrs:
            span.attrs.update(attrs)
        self._admit(span)
        return span

    def span(self, trace_id: str, name: str, *,
             parent: Optional[Span] = None, **attrs) -> _LiveSpan:
        """``with tracer.span(tid, "solve") as s: ...`` -- the span
        closes (and records) on exit."""
        return _LiveSpan(self, self.begin(trace_id, name, parent=parent,
                                          **attrs))

    def record(self, trace_id: str, name: str, start: float, end: float,
               *, parent: Optional[Span] = None, origin: str = "driver",
               **attrs) -> Span:
        """Record an already-timed stage retroactively (how the queue
        wait -- measured by timestamps, not an open span -- lands)."""
        span = Span(trace_id, name,
                    parent_id=parent.span_id if parent is not None else None,
                    start=start, origin=origin, attrs=attrs or None)
        span.end = end
        self._admit(span)
        return span

    def instant(self, trace_id: str, name: str, *,
                parent: Optional[Span] = None, origin: str = "driver",
                **attrs) -> Span:
        now = time.perf_counter()
        return self.record(trace_id, name, now, now, parent=parent,
                           origin=origin, **attrs)

    def add_remote_spans(self, trace_id: str, wire_spans: List[dict],
                         *, base: float, origin: str,
                         parent: Optional[Span] = None) -> int:
        """Stitch a worker's relative-offset spans (``{"n","s","d","a"}``
        dicts off a done frame) into the driver's trace, re-based onto
        the driver-side ``base`` timestamp (the lease's issue time)."""
        n = 0
        for w in wire_spans or ():
            try:
                start = base + float(w["s"])
                attrs = dict(w.get("a") or {})
                attrs["clock"] = "rebased"
                self.record(trace_id, str(w["n"]), start,
                            start + float(w["d"]), parent=parent,
                            origin=origin, **attrs)
                n += 1
            except (KeyError, TypeError, ValueError):
                continue                # a malformed span never kills intake
        return n

    def _admit(self, span: Span) -> None:
        with self._lock:
            spans = self._spans.setdefault(span.trace_id, [])
            if len(spans) >= self.max_spans_per_trace:
                self._dropped[span.trace_id] = \
                    self._dropped.get(span.trace_id, 0) + 1
                return
            spans.append(span)

    def label(self, trace_id: str, label: str) -> None:
        with self._lock:
            self._labels[trace_id] = label

    # -- readout / completion -------------------------------------------------
    def spans(self, trace_id: str) -> List[Span]:
        with self._lock:
            return list(self._spans.get(trace_id, ()))

    def live_traces(self) -> List[TicketTrace]:
        """Snapshot of every unfinished trace (the serve loop's rolling
        trace shows up here for ``/traces``)."""
        with self._lock:
            items = [(tid, list(spans))
                     for tid, spans in self._spans.items() if spans]
            labels = dict(self._labels)
            dropped = dict(self._dropped)
        now = time.perf_counter()
        out = []
        for tid, spans in items:
            out.append(TicketTrace(
                trace_id=tid, label=labels.get(tid, ""), status="live",
                started=min(s.start for s in spans), finished=now,
                spans=spans, dropped_spans=dropped.get(tid, 0)))
        return out

    def finish(self, trace_id: str, *, status: str = "ok",
               anomaly: Optional[str] = None,
               label: str = "") -> Optional[TicketTrace]:
        """Close the trace: pop its spans, assemble the
        :class:`TicketTrace`, and hand it to the flight recorder.
        Returns the trace (``None`` if nothing was ever recorded)."""
        with self._lock:
            spans = self._spans.pop(trace_id, None)
            dropped = self._dropped.pop(trace_id, 0)
            label = label or self._labels.pop(trace_id, "")
        if not spans:
            return None
        trace = TicketTrace(
            trace_id=trace_id, label=label, status=status, anomaly=anomaly,
            started=min(s.start for s in spans),
            finished=max(s.end if s.end is not None else s.start
                         for s in spans),
            spans=sorted(spans, key=lambda s: s.start),
            dropped_spans=dropped)
        if self.recorder is not None:
            self.recorder.add(trace)
        return trace

    def note_anomaly(self, kind: str, detail: str = "") -> None:
        """Forward an out-of-band anomaly (cert rejection, demotion) to
        the flight recorder's trigger."""
        if self.recorder is not None:
            self.recorder.note_anomaly(kind, detail)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


class _Histogram:
    """Bounded-reservoir histogram: exact count/sum/min/max, quantiles
    over the last ``cap`` samples (deterministic sliding window -- the
    recent behavior is what an operator is asking about)."""

    __slots__ = ("samples", "count", "total", "min", "max")

    def __init__(self, cap: int = 512):
        self.samples: deque = deque(maxlen=cap)
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float) -> None:
        v = float(value)
        self.samples.append(v)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        ordered = sorted(self.samples)
        idx = min(len(ordered) - 1, max(0, round(q * (len(ordered) - 1))))
        return ordered[idx]

    def summary(self) -> dict:
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "mean": (self.total / self.count) if self.count else 0.0,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


def _label_key(labels: dict) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_text(key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class MetricsRegistry:
    """Counters, gauges, and bounded histograms behind one write path.

    Metric identity is ``(name, sorted labels)``; the exposition key is
    ``name{k="v",...}``.  ``ServiceStats.bump`` mirrors every counter
    increment here as ``plan_<counter>{tenant="..."}`` -- the documented
    ``ServiceStats`` -> ``MetricsRegistry`` mapping -- so the registry
    sees exactly the increments the stats slices reconcile over."""

    def __init__(self, histogram_cap: int = 512):
        self._lock = threading.Lock()
        self._counters: Dict[Tuple[str, tuple], float] = {}
        self._gauges: Dict[Tuple[str, tuple], float] = {}
        self._hists: Dict[Tuple[str, tuple], _Histogram] = {}
        self._hist_cap = max(16, int(histogram_cap))

    # -- the write path -------------------------------------------------------
    def inc(self, name: str, n: float = 1, **labels) -> None:
        key = (name, _label_key(labels))
        with self._lock:
            self._counters[key] = self._counters.get(key, 0) + n

    def set_gauge(self, name: str, value: float, **labels) -> None:
        key = (name, _label_key(labels))
        with self._lock:
            self._gauges[key] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        key = (name, _label_key(labels))
        with self._lock:
            hist = self._hists.get(key)
            if hist is None:
                hist = self._hists[key] = _Histogram(self._hist_cap)
            hist.observe(value)

    # -- readout --------------------------------------------------------------
    def counter(self, name: str, **labels) -> float:
        with self._lock:
            return self._counters.get((name, _label_key(labels)), 0)

    def gauge(self, name: str, **labels) -> Optional[float]:
        with self._lock:
            return self._gauges.get((name, _label_key(labels)))

    def histogram(self, name: str, **labels) -> Optional[dict]:
        with self._lock:
            hist = self._hists.get((name, _label_key(labels)))
            return hist.summary() if hist is not None else None

    def snapshot(self) -> dict:
        """Everything, JSON-serializable, keys flattened to
        ``name{labels}`` exposition form."""
        with self._lock:
            counters = {name + _label_text(k): v
                        for (name, k), v in sorted(self._counters.items())}
            gauges = {name + _label_text(k): v
                      for (name, k), v in sorted(self._gauges.items())}
            hists = {name + _label_text(k): h.summary()
                     for (name, k), h in sorted(self._hists.items())}
        return {"counters": counters, "gauges": gauges,
                "histograms": hists}

    def prometheus(self) -> str:
        """Prometheus text exposition (0.0.4): counters, gauges, and
        histograms as summaries with p50/p95/p99 quantile series."""
        lines: List[str] = []
        with self._lock:
            counters = sorted(self._counters.items())
            gauges = sorted(self._gauges.items())
            hists = [(name, k, h.summary())
                     for (name, k), h in sorted(self._hists.items())]
        seen = set()
        for (name, k), v in counters:
            if name not in seen:
                seen.add(name)
                lines.append(f"# TYPE {name} counter")
            lines.append(f"{name}{_label_text(k)} {v}")
        for (name, k), v in gauges:
            if name not in seen:
                seen.add(name)
                lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name}{_label_text(k)} {v}")
        for name, k, s in hists:
            if name not in seen:
                seen.add(name)
                lines.append(f"# TYPE {name} summary")
            base = dict(k)
            for q in ("0.5", "0.95", "0.99"):
                lab = _label_text(_label_key({**base, "quantile": q}))
                val = {"0.5": s["p50"], "0.95": s["p95"],
                       "0.99": s["p99"]}[q]
                lines.append(f"{name}{lab} {val}")
            lines.append(f"{name}_sum{_label_text(k)} {s['sum']}")
            lines.append(f"{name}_count{_label_text(k)} {s['count']}")
        return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# /metrics + /traces HTTP endpoint (stdlib only)
# ---------------------------------------------------------------------------


def start_observability_server(metrics: MetricsRegistry,
                               recorder: Optional[FlightRecorder] = None,
                               *, tracer: Optional[Tracer] = None,
                               host: str = "127.0.0.1", port: int = 0):
    """Serve ``/metrics`` (Prometheus text), ``/traces`` (Chrome trace
    JSON: flight-recorder ring + live traces), and ``/stats`` (registry
    snapshot JSON) from a daemon thread.  Returns the
    ``ThreadingHTTPServer`` -- read the bound port off
    ``server.server_address`` and stop it with ``server.shutdown()``.
    """
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class Handler(BaseHTTPRequestHandler):
        def _send(self, body: bytes, ctype: str, code: int = 200) -> None:
            self.send_response(code)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):  # noqa: N802 (stdlib handler API)
            path = self.path.split("?", 1)[0]
            if path == "/metrics":
                self._send(metrics.prometheus().encode(),
                           "text/plain; version=0.0.4")
            elif path == "/traces":
                traces = recorder.traces() if recorder is not None else []
                if tracer is not None:
                    traces = traces + tracer.live_traces()
                body = json.dumps(
                    {"traceEvents": chrome_trace_events(traces),
                     "displayTimeUnit": "ms"}).encode()
                self._send(body, "application/json")
            elif path == "/stats":
                self._send(json.dumps(metrics.snapshot()).encode(),
                           "application/json")
            else:
                self._send(b"not found", "text/plain", 404)

        def log_message(self, *args):    # silence per-request stderr spam
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    server.daemon_threads = True
    threading.Thread(target=server.serve_forever, daemon=True,
                     name="observability-http").start()
    return server


__all__ = [
    "FlightRecorder",
    "MetricsRegistry",
    "NULL_SPAN",
    "Span",
    "TicketTrace",
    "Tracer",
    "chrome_trace_events",
    "new_trace_id",
    "spans_to_wire",
    "start_observability_server",
]
