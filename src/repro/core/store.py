"""Pluggable plan stores: plans and compiled artifacts shared across
planners -- and across *processes*.

``BankingPlanner`` used to own its durability story directly: an in-memory
dict fronting a directory of ``<signature>.<scorer>.json`` plans (and
``*.compiled.json`` artifacts).  That worked for one process warm-starting
the next, but the service front door (:mod:`repro.core.service`) needs the
same plans visible to many planners at once -- several serving processes
sharing one plan directory, a solve in one process answering submits in
another.  This module factors the storage layer out behind a small ABC:

* :class:`MemoryStore` -- a thread-safe in-process dict; the default when
  no durability is requested.
* :class:`DirectoryStore` -- a directory of JSON plans using **exactly the
  layout the planner's old ``cache_dir=`` wrote** (``<sig>.<scorer>.json``
  beside ``<sig>.<scorer>.<backend>.compiled.json``), so existing plan
  directories keep working.  Writes go through a lock file (O_CREAT|O_EXCL,
  the only primitive that is atomic on every POSIX filesystem including
  NFS) plus the existing tmp-file + rename dance; reads take no lock and
  tolerate torn or partial JSON as a cache miss -- a reader racing a
  writer re-solves rather than crashing.

Stores also index plans by **family** -- the problem signature *minus* the
solver options -- which is what lets the service's stale-while-revalidate
policy answer a submit whose options drifted from a stored near-match
while the exact solve runs in the background.
"""

from __future__ import annotations

import abc
import json
import os
import threading
import time
from pathlib import Path
from typing import Dict, Iterable, Optional, Tuple, Union

from .artifact import CompiledBankingPlan

# JSON syntax/shape problems a torn or foreign file can produce; every
# store read path treats these as a miss.
_MISS_ERRORS = (ValueError, KeyError, TypeError, json.JSONDecodeError,
                OSError)


def _safe(scorer_name: str) -> str:
    """Scorer names may embed ':' / '/' (custom callables); keep the file
    layout identical to what ``BankingPlanner(cache_dir=...)`` wrote."""
    return scorer_name.replace(":", "_").replace("/", "_")


class PlanStore(abc.ABC):
    """Where durable plans (and their compiled artifacts) live.

    Keys are (canonical signature, scorer name) for plans and
    (signature, scorer name, backend) for artifacts; ``find_family`` serves
    the stale-while-revalidate near-match lookup.  Implementations must be
    safe to call from multiple threads; :class:`DirectoryStore` is also
    safe across processes.
    """

    # -- plans ---------------------------------------------------------------
    @abc.abstractmethod
    def get(self, signature: str, scorer_name: str):
        """The stored plan, or ``None`` (damaged entries read as None)."""

    @abc.abstractmethod
    def put(self, plan) -> None:
        """Persist ``plan`` (keyed by its signature + scorer_name)."""

    # -- compiled artifacts ---------------------------------------------------
    @abc.abstractmethod
    def get_artifact(self, signature: str, scorer_name: str,
                     backend: str) -> Optional[CompiledBankingPlan]:
        ...

    @abc.abstractmethod
    def put_artifact(self, artifact: CompiledBankingPlan) -> None:
        ...

    # -- enumeration / near-match ---------------------------------------------
    @abc.abstractmethod
    def plans(self) -> Iterable:
        """Every readable plan (damaged entries skipped)."""

    @abc.abstractmethod
    def artifacts(self) -> Iterable[CompiledBankingPlan]:
        """Every readable compiled artifact (damaged entries skipped)."""

    def find_family(self, family: str, *,
                    exclude_signature: str = "") -> Optional["object"]:
        """Newest stored plan of the same problem *family* (same memory +
        access polytopes, any solver options/scorer) -- the near-match that
        stale-while-revalidate serves while the exact solve runs."""
        if not family:
            return None
        best = None
        for plan in self.plans():
            if (getattr(plan, "family", "") == family
                    and plan.signature != exclude_signature
                    and plan.best is not None):
                if best is None or plan.created_at > best.created_at:
                    best = plan
        return best

    # -- telemetry sidecar ------------------------------------------------------
    def get_telemetry(self, signature: str) -> list:
        """Persisted :class:`~repro.core.telemetry.MeasuredCost` records
        for one plan signature ([] when the store keeps none)."""
        return []

    def merge_telemetry(self, signature: str, records) -> None:
        """Fold observation *deltas* into the stored records for
        ``signature`` (no-op for stores without telemetry support)."""

    # -- certificate sidecar ----------------------------------------------------
    def get_certificate(self, signature: str, scorer_name: str):
        """Persisted conflict certificate for one plan (``None`` when the
        store keeps none)."""
        return None

    def put_certificate(self, signature: str, scorer_name: str,
                        cert: dict) -> None:
        """Persist a conflict certificate beside its plan (no-op for
        stores without certificate support)."""

    # -- joint-plan sidecar ------------------------------------------------------
    def get_joint(self, signature: str):
        """Persisted :class:`~repro.core.jointplan.JointPlan` for one
        ``jp1-`` joint signature (``None`` when the store keeps none)."""
        return None

    def put_joint(self, plan) -> None:
        """Persist a whole-model joint plan (no-op for stores without
        joint-plan support)."""

    # -- demotion ---------------------------------------------------------------
    def delete(self, signature: str, scorer_name: str) -> None:
        """Drop a stored plan and its compiled artifacts -- how demotion
        evicts a loser (no-op for stores without delete support)."""


# ---------------------------------------------------------------------------
# In-process store
# ---------------------------------------------------------------------------


class MemoryStore(PlanStore):
    """Thread-safe in-process store (the no-durability default)."""

    def __init__(self):
        self._plans: Dict[Tuple[str, str], object] = {}
        self._artifacts: Dict[Tuple[str, str, str], CompiledBankingPlan] = {}
        self._telemetry: Dict[str, Dict[tuple, object]] = {}
        self._certs: Dict[Tuple[str, str], dict] = {}
        self._joint: Dict[str, object] = {}
        self._lock = threading.Lock()

    def get(self, signature: str, scorer_name: str):
        with self._lock:
            return self._plans.get((signature, scorer_name))

    def put(self, plan) -> None:
        with self._lock:
            self._plans[(plan.signature, plan.scorer_name)] = plan

    def get_artifact(self, signature: str, scorer_name: str,
                     backend: str) -> Optional[CompiledBankingPlan]:
        with self._lock:
            return self._artifacts.get((signature, scorer_name, backend))

    def put_artifact(self, artifact: CompiledBankingPlan) -> None:
        with self._lock:
            self._artifacts[(artifact.signature, artifact.scorer_name,
                             artifact.backend)] = artifact

    def plans(self) -> Iterable:
        with self._lock:
            return list(self._plans.values())

    def artifacts(self) -> Iterable[CompiledBankingPlan]:
        with self._lock:
            return list(self._artifacts.values())

    def get_telemetry(self, signature: str) -> list:
        with self._lock:
            table = self._telemetry.get(signature, {})
            return [rec.copy() for rec in table.values()]

    def merge_telemetry(self, signature: str, records) -> None:
        with self._lock:
            table = self._telemetry.setdefault(signature, {})
            for rec in records:
                mine = table.get(rec.key)
                if mine is None:
                    table[rec.key] = rec.copy()
                else:
                    mine.merge(rec)

    def get_certificate(self, signature: str, scorer_name: str):
        with self._lock:
            return self._certs.get((signature, scorer_name))

    def put_certificate(self, signature: str, scorer_name: str,
                        cert: dict) -> None:
        with self._lock:
            self._certs[(signature, scorer_name)] = cert

    def get_joint(self, signature: str):
        with self._lock:
            return self._joint.get(signature)

    def put_joint(self, plan) -> None:
        with self._lock:
            self._joint[plan.signature] = plan

    def delete(self, signature: str, scorer_name: str) -> None:
        with self._lock:
            self._plans.pop((signature, scorer_name), None)
            self._certs.pop((signature, scorer_name), None)
            for key in [k for k in self._artifacts
                        if k[0] == signature and k[1] == scorer_name]:
                self._artifacts.pop(key, None)

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()
            self._artifacts.clear()
            self._telemetry.clear()
            self._certs.clear()
            self._joint.clear()


# ---------------------------------------------------------------------------
# Cross-process store: a directory of JSON plans behind a lock file
# ---------------------------------------------------------------------------


class FileLock:
    """Advisory lock file via O_CREAT|O_EXCL -- atomic on any POSIX fs.

    Writers take it so two processes never interleave a read-modify-write
    on the same key; readers don't (they rely on tmp+rename atomicity and
    treat torn JSON as a miss).  A lock older than ``stale_seconds`` is
    broken: the holder crashed, and plans are re-derivable, so liveness
    beats strict exclusion here.
    """

    def __init__(self, path: Union[str, Path], *, timeout: float = 10.0,
                 stale_seconds: float = 30.0, poll: float = 0.005):
        self.path = Path(path)
        self.timeout = timeout
        self.stale_seconds = stale_seconds
        self.poll = poll

    def acquire(self) -> None:
        deadline = time.monotonic() + self.timeout
        while True:
            try:
                fd = os.open(self.path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                self._break_if_stale()
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"could not acquire {self.path} within "
                        f"{self.timeout}s")
                time.sleep(self.poll)
            else:
                os.write(fd, str(os.getpid()).encode())
                os.close(fd)
                return

    def _break_if_stale(self) -> None:
        try:
            age = time.time() - self.path.stat().st_mtime
        except OSError:
            return  # already released
        if age > self.stale_seconds:
            try:
                self.path.unlink()
            except OSError:
                pass  # someone else broke it first

    def release(self) -> None:
        try:
            self.path.unlink()
        except OSError:
            pass

    def __enter__(self) -> "FileLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class DirectoryStore(PlanStore):
    """Plans shared across processes through a directory of JSON files.

    File layout is byte-compatible with the planner's legacy ``cache_dir``:
    ``<signature>.<scorer>.json`` for plans,
    ``<signature>.<scorer>.<backend>.compiled.json`` for artifacts -- a
    directory written by either API serves the other.  All writes are
    lock-file-guarded tmp+rename; reads are lock-free and treat unreadable
    or torn files as misses.

    ``max_bytes`` caps the store: after every write, least-recently-used
    entries (by mtime -- reads touch their file, so a hot entry stays
    young) are evicted until the plan/artifact files fit the cap.
    ``sweep()`` garbage-collects entries written under a stale
    ``SIGNATURE_VERSION`` -- their signatures can never be probed again,
    so they are dead weight after a version bump.

    Conflict certificates live in a ``certs/`` sidecar (same layout as
    ``telemetry/``, outside the LRU cap).  With ``verify_hydrated=True``
    -- what a ``PlanService`` armed with ``verify=`` sets -- every plan
    hydrated from disk must come with a certificate that re-checks and
    matches the plan's scheme; anything else reads as a miss and
    re-solves, so a poisoned or pre-verification entry can never serve.
    """

    LOCK_NAME = ".store.lock"

    def __init__(self, path: Union[str, Path], *, lock_timeout: float = 10.0,
                 lock_stale_seconds: float = 30.0,
                 max_bytes: Optional[int] = None,
                 verify_hydrated: bool = False):
        self.verify_hydrated = verify_hydrated
        self.path = Path(path)
        self.path.mkdir(parents=True, exist_ok=True)
        self._lock_timeout = lock_timeout
        self._lock_stale = lock_stale_seconds
        self.max_bytes = max_bytes
        # family -> (created_at, signature, scorer_name), rebuilt only
        # when the directory mtime moves (see find_family)
        self._family_index: Dict[str, Tuple[float, str, str]] = {}
        self._family_mtime = -1
        self._index_lock = threading.Lock()

    def _lock(self) -> FileLock:
        return FileLock(self.path / self.LOCK_NAME,
                        timeout=self._lock_timeout,
                        stale_seconds=self._lock_stale)

    # -- paths (legacy planner cache_dir layout) -------------------------------
    def plan_path(self, signature: str, scorer_name: str) -> Path:
        return self.path / f"{signature}.{_safe(scorer_name)}.json"

    def artifact_path(self, signature: str, scorer_name: str,
                      backend: str) -> Path:
        return self.path / (f"{signature}.{_safe(scorer_name)}."
                            f"{backend}.compiled.json")

    # -- plans ---------------------------------------------------------------
    def get(self, signature: str, scorer_name: str):
        from .planner import BankingPlan

        p = self.plan_path(signature, scorer_name)
        try:
            plan = BankingPlan.from_json(json.loads(p.read_text()))
        except _MISS_ERRORS:
            return None  # absent, torn, or foreign file: a miss
        if self.verify_hydrated and not self._hydrate_verified(plan):
            return None  # unverifiable entry: treat as a miss, re-solve
        self._touch(p)
        return plan

    def _hydrate_verified(self, plan) -> bool:
        """Re-verify a hydrated plan against its persisted certificate."""
        if plan.best is None:
            return True  # failed solves carry no scheme to refute
        cert = self.get_certificate(plan.signature, plan.scorer_name)
        if cert is None:
            return False
        from ..analysis.certify import (certificate_matches_plan,
                                        check_certificate)
        ok, _reason = check_certificate(cert)
        return ok and certificate_matches_plan(cert, plan)

    def put(self, plan) -> None:
        path = self.plan_path(plan.signature, plan.scorer_name)
        self._write_locked(path, plan.to_json())

    # -- artifacts -------------------------------------------------------------
    def get_artifact(self, signature: str, scorer_name: str,
                     backend: str) -> Optional[CompiledBankingPlan]:
        p = self.artifact_path(signature, scorer_name, backend)
        try:
            art = CompiledBankingPlan.from_json(json.loads(p.read_text()))
        except _MISS_ERRORS:
            return None
        self._touch(p)
        return art

    def put_artifact(self, artifact: CompiledBankingPlan) -> None:
        path = self.artifact_path(artifact.signature, artifact.scorer_name,
                                  artifact.backend)
        self._write_locked(path, artifact.to_json())

    # -- telemetry sidecar ------------------------------------------------------
    def telemetry_path(self, signature: str) -> Path:
        return self.path / "telemetry" / f"{signature}.json"

    def get_telemetry(self, signature: str) -> list:
        """Lock-free read of one signature's telemetry sidecar -- same
        torn-JSON-is-a-miss discipline as plan reads."""
        from .telemetry import TELEMETRY_FORMAT, MeasuredCost

        p = self.telemetry_path(signature)
        try:
            d = json.loads(p.read_text())
            if d.get("format") != TELEMETRY_FORMAT:
                return []
            return [MeasuredCost.from_json(r) for r in d["records"]]
        except _MISS_ERRORS:
            return []

    def merge_telemetry(self, signature: str, records) -> None:
        """Read-merge-write of the sidecar under the store lock, so two
        processes flushing observations concurrently lose nothing.  A
        torn sidecar is abandoned (observations are cheap to re-earn);
        the merged write heals it."""
        from .telemetry import TELEMETRY_FORMAT, MeasuredCost

        records = list(records)
        if not records:
            return
        path = self.telemetry_path(signature)
        try:
            with self._lock():
                table: Dict[tuple, object] = {}
                try:
                    d = json.loads(path.read_text())
                    if d.get("format") == TELEMETRY_FORMAT:
                        for r in d["records"]:
                            rec = MeasuredCost.from_json(r)
                            table[rec.key] = rec
                except _MISS_ERRORS:
                    table = {}  # absent or torn: start fresh
                for rec in records:
                    mine = table.get(rec.key)
                    if mine is None:
                        table[rec.key] = rec.copy()
                    else:
                        mine.merge(rec)
                payload = {"format": TELEMETRY_FORMAT,
                           "records": [r.to_json() for r in table.values()]}
                path.parent.mkdir(parents=True, exist_ok=True)
                tmp = path.with_suffix(path.suffix + f".tmp{os.getpid()}")
                tmp.write_text(json.dumps(payload))
                tmp.replace(path)
        except (TimeoutError, OSError):
            pass  # best-effort, like every other durable write here

    # -- certificate sidecar ----------------------------------------------------
    def certificate_path(self, signature: str, scorer_name: str) -> Path:
        return (self.path / "certs"
                / f"{signature}.{_safe(scorer_name)}.json")

    def get_certificate(self, signature: str, scorer_name: str):
        """Lock-free read of one plan's certificate sidecar -- torn or
        foreign JSON reads as None, same discipline as plan reads."""
        p = self.certificate_path(signature, scorer_name)
        try:
            return json.loads(p.read_text())
        except _MISS_ERRORS:
            return None

    def put_certificate(self, signature: str, scorer_name: str,
                        cert: dict) -> None:
        """Atomic tmp+rename write under the store lock, mirroring the
        telemetry sidecar (certs/ sits outside the LRU byte cap)."""
        path = self.certificate_path(signature, scorer_name)
        try:
            with self._lock():
                path.parent.mkdir(parents=True, exist_ok=True)
                tmp = path.with_suffix(path.suffix + f".tmp{os.getpid()}")
                tmp.write_text(json.dumps(cert, indent=1, sort_keys=True))
                tmp.replace(path)
        except (TimeoutError, OSError):
            pass  # best-effort, like every other durable write here

    # -- joint-plan sidecar ------------------------------------------------------
    def joint_path(self, signature: str) -> Path:
        return self.path / "joint" / f"{signature}.json"

    def get_joint(self, signature: str):
        """Lock-free read of one joint plan -- torn or foreign JSON
        reads as None, same discipline as plan reads.  ``joint/`` holds
        ``jp1-*`` whole-model selections, outside the plan LRU cap."""
        from .jointplan import JointPlan

        p = self.joint_path(signature)
        try:
            plan = JointPlan.from_json(json.loads(p.read_text()))
        except _MISS_ERRORS:
            return None
        self._touch(p)
        plan.status = "cached-disk"
        return plan

    def put_joint(self, plan) -> None:
        """Atomic tmp+rename write under the store lock, mirroring the
        certificate sidecar."""
        path = self.joint_path(plan.signature)
        try:
            with self._lock():
                path.parent.mkdir(parents=True, exist_ok=True)
                tmp = path.with_suffix(path.suffix + f".tmp{os.getpid()}")
                tmp.write_text(json.dumps(plan.to_json(), indent=1,
                                          sort_keys=True))
                tmp.replace(path)
        except (TimeoutError, OSError):
            pass  # best-effort, like every other durable write here

    # -- demotion ---------------------------------------------------------------
    def delete(self, signature: str, scorer_name: str) -> None:
        """Unlink a plan and its compiled artifacts (demotion eviction).
        The telemetry sidecar survives -- measurements stay evidence;
        the certificate goes with the scheme it certified."""
        try:
            with self._lock():
                try:
                    self.plan_path(signature, scorer_name).unlink()
                except OSError:
                    pass
                try:
                    self.certificate_path(signature, scorer_name).unlink()
                except OSError:
                    pass
                pattern = f"{signature}.{_safe(scorer_name)}.*.compiled.json"
                for f in self.path.glob(pattern):
                    try:
                        f.unlink()
                    except OSError:
                        pass
        except (TimeoutError, OSError):
            pass

    @staticmethod
    def _touch(path: Path) -> None:
        """Freshen mtime on a read hit, so LRU eviction spares hot
        entries.  Best-effort: a read-only store still serves."""
        try:
            os.utime(path)
        except OSError:
            pass

    def _write_locked(self, path: Path, payload: dict) -> None:
        blob = json.dumps(payload, indent=1, sort_keys=True)
        try:
            with self._lock():
                tmp = path.with_suffix(path.suffix + f".tmp{os.getpid()}")
                tmp.write_text(blob)
                tmp.replace(path)
                self._evict_locked()
        except (TimeoutError, OSError):
            pass  # durability is best-effort; in-memory caches still hold

    # -- eviction + versioning ---------------------------------------------------
    def _entries(self):
        """(path, mtime, size) of every plan/artifact file.  Foreign
        files sharing the directory (``ml_scorer.json``, the lock, tmp
        leftovers) are never eviction candidates."""
        out = []
        for f in self.path.glob("bp*.json"):
            try:
                st = f.stat()
            except OSError:
                continue
            out.append((f, st.st_mtime, st.st_size))
        return out

    def _evict_locked(self) -> int:
        """Drop least-recently-used entries until under ``max_bytes``.
        Caller holds the store lock."""
        if self.max_bytes is None:
            return 0
        entries = self._entries()
        total = sum(size for _, _, size in entries)
        removed = 0
        for f, _, size in sorted(entries, key=lambda e: e[1]):
            if total <= self.max_bytes:
                break
            try:
                f.unlink()
            except OSError:
                continue  # another process got there first
            total -= size
            removed += 1
        return removed

    def sweep(self) -> int:
        """Garbage-collect entries whose ``SIGNATURE_VERSION`` is stale.

        Signatures embed the version in their prefix (``bp<V>-``); a
        version bump makes every older entry unreachable -- no probe
        will ever hash to its key again -- so they only waste the size
        budget.  Returns the number of files removed.
        """
        from .planner import SIGNATURE_VERSION

        live = f"bp{SIGNATURE_VERSION}-"
        removed = 0
        try:
            with self._lock():
                for f, _, _ in self._entries():
                    if not f.name.startswith(live):
                        try:
                            f.unlink()
                            removed += 1
                        except OSError:
                            pass
        except (TimeoutError, OSError):
            pass
        return removed

    # -- enumeration -----------------------------------------------------------
    def plans(self) -> Iterable:
        from .planner import BankingPlan

        for f in sorted(self.path.glob("*.json")):
            if f.name.endswith(".compiled.json"):
                continue
            try:
                yield BankingPlan.from_json(json.loads(f.read_text()))
            except _MISS_ERRORS:
                continue

    def artifacts(self) -> Iterable[CompiledBankingPlan]:
        for f in sorted(self.path.glob("*.compiled.json")):
            try:
                yield CompiledBankingPlan.from_json(json.loads(f.read_text()))
            except _MISS_ERRORS:
                continue

    # -- near-match index --------------------------------------------------------
    def find_family(self, family: str, *,
                    exclude_signature: str = ""):
        """Same-family near-match via a directory-mtime-invalidated index.

        The base-class scan would deserialize every plan (rebuilding its
        resolution graphs) on every cold submit; here the raw JSON is
        skimmed once per directory change for (family, created_at,
        signature) and only the chosen plan is actually loaded.
        """
        if not family:
            return None
        self._refresh_family_index()
        with self._index_lock:
            hit = self._family_index.get(family)
        if hit is None:
            return None
        if hit[1] == exclude_signature:
            # the newest family member is the excluded one; fall back to
            # the (rare) full scan for an older sibling
            return super().find_family(family,
                                       exclude_signature=exclude_signature)
        return self.get(hit[1], hit[2])

    def _refresh_family_index(self) -> None:
        try:
            mtime = self.path.stat().st_mtime_ns
        except OSError:
            return
        with self._index_lock:
            if mtime == self._family_mtime:
                return
        index: Dict[str, Tuple[float, str, str]] = {}
        for f in self.path.glob("*.json"):
            if f.name.endswith(".compiled.json"):
                continue
            try:
                d = json.loads(f.read_text())
                fam = d.get("family", "")
                if not fam or d.get("best") is None:
                    continue
                entry = (float(d.get("created_at", 0.0)),
                         d["signature"], d.get("scorer_name", "proxy"))
            except _MISS_ERRORS:
                continue
            if fam not in index or entry > index[fam]:
                index[fam] = entry
        with self._index_lock:
            self._family_mtime = mtime
            self._family_index = index


def as_store(store_or_path) -> Optional[PlanStore]:
    """Coerce ``None`` / a PlanStore / a directory path to a PlanStore."""
    if store_or_path is None or isinstance(store_or_path, PlanStore):
        return store_or_path
    return DirectoryStore(store_or_path)


__all__ = [
    "DirectoryStore",
    "FileLock",
    "MemoryStore",
    "PlanStore",
    "as_store",
]
