"""DEPRECATED free-function banking API -- use ``core.planner`` instead.

The front door of the banking system is now the **planner subsystem**
(:mod:`repro.core.planner`): ``BankingPlanner`` produces durable
``BankingPlan`` artifacts keyed by canonical program signatures, cached
in memory (and optionally on disk as JSON), ranked through the scorer
registry (``"proxy"``, ``"ml"``, or any registered callable), and solved
in parallel across memories by ``plan_all``::

    from repro.core import BankingPlanner

    planner = BankingPlanner()
    plan = planner.plan(program, "table")      # cache hit on repeat calls
    plan.best.describe()
    plan.save("plans/table.json")              # warm-start a later run

``partition_memory`` / ``partition_all`` below are thin deprecated shims
over a process-wide default planner, kept so existing snippets keep
working.  They run the same pipeline (paper Fig. 1: unroll -> build_groups
-> solve -> rank) but return the legacy ``BankingReport`` container and
emit a ``DeprecationWarning``.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from .controller import Program
from .polytope import AccessGroup
from .solver import BankingSolution, SolverOptions
from .planner import default_planner, rank_solutions  # noqa: F401 (re-export)


@dataclass
class BankingReport:
    """Legacy transient result container (superseded by ``BankingPlan``)."""

    memory: str
    groups: List[AccessGroup]
    solutions: List[BankingSolution]
    best: Optional[BankingSolution]
    solve_seconds: float
    num_candidates: int

    def table_row(self) -> Dict[str, float]:
        r = self.best.resources.total if self.best and self.best.resources else None
        return {
            "memory": self.memory,
            "lut": r.lut if r else float("nan"),
            "ff": r.ff if r else float("nan"),
            "bram": r.bram if r else 0,
            "dsp": r.dsp if r else 0,
            "banks": self.best.num_banks if self.best else 0,
            "seconds": self.solve_seconds,
        }


def _deprecated(name: str) -> None:
    warnings.warn(
        f"{name} is deprecated; use repro.core.BankingPlanner "
        f"(plan / plan_all) instead",
        DeprecationWarning,
        stacklevel=3,
    )


def partition_memory(
    program: Program,
    memory: str,
    opts: Optional[SolverOptions] = None,
    scorer: Optional[Callable[[BankingSolution], float]] = None,
) -> BankingReport:
    """Deprecated shim: one memory through the shared default planner."""
    _deprecated("partition_memory")
    return default_planner().plan(program, memory, opts=opts,
                                  scorer=scorer).to_report()


def partition_all(
    program: Program,
    opts: Optional[SolverOptions] = None,
    scorer: Optional[Callable[[BankingSolution], float]] = None,
) -> Dict[str, BankingReport]:
    """Deprecated shim: every memory, via the planner's threaded batch."""
    _deprecated("partition_all")
    plans = default_planner().plan_all(program, opts=opts, scorer=scorer)
    return {name: p.to_report() for name, p in plans.items()}
