"""Top-level banking API (paper Fig. 1: accesses + concurrency -> scheme).

``partition_memory`` is the end-to-end pipeline:

    program (controller tree)
      -> unroll                (Sec 2.4.3: lanes + UIDs + synchronization)
      -> build_groups          (Sec 3.2, Fig. 8)
      -> solve                 (Sec 3.3: candidate geometries, validity)
      -> transforms            (Sec 3.4: applied inside solve)
      -> rank                  (Sec 3.5: ML cost model; proxy fallback)
      -> best BankingSolution
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from .controller import Program, UnrolledProgram, unroll
from .grouping import build_groups
from .polytope import AccessGroup, Iterator, MemorySpec
from .solver import BankingSolution, SolverOptions, solve


@dataclass
class BankingReport:
    memory: str
    groups: List[AccessGroup]
    solutions: List[BankingSolution]
    best: Optional[BankingSolution]
    solve_seconds: float
    num_candidates: int

    def table_row(self) -> Dict[str, float]:
        r = self.best.resources.total if self.best and self.best.resources else None
        return {
            "memory": self.memory,
            "lut": r.lut if r else float("nan"),
            "ff": r.ff if r else float("nan"),
            "bram": r.bram if r else 0,
            "dsp": r.dsp if r else 0,
            "banks": self.best.num_banks if self.best else 0,
            "seconds": self.solve_seconds,
        }


def rank_solutions(
    sols: List[BankingSolution],
    scorer: Optional[Callable[[BankingSolution], float]] = None,
) -> List[BankingSolution]:
    """Order candidate schemes best-first.

    ``scorer`` is normally the ML cost model (core.cost_model.MLScorer);
    without one we fall back to the weighted resource proxy -- this fallback
    is exactly the 'first-order rules' behaviour the paper improves upon.
    """
    for s in sols:
        if scorer is not None:
            s.score = float(scorer(s))
        elif s.resources is not None:
            s.score = s.resources.total.weighted()
    return sorted(sols, key=lambda s: s.score)


def partition_memory(
    program: Program,
    memory: str,
    opts: Optional[SolverOptions] = None,
    scorer: Optional[Callable[[BankingSolution], float]] = None,
) -> BankingReport:
    t0 = time.perf_counter()
    up = unroll(program)
    groups = build_groups(up, memory)
    mem = program.memories[memory]
    sols = solve(mem, groups, up.iterators, opts)
    ranked = rank_solutions(sols, scorer)
    dt = time.perf_counter() - t0
    return BankingReport(
        memory=memory,
        groups=groups,
        solutions=ranked,
        best=ranked[0] if ranked else None,
        solve_seconds=dt,
        num_candidates=len(sols),
    )


def partition_all(
    program: Program,
    opts: Optional[SolverOptions] = None,
    scorer: Optional[Callable[[BankingSolution], float]] = None,
) -> Dict[str, BankingReport]:
    return {
        name: partition_memory(program, name, opts, scorer)
        for name in program.memories
    }
