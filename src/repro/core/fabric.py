"""SolveFabric: remote shard workers with live cut broadcast.

PR 4 made the cold solve a shardable pipeline -- ``CandidateSpace``
enumerates, ``SolveShard``s evaluate anywhere, one ``SolutionReducer``
merges -- and ``evaluate_parallel`` proved the work-unit/cut protocol
over a local fork pool.  This module lifts the same protocol onto
**remote worker processes** (one reducer, many hosts) so huge
multi-memory programs solve at wire speed:

* The fabric listens on a socket; ``launch/solve_worker.py <host:port>``
  attaches any number of worker processes (run it on N hosts to attach
  N hosts to one service).
* Each solve ships its :class:`~repro.core.candidates.CandidateSpace`
  **once** per worker (``space_to_wire``), then **leases** small work
  units -- candidate index lists -- against it.  A worker keeps the
  rebuilt space (and its conflict cache) for the solve's lifetime, so
  memoized residue analyses span all of that worker's leases.
* Scored :class:`~repro.core.solver.BankingSolution` streams flow back
  incrementally (``events_to_wire`` batches) into the single
  :class:`~repro.core.candidates.SolutionReducer`, so
  ``ticket.best_so_far()`` and server promotions work identically
  whether shards ran in-process or on three other machines.
* **Cut broadcast**: whenever the reducer publishes a new section cut,
  the fabric pushes the snapshot to every worker with an in-flight
  lease of that solve (and stamps it on every future lease), so remote
  shards prune beyond-cut candidates as aggressively as the monolithic
  search.  Dispatch itself is cut-filtered too: once a cap is provably
  reached, none of that section's remaining candidates are ever leased.
* **Fault tolerance**: a worker that dies (EOF) or times out has its
  leases requeued with that worker *excluded*; a unit no live worker
  may take is evaluated locally by the driving thread, so the solve
  always converges to the exact monolithic answer.
* **Backpressure**: each worker holds at most ``lease_window``
  outstanding leases; further units queue at the fabric until a lease
  drains.

Wire format: 4-byte big-endian length + pickled dict frames.  Workers
are trusted peers of the service (pickle!) -- bind the fabric to a
private interface.
"""

from __future__ import annotations

import os
import pickle
import queue
import socket
import struct
import subprocess
import sys
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .candidates import (
    CandidateSpace,
    SolutionReducer,
    evaluate,
    events_from_wire,
    shard_from_indices,
    space_to_wire,
)

_LEN = struct.Struct("!I")
# Hard ceiling audited BEFORE any allocation or unpickle: a corrupt or
# hostile peer announcing a huge length prefix must not make the reader
# allocate it.  64 MiB clears the biggest wired candidate space by two
# orders of magnitude; raise via max_frame= on read_frame if a future
# payload legitimately outgrows it.
_MAX_FRAME = 64 << 20
_WIRE_PROTO = pickle.HIGHEST_PROTOCOL


# ---------------------------------------------------------------------------
# Framing (shared with launch/solve_worker.py)
# ---------------------------------------------------------------------------


def write_frame(sock: socket.socket, msg: dict,
                lock: Optional[threading.Lock] = None) -> None:
    blob = pickle.dumps(msg, protocol=_WIRE_PROTO)
    data = _LEN.pack(len(blob)) + blob
    if lock is not None:
        with lock:
            sock.sendall(data)
    else:
        sock.sendall(data)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise EOFError("peer closed the connection")
        buf += chunk
    return buf


def read_frame(sock: socket.socket,
               max_frame: int = _MAX_FRAME) -> dict:
    (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    if n > max_frame:
        # audit the length prefix before allocating anything for it
        raise ValueError(f"frame of {n} bytes exceeds the "
                         f"{max_frame}-byte wire bound")
    return pickle.loads(_recv_exact(sock, n))


# ---------------------------------------------------------------------------
# Book-keeping
# ---------------------------------------------------------------------------


@dataclass
class FabricStats:
    """Cumulative counters across every solve this fabric ran."""

    solves: int = 0
    leases: int = 0
    requeues: int = 0         # leases re-issued after worker death/timeout
    cut_broadcasts: int = 0   # cut snapshots pushed to in-flight workers
    results_frames: int = 0   # result batches received off the wire
    evaluated: int = 0        # candidate evaluations reported by workers
    local_evaluated: int = 0  # orphan units evaluated by the driving thread
    workers_joined: int = 0
    workers_lost: int = 0
    cert_rejected: int = 0    # result batches refused by a solve's verifier
    heartbeats: int = 0       # liveness frames received from workers


@dataclass
class FabricReport:
    """Per-solve accounting, returned by :meth:`SolveFabric.solve`."""

    leases: int = 0
    requeues: int = 0
    cut_broadcasts: int = 0
    evaluated: int = 0
    local_evaluated: int = 0
    workers_used: int = 0
    workers_lost: int = 0    # deaths of workers holding this solve's leases
    cert_rejected: int = 0   # result batches refused by the verifier
    heartbeats: int = 0      # hb frames from workers holding our leases
    peak_leases: int = 0     # max concurrently outstanding leases


@dataclass
class _Unit:
    """One leasable work unit: a contiguous candidate index run, plus
    the workers excluded from taking it (they died or timed out holding
    its lease)."""

    indices: Tuple[int, ...]
    excluded: frozenset = frozenset()


@dataclass
class _Lease:
    lease_id: int
    unit: _Unit
    solve: "_FabricSolve"
    worker_id: int
    issued_at: float
    # perf_counter twin of issued_at: trace spans live on the
    # perf_counter clock, and worker-side spans rebase onto this
    issued_pc: float = 0.0


class _Worker:
    def __init__(self, wid: int, sock: socket.socket, addr):
        self.wid = wid
        self.sock = sock
        self.addr = addr
        self.send_lock = threading.Lock()
        # all scheduler traffic goes through one ordered queue drained
        # by a dedicated sender thread, so a worker can never see a
        # lease before the space frame it depends on
        self.sendq: "queue.Queue" = queue.Queue()
        self.outstanding: Dict[int, _Lease] = {}
        self.spaces: set = set()      # solve_ids whose space was shipped
        self.alive = True
        self.last_seen = time.monotonic()  # any frame refreshes this
        self.hb_seen = False          # worker speaks the heartbeat frame


class _FabricSolve:
    def __init__(self, solve_id: int, space: CandidateSpace,
                 reducer: SolutionReducer, verifier=None,
                 lease_cap: Optional[int] = None, trace=None):
        self.solve_id = solve_id
        self.space = space
        self.reducer = reducer
        self.verifier = verifier          # untrusted-result gate (or None)
        self.lease_cap = lease_cap        # max concurrent leases (QoS)
        self.trace = trace                # (Tracer, trace_id) or None
        self.payload = space_to_wire(space)
        self.pending: deque = deque()
        self.outstanding: Dict[int, _Lease] = {}
        self.cuts_sent: Dict[int, int] = {}
        self.report = FabricReport()
        self.workers_used: set = set()
        self.finished = False


# ---------------------------------------------------------------------------
# The fabric
# ---------------------------------------------------------------------------


class SolveFabric:
    """Coordinator for remote shard workers (see module docstring).

    Parameters
    ----------
    listen : ``(host, port)`` to accept workers on (port 0 = ephemeral)
    chunk : default candidates per lease (per-solve override via
        ``solve(chunk=...)``)
    lease_window : max outstanding leases per worker (backpressure)
    lease_timeout : seconds before an unanswered lease is requeued with
        the slow worker excluded
    hb_timeout : seconds of total silence (no frame of any kind) after
        which a worker that HAS sent heartbeat frames is declared dead
        and dropped -- far cheaper than waiting out ``lease_timeout``,
        since workers heartbeat every couple of seconds
        (``solve_worker.py --hb-interval``).  Workers that never sent a
        heartbeat (older clients) are exempt and only age out via the
        lease timeout.
    broadcast_cuts : distribute reducer cuts (lease stamping, mid-flight
        broadcast, and dispatch-time filtering); disable only to measure
        what the cut protocol saves
    """

    def __init__(self, listen: Tuple[str, int] = ("127.0.0.1", 0), *,
                 chunk: int = 32, lease_window: int = 2,
                 lease_timeout: float = 60.0,
                 hb_timeout: float = 10.0,
                 broadcast_cuts: bool = True):
        self.chunk = max(1, int(chunk))
        self.lease_window = max(1, int(lease_window))
        self.lease_timeout = float(lease_timeout)
        self.hb_timeout = float(hb_timeout)
        self.broadcast_cuts = broadcast_cuts
        self.stats = FabricStats()
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._workers: Dict[int, _Worker] = {}
        self._leases: Dict[int, _Lease] = {}
        self._solves: Dict[int, _FabricSolve] = {}
        self._next_worker = iter(range(1 << 62)).__next__
        self._next_lease = iter(range(1 << 62)).__next__
        self._next_solve = iter(range(1 << 62)).__next__
        self._shutdown = False
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind(listen)
        self._listener.listen(64)
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True, name="fabric-accept")
        self._accept_thread.start()

    # -- addressing / membership ---------------------------------------------
    @property
    def address(self) -> str:
        """``host:port`` workers attach to (``solve_worker.py`` argv)."""
        host, port = self._listener.getsockname()[:2]
        return f"{host}:{port}"

    @property
    def workers_alive(self) -> int:
        with self._lock:
            return sum(1 for w in self._workers.values() if w.alive)

    def wait_for_workers(self, n: int, timeout: float = 30.0) -> bool:
        """Block until ``n`` workers are attached (True) or time out."""
        deadline = time.monotonic() + timeout
        with self._cond:
            while sum(1 for w in self._workers.values() if w.alive) < n:
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                self._cond.wait(left)
        return True

    # -- accept / read loops --------------------------------------------------
    def _accept_loop(self) -> None:
        while True:
            try:
                sock, addr = self._listener.accept()
            except OSError:
                return                    # listener closed: shutting down
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._cond:
                if self._shutdown:
                    sock.close()
                    return
                worker = _Worker(self._next_worker(), sock, addr)
                self._workers[worker.wid] = worker
                self.stats.workers_joined += 1
                self._cond.notify_all()
            threading.Thread(target=self._read_loop, args=(worker,),
                             daemon=True,
                             name=f"fabric-read-{worker.wid}").start()
            threading.Thread(target=self._send_loop, args=(worker,),
                             daemon=True,
                             name=f"fabric-send-{worker.wid}").start()
            with self._cond:
                self._pump()

    def _send_loop(self, worker: _Worker) -> None:
        """Drain the worker's ordered send queue (None = stop)."""
        while True:
            msg = worker.sendq.get()
            if msg is None:
                return
            try:
                write_frame(worker.sock, msg, worker.send_lock)
            except OSError:
                self._drop_worker(worker)
                return

    def _read_loop(self, worker: _Worker) -> None:
        try:
            while True:
                msg = read_frame(worker.sock)
                t = msg.get("t")
                if t == "results":
                    self._on_results(worker, msg)
                elif t == "done":
                    self._on_done(worker, msg)
                elif t == "error":
                    self._on_error(worker, msg)
                elif t == "hb":
                    self._on_hb(worker)
                # "join" is informational (pid/host for debugging)
        except Exception:
            # dead socket, poisoned frame, or a handler error (e.g. a
            # custom scorer raising inside reducer.add): in every case
            # the worker must be dropped so its leases requeue instead
            # of burning the full lease timeout on a deaf connection
            pass
        self._drop_worker(worker)

    # -- message handling -----------------------------------------------------
    def _touch_worker(self, worker: _Worker) -> None:
        """Any frame proves the worker alive: refresh EVERY lease it
        holds (a queued second lease must not time out while the worker
        is legitimately busy on its first).  Caller holds the lock."""
        now = time.monotonic()
        worker.last_seen = now
        for lease in worker.outstanding.values():
            lease.issued_at = now

    def _on_hb(self, worker: _Worker) -> None:
        """A heartbeat proves the PROCESS alive -- it refreshes worker
        liveness but deliberately NOT lease ``issued_at``: a worker that
        heartbeats while hung on a lease must still lose that lease to
        the lease timeout.  The frames are counted per solve the worker
        holds leases for, so ``ServiceStats.fabric_heartbeats`` can
        attribute them to tenants."""
        with self._lock:
            worker.last_seen = time.monotonic()
            worker.hb_seen = True
            self.stats.heartbeats += 1
            for solve in {lease.solve for lease in
                          worker.outstanding.values()}:
                solve.report.heartbeats += 1

    def _on_results(self, worker: _Worker, msg: dict) -> None:
        with self._lock:
            lease = self._leases.get(msg["lease_id"])
            self.stats.results_frames += 1
            self._touch_worker(worker)
        if lease is None:
            return                        # late frame of a requeued lease
        solve = lease.solve
        # decode + verify + reduce outside the fabric lock: certifying
        # and scoring can be heavy
        events = list(events_from_wire(msg["payload"]))
        if solve.verifier is not None:
            rejection = solve.verifier(events)
            if rejection is not None:
                # untrusted result failed certification: drop the whole
                # batch, take the lease away, and requeue its unit with
                # this worker excluded -- the unit re-runs elsewhere (or
                # locally on the driving thread), so the solve still
                # converges to the exact monolithic answer
                with self._cond:
                    solve.report.cert_rejected += 1
                    self.stats.cert_rejected += 1
                    live = self._leases.pop(lease.lease_id, None)
                    if live is not None:
                        worker.outstanding.pop(lease.lease_id, None)
                        self._requeue(live)
                        self._pump()
                        self._cond.notify_all()
                if solve.trace is not None:
                    tr, tid = solve.trace
                    tr.instant(tid, "cert-reject",
                               worker=worker.wid,
                               lease_id=lease.lease_id)
                    tr.note_anomaly("cert-rejection",
                                    detail=f"worker-{worker.wid}")
                return
        for ev in events:
            solve.reducer.add(ev)
        self._publish_cuts(solve)

    def _publish_cuts(self, solve: _FabricSolve) -> None:
        """Push newly published reducer cuts to workers holding leases
        of this solve."""
        if not self.broadcast_cuts:
            return
        cuts = solve.reducer.cuts()
        targets: List[_Worker] = []
        with self._lock:
            if len(cuts) == len(solve.cuts_sent) or solve.finished:
                return                    # cuts only ever appear
            solve.cuts_sent = cuts
            seen = set()
            for lease in solve.outstanding.values():
                w = self._workers.get(lease.worker_id)
                if w is not None and w.alive and w.wid not in seen:
                    seen.add(w.wid)
                    targets.append(w)
            solve.report.cut_broadcasts += 1
            self.stats.cut_broadcasts += 1
        if solve.trace is not None:
            tr, tid = solve.trace
            tr.instant(tid, "cut-broadcast", workers=len(targets),
                       cuts=len(cuts))
        for w in targets:
            w.sendq.put({"t": "cuts", "solve_id": solve.solve_id,
                         "cuts": cuts})

    def _on_done(self, worker: _Worker, msg: dict) -> None:
        with self._cond:
            self._touch_worker(worker)
            lease = self._leases.pop(msg["lease_id"], None)
            if lease is None:
                return                    # lease was requeued already
            worker.outstanding.pop(lease.lease_id, None)
            lease.solve.outstanding.pop(lease.lease_id, None)
            n = int(msg.get("evaluated", 0))
            lease.solve.report.evaluated += n
            self.stats.evaluated += n
            self._pump()
            self._cond.notify_all()
        trace = lease.solve.trace
        if trace is not None:
            tr, tid = trace
            # the driver-side lease span (issue -> done) plus whatever
            # spans the worker measured locally, rebased onto the
            # lease's issue time so the whole tree shares one clock
            tr.record(tid, "lease", lease.issued_pc,
                      time.perf_counter(), worker=lease.worker_id,
                      lease_id=lease.lease_id, evaluated=n)
            tr.add_remote_spans(tid, msg.get("spans"),
                                base=lease.issued_pc,
                                origin=f"worker-{lease.worker_id}")

    def _on_error(self, worker: _Worker, msg: dict) -> None:
        with self._cond:
            lease = self._leases.pop(msg["lease_id"], None)
            if lease is None:
                return
            worker.outstanding.pop(lease.lease_id, None)
            self._requeue(lease)
            self._pump()
            self._cond.notify_all()

    def _drop_worker(self, worker: _Worker) -> None:
        with self._cond:
            if not worker.alive:
                return
            worker.alive = False
            self._workers.pop(worker.wid, None)
            self.stats.workers_lost += 1
            # the loss belongs to the solves that held leases on this
            # worker -- concurrent solves must not each claim it
            hit: Dict[int, _FabricSolve] = {}
            for lease in list(worker.outstanding.values()):
                self._leases.pop(lease.lease_id, None)
                self._requeue(lease)
                hit[lease.solve.solve_id] = lease.solve
            for solve in hit.values():
                solve.report.workers_lost += 1
            worker.outstanding.clear()
            self._pump()
            self._cond.notify_all()
        worker.sendq.put(None)            # stop the sender thread
        try:
            worker.sock.close()
        except OSError:
            pass

    def _requeue(self, lease: _Lease) -> None:
        """Give a failed lease's unit back to the queue, excluding the
        worker it failed on (caller holds the lock)."""
        solve = lease.solve
        solve.outstanding.pop(lease.lease_id, None)
        if solve.finished:
            return
        unit = _Unit(indices=lease.unit.indices,
                     excluded=lease.unit.excluded | {lease.worker_id})
        solve.pending.appendleft(unit)
        solve.report.requeues += 1
        self.stats.requeues += 1
        if solve.trace is not None:
            tr, tid = solve.trace
            tr.instant(tid, "requeue", worker=lease.worker_id,
                       lease_id=lease.lease_id,
                       units=len(unit.indices))

    # -- scheduling -----------------------------------------------------------
    def _cut_filter(self, solve: _FabricSolve,
                    indices: Sequence[int]) -> List[int]:
        """Drop candidates provably beyond a published cut (dispatch-time
        pruning; racy reads are safe -- cuts only ever appear)."""
        if not self.broadcast_cuts:      # measurement mode: no cut help
            return list(indices)
        cuts = solve.reducer.cuts()
        if not cuts:
            return list(indices)
        space = solve.space
        out = []
        for i in indices:
            cand = space.candidates[i]
            cut = cuts.get(cand.section)
            if cut is None or cand.index <= cut:
                out.append(i)
        return out

    def _pump(self) -> None:
        """Assign pending units to workers with lease capacity (caller
        holds the lock).  Frames go onto each worker's ordered send
        queue -- never blocking here, and always space-before-lease."""
        for solve in self._solves.values():
            if solve.finished:
                continue
            still_pending: deque = deque()
            while solve.pending:
                if (solve.lease_cap is not None
                        and len(solve.outstanding) >= solve.lease_cap):
                    # QoS cap: this solve may not hold more concurrent
                    # leases -- other solves' units still dispatch
                    break
                unit = solve.pending.popleft()
                target = None
                capacity = False
                for w in self._workers.values():
                    if (w.alive
                            and len(w.outstanding) < self.lease_window):
                        capacity = True
                        if w.wid not in unit.excluded:
                            target = w
                            break
                if target is None:
                    still_pending.append(unit)
                    if not capacity:
                        break             # no capacity anywhere: stop
                    continue              # only exclusions blocked this
                                          # unit: later ones may still go
                indices = self._cut_filter(solve, unit.indices)
                if not indices:
                    continue              # whole unit beyond the cuts
                lease = _Lease(lease_id=self._next_lease(), unit=unit,
                               solve=solve, worker_id=target.wid,
                               issued_at=time.monotonic(),
                               issued_pc=time.perf_counter())
                self._leases[lease.lease_id] = lease
                target.outstanding[lease.lease_id] = lease
                solve.outstanding[lease.lease_id] = lease
                solve.workers_used.add(target.wid)
                solve.report.leases += 1
                solve.report.peak_leases = max(solve.report.peak_leases,
                                               len(solve.outstanding))
                self.stats.leases += 1
                if solve.solve_id not in target.spaces:
                    target.spaces.add(solve.solve_id)
                    target.sendq.put({"t": "space",
                                      "solve_id": solve.solve_id,
                                      "payload": solve.payload})
                frame = {
                    "t": "lease", "solve_id": solve.solve_id,
                    "lease_id": lease.lease_id, "indices": indices,
                    "cuts": (solve.cuts_sent if self.broadcast_cuts
                             else {}),
                }
                if solve.trace is not None:
                    # trace_id rides the wire; workers that predate the
                    # key ignore it, and their done frames simply carry
                    # no spans back
                    frame["trace"] = solve.trace[1]
                target.sendq.put(frame)
            still_pending.extend(solve.pending)
            solve.pending = still_pending

    def _check_timeouts(self, solve: _FabricSolve) -> None:
        now = time.monotonic()
        # heartbeat liveness first: a worker that speaks the hb frame
        # and then goes silent (process death, network partition) is
        # dropped after hb_timeout instead of burning the much longer
        # lease_timeout.  Collect under the lock, drop outside it
        # (_drop_worker takes the condition itself).
        with self._lock:
            silent = [w for w in self._workers.values()
                      if w.alive and w.hb_seen
                      and now - w.last_seen > self.hb_timeout]
        for w in silent:
            self._drop_worker(w)
        with self._cond:
            for lease in list(solve.outstanding.values()):
                if now - lease.issued_at > self.lease_timeout:
                    self._leases.pop(lease.lease_id, None)
                    w = self._workers.get(lease.worker_id)
                    if w is not None:
                        w.outstanding.pop(lease.lease_id, None)
                    self._requeue(lease)
            self._pump()

    def _orphan_units(self, solve: _FabricSolve) -> List[_Unit]:
        """Units no live worker may take (caller holds the lock)."""
        alive = {w.wid for w in self._workers.values() if w.alive}
        out, keep = [], deque()
        for unit in solve.pending:
            if not alive or alive <= unit.excluded:
                out.append(unit)
            else:
                keep.append(unit)
        solve.pending = keep
        return out

    # -- the driver -----------------------------------------------------------
    def solve(self, space: CandidateSpace, *,
              reducer: Optional[SolutionReducer] = None,
              scorer=None, chunk: Optional[int] = None,
              verifier=None,
              lease_cap: Optional[int] = None,
              trace=None) -> FabricReport:
        """Evaluate ``space`` across the attached workers, merging every
        stream into ``reducer`` (one is created when omitted -- read the
        merged result off ``reducer.finalize()``).  Blocks until every
        candidate is accounted for; the calling thread doubles as the
        fallback evaluator for units no live worker may take, so the
        solve converges even if every worker dies mid-flight.

        ``verifier`` gates every remote result batch before it reaches
        the reducer: called with the decoded event list, ``None`` means
        accept, anything else rejects the batch and requeues its unit
        away from the sending worker (``FabricReport.cert_rejected``).
        Locally evaluated orphan units bypass it -- they never crossed
        the trust boundary.  Build one with
        ``repro.analysis.make_batch_verifier(space)``.

        ``lease_cap`` bounds this solve's CONCURRENT outstanding leases
        (a low-QoS tenant's solve may not occupy every worker's lease
        window while an interactive solve waits); ``None`` = unbounded.

        ``trace`` is ``(tracer, trace_id)`` from the submitting
        service: the id is stamped on every lease frame (workers echo
        their measured spans on the done frame), and the driver records
        serialize / lease / requeue / cut-broadcast / local-eval spans
        under it -- the whole distributed solve stitches into ONE trace.
        """
        red = reducer if reducer is not None else SolutionReducer(
            space, scorer=scorer)
        step = max(1, int(chunk) if chunk is not None else self.chunk)
        n = len(space)
        # encoding the space (pickle + zlib) can take a while for big
        # problems: do it before touching the fabric lock so concurrent
        # solves' result intake and dispatch never stall behind it
        t_ser = time.perf_counter()
        solve = _FabricSolve(self._next_solve(), space, red,
                             verifier=verifier, lease_cap=lease_cap,
                             trace=trace)
        if trace is not None:
            trace[0].record(trace[1], "serialize", t_ser,
                            time.perf_counter(),
                            bytes=len(solve.payload), candidates=n)
        for lo in range(0, n, step):
            solve.pending.append(
                _Unit(indices=tuple(range(lo, min(lo + step, n)))))
        with self._cond:
            if self._shutdown:
                raise RuntimeError("SolveFabric is shut down")
            self._solves[solve.solve_id] = solve
            self.stats.solves += 1
            self._pump()
        try:
            while True:
                with self._cond:
                    if red.complete() or (not solve.pending
                                          and not solve.outstanding):
                        break
                    self._cond.wait(0.05)
                self._check_timeouts(solve)
                with self._lock:
                    orphans = self._orphan_units(solve)
                for unit in orphans:      # evaluate locally: always converge
                    idxs = self._cut_filter(solve, unit.indices)
                    if not idxs:
                        continue
                    local = 0
                    t_loc = time.perf_counter()
                    for ev in evaluate(shard_from_indices(space, idxs),
                                       gate=red):
                        red.add(ev)
                        local += 1
                    if trace is not None:
                        trace[0].record(trace[1], "local-eval", t_loc,
                                        time.perf_counter(),
                                        units=len(idxs), evaluated=local)
                    with self._lock:
                        solve.report.local_evaluated += local
                        self.stats.local_evaluated += local
        finally:
            retire: List[_Worker] = []
            with self._cond:
                solve.finished = True
                solve.pending.clear()
                for lease in list(solve.outstanding.values()):
                    self._leases.pop(lease.lease_id, None)
                    w = self._workers.get(lease.worker_id)
                    if w is not None:
                        w.outstanding.pop(lease.lease_id, None)
                solve.outstanding.clear()
                self._solves.pop(solve.solve_id, None)
                for w in self._workers.values():
                    if solve.solve_id in w.spaces and w.alive:
                        retire.append(w)
                solve.report.workers_used = len(solve.workers_used)
                self._cond.notify_all()
            for w in retire:
                w.sendq.put({"t": "retire", "solve_id": solve.solve_id})
        return solve.report

    # -- lifecycle ------------------------------------------------------------
    def shutdown(self) -> None:
        with self._cond:
            if self._shutdown:
                return
            self._shutdown = True
            workers = list(self._workers.values())
            self._cond.notify_all()
        try:
            self._listener.close()
        except OSError:
            pass
        for w in workers:
            try:
                write_frame(w.sock, {"t": "shutdown"}, w.send_lock)
            except OSError:
                pass
            w.sendq.put(None)
            try:
                w.sock.close()
            except OSError:
                pass

    def __enter__(self) -> "SolveFabric":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()


# ---------------------------------------------------------------------------
# Local worker helper (tests, benchmarks, the quickstart demo)
# ---------------------------------------------------------------------------


def spawn_local_workers(address: str, n: int, *,
                        python: Optional[str] = None,
                        hb_interval: Optional[float] = None
                        ) -> List[subprocess.Popen]:
    """Launch ``n`` solve-worker subprocesses attached to ``address``.

    The callers' ``src`` root is prepended to the children's
    ``PYTHONPATH`` so the workers resolve the same ``repro`` tree as
    this process.  ``hb_interval`` overrides the workers' heartbeat
    cadence (seconds).  Remember to ``terminate()`` them (and
    ``wait()``).
    """
    import repro

    # namespace-package safe: __path__ always exists, __file__ may not
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    argv = [python or sys.executable, "-m",
            "repro.launch.solve_worker", address]
    if hb_interval is not None:
        argv += ["--hb-interval", str(hb_interval)]
    return [subprocess.Popen(argv, env=env) for _ in range(n)]


__all__ = [
    "FabricReport",
    "FabricStats",
    "SolveFabric",
    "read_frame",
    "spawn_local_workers",
    "write_frame",
]
