"""Joint whole-model planning: co-select schemes under a shared budget.

Every memory used to take its locally-best scheme -- a model's KV pools,
MoE dispatch buffers, and SSM chunk state each greedily argmin'ing with
no global accounting.  The paper's headline wins (40.3% fewer logic
resources, 54.9% fewer BRAMs) come from choosing partitioning schemes
that share resources *across* arrays, so this module plans the whole
model at once:

* a :class:`JointRequest` bundles one ``Program``'s memories plus a
  global :class:`ResourceBudget` (banks / bank volume / LUT-FF-BRAM-DSP,
  the axes of :class:`~repro.core.resources.SchemeResources`);
* per-memory candidate spaces enumerate exactly as today, but instead of
  each memory's argmin a reducer keeps a small **Pareto frontier** per
  memory (predicted cost x resource axes, :func:`pareto_frontier`) with
  the trivial single-bank scheme *always* on it, so a feasible
  co-selection always exists;
* :func:`co_select` is an exact branch-and-bound DP over the kept
  frontiers: one scheme per memory, minimum total predicted cost,
  subject to the budget -- exhaustive for the frontier sizes we keep,
  with admissible per-axis/per-cost lower bounds pruning the product
  space.  Selection is a pure function of the frontiers (deterministic
  traversal, deterministic tie-breaks), so it is invariant to the order
  member solves happen to land in;
* the result persists as a :class:`JointPlan` -- member signatures +
  chosen schemes + budget -- through the ``PlanStore``'s ``joint/``
  sidecar, JSON round-trip like any ``BankingPlan``.

The service front door is :meth:`repro.core.service.PlanService
.submit_joint` -> :class:`~repro.core.service.JointTicket`: a ticket
*graph* whose per-memory solves fan out through the existing pool /
fabric executors and re-co-select progressively as members land.  The
runtime closes the loop with a coherent multi-pool hot-swap
(``runtime/server.py``): all of a model's pools promote to the jointly
selected layouts atomically between decode ticks.
"""

from __future__ import annotations

import hashlib
import json
import time
from dataclasses import asdict, dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .controller import Program
from .polytope import AccessGroup, Iterator, MemorySpec
from .solver import BankingSolution, SolverOptions

JOINT_FORMAT = "joint-plan/v1"
JOINT_SIGNATURE_PREFIX = "jp1-"

# The budget axes, in the order every use-vector tuple follows.
BUDGET_AXES = ("banks", "volume", "lut", "ff", "bram", "dsp")


# ---------------------------------------------------------------------------
# Budget currency
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ResourceUse:
    """One scheme's (or selection's) draw on the shared budget axes.

    ``banks`` counts physical banks (duplicates included), ``volume`` the
    total words of bank storage they hold; the rest come straight off
    ``SchemeResources.total``.
    """

    banks: int = 0
    volume: int = 0
    lut: float = 0.0
    ff: float = 0.0
    bram: int = 0
    dsp: int = 0

    def __add__(self, o: "ResourceUse") -> "ResourceUse":
        return ResourceUse(self.banks + o.banks, self.volume + o.volume,
                           self.lut + o.lut, self.ff + o.ff,
                           self.bram + o.bram, self.dsp + o.dsp)

    def axis(self, name: str) -> float:
        return getattr(self, name)

    def as_tuple(self) -> Tuple[float, ...]:
        return tuple(self.axis(a) for a in BUDGET_AXES)

    def as_dict(self) -> Dict[str, float]:
        return {a: self.axis(a) for a in BUDGET_AXES}

    @staticmethod
    def of_solution(sol: BankingSolution) -> "ResourceUse":
        banks = int(sol.num_banks) * max(1, int(sol.duplicates))
        r = sol.resources.total if sol.resources is not None else None
        return ResourceUse(
            banks=banks,
            volume=banks * int(sol.bank_volume),
            lut=float(r.lut) if r else 0.0,
            ff=float(r.ff) if r else 0.0,
            bram=int(r.bram) if r else banks,
            dsp=int(r.dsp) if r else 0,
        )

    @staticmethod
    def from_json(d: dict) -> "ResourceUse":
        return ResourceUse(**{a: d.get(a, 0) for a in BUDGET_AXES})


@dataclass(frozen=True)
class ResourceBudget:
    """Global caps on the shared axes; ``None`` leaves an axis unbounded.

    An all-``None`` budget (``ResourceBudget()``) is *slack*: every
    selection fits, and joint co-selection degenerates to each memory's
    independent argmin.
    """

    banks: Optional[int] = None
    volume: Optional[int] = None
    lut: Optional[float] = None
    ff: Optional[float] = None
    bram: Optional[int] = None
    dsp: Optional[int] = None

    @property
    def bounded(self) -> bool:
        return any(getattr(self, a) is not None for a in BUDGET_AXES)

    def admits(self, use: ResourceUse) -> bool:
        for a in BUDGET_AXES:
            cap = getattr(self, a)
            if cap is not None and use.axis(a) > cap:
                return False
        return True

    def headroom(self, use: ResourceUse) -> Dict[str, float]:
        """Remaining slack per bounded axis (negative = over)."""
        return {a: getattr(self, a) - use.axis(a)
                for a in BUDGET_AXES if getattr(self, a) is not None}

    def as_dict(self) -> Dict[str, Optional[float]]:
        return {a: getattr(self, a) for a in BUDGET_AXES}

    @staticmethod
    def from_json(d: Optional[dict]) -> Optional["ResourceBudget"]:
        if d is None:
            return None
        return ResourceBudget(**{a: d.get(a) for a in BUDGET_AXES})


# ---------------------------------------------------------------------------
# The trivial member: one bank, always feasible
# ---------------------------------------------------------------------------


def trivial_solution(mem: MemorySpec, groups: List[AccessGroup],
                     iterators: Dict[str, Iterator],
                     opts: Optional[SolverOptions] = None) -> BankingSolution:
    """The single-bank scheme as a scored, resource-estimated solution.

    ``FlatGeometry(N=1, B=1)`` serializes concurrent accesses instead of
    banking them -- never refused, never needs a solver -- which is what
    guarantees every frontier holds at least one member and an
    over-constrained budget degrades to all-trivial instead of raising.
    Mirrors :func:`repro.core.artifact.compile_trivial`'s geometry
    exactly, so the compiled fallback artifact and this solution describe
    the same layout.
    """
    from .geometry import FlatGeometry
    from .solver import _attach_flat

    opts = opts or SolverOptions()
    nd = len(mem.dims)
    alpha = tuple(1 if i == 0 else 0 for i in range(nd))
    geo = FlatGeometry(N=1, B=1, alpha=alpha, P=(1,) * nd)
    ports_needed = max((len(g) for g in groups), default=1)
    sol = _attach_flat(groups, mem, geo, (1,) * nd, iterators,
                       required_ports=ports_needed, opts=opts,
                       note="trivial single-bank fallback")
    if sol.resources is not None:
        sol.score = sol.resources.total.weighted()
    return sol


def is_trivial(sol: BankingSolution) -> bool:
    return (sol.kind == "flat" and sol.geometry.N == 1
            and sol.geometry.B == 1 and sol.duplicates <= 1)


# ---------------------------------------------------------------------------
# Per-memory Pareto frontiers
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FrontierPoint:
    """One kept scheme for one memory: predicted cost x budget draw."""

    solution: BankingSolution
    use: ResourceUse
    score: float
    trivial: bool = False

    def key(self) -> tuple:
        """Deterministic identity for tie-breaks and change detection."""
        g = self.solution.geometry
        geo = ((g.N, g.B, g.alpha) if self.solution.kind == "flat"
               else (g.Ns, g.Bs, g.alphas))
        return (self.solution.kind, geo, self.solution.duplicates,
                self.trivial)


def _dominates(a: FrontierPoint, b: FrontierPoint) -> bool:
    """a dominates b: no worse on cost and every axis, better somewhere."""
    if a.score > b.score:
        return False
    at, bt = a.use.as_tuple(), b.use.as_tuple()
    if any(x > y for x, y in zip(at, bt)):
        return False
    return a.score < b.score or any(x < y for x, y in zip(at, bt))


# A selection is only ever charged a trivial member when nothing else
# fits: the single-bank scheme *serializes* concurrent accesses, so its
# predicted cost is not comparable to the solver's conflict-free schemes.
# Frontier points for it carry this additive penalty (plus a multiple of
# the frontier's worst real score), which keeps sums finite and ordered:
# any selection avoiding trivials beats any selection using one.
TRIVIAL_PENALTY = 1e9


def pareto_frontier(solutions: Sequence[BankingSolution], *,
                    trivial: BankingSolution,
                    cap: int = 8) -> List[FrontierPoint]:
    """The kept frontier for one memory: Pareto-optimal (cost x axes)
    points, truncated to ``cap``, with the trivial scheme always last
    (at :data:`TRIVIAL_PENALTY`-inflated cost, so co-selection only
    falls back to it under budget pressure).

    Truncation keeps the lowest-cost points plus each axis's minimum-use
    point, so a tight budget still sees the cheapest-per-axis options.
    Points are sorted best-cost-first; deterministic given the solutions.
    """
    pts = []
    for s in solutions:
        if s is None:
            continue
        pts.append(FrontierPoint(solution=s, use=ResourceUse.of_solution(s),
                                 score=float(s.score)))
    front = [p for p in pts
             if not any(_dominates(q, p) for q in pts if q is not p)]
    # dedupe identical schemes (duplicate geometry from merged shards)
    seen = set()
    front = [p for p in front
             if (k := p.key()) not in seen and not seen.add(k)]
    front.sort(key=lambda p: (p.score, p.use.as_tuple()))
    if len(front) > max(1, cap - 1):
        keep = list(front[:max(1, cap - 1)])
        kept = {p.key() for p in keep}
        for axis in BUDGET_AXES:   # cheapest-per-axis survivors
            low = min(front, key=lambda p: (p.use.axis(axis), p.score))
            if low.key() not in kept:
                keep.append(low)
                kept.add(low.key())
        keep.sort(key=lambda p: (p.score, p.use.as_tuple()))
        front = keep
    worst = max((p.score for p in front), default=0.0)
    tp = FrontierPoint(solution=trivial,
                       use=ResourceUse.of_solution(trivial),
                       score=(max(float(trivial.score), worst) * 1e3
                              + TRIVIAL_PENALTY),
                       trivial=True)
    return front + [tp]


# ---------------------------------------------------------------------------
# Exact co-selection over frontiers
# ---------------------------------------------------------------------------


@dataclass
class JointSelection:
    """One scheme per memory plus the totals the budget judged."""

    picks: Dict[str, FrontierPoint]
    total_use: ResourceUse
    total_score: float
    feasible: bool     # False: even all-trivial exceeds the budget

    def key(self) -> tuple:
        return tuple((name, p.key()) for name, p in sorted(self.picks.items()))


def co_select(frontiers: Dict[str, List[FrontierPoint]],
              budget: Optional[ResourceBudget],
              stats_out: Optional[dict] = None) -> JointSelection:
    """Pick one frontier point per memory minimizing total predicted
    cost subject to ``budget`` -- exact for the kept frontier sizes.

    Branch-and-bound over memories in sorted-name order: partial
    selections prune on (a) an admissible per-axis lower bound (each
    remaining memory must draw at least its frontier's per-axis minimum)
    and (b) an admissible cost lower bound.  With a slack (or ``None``)
    budget this degenerates to each memory's independent argmin.  If no
    selection fits -- the budget is under even the all-trivial draw --
    the all-trivial selection is returned with ``feasible=False``:
    co-selection never raises for want of resources.

    ``stats_out`` (a dict, when given) receives the search effort --
    ``nodes`` visited and ``pruned`` (bound + admissibility cuts) -- so
    a co-select trace span can say how hard the search worked.
    """
    if stats_out is not None:
        stats_out["nodes"] = 0
        stats_out["pruned"] = 0
    names = sorted(frontiers)
    if not names:
        return JointSelection({}, ResourceUse(), 0.0, True)
    budget = budget or ResourceBudget()
    fronts = [sorted(frontiers[n], key=lambda p: (p.score, p.use.as_tuple(),
                                                  p.trivial))
              for n in names]
    if not budget.bounded:     # slack: independent argmin per memory
        picks = {}
        for name, f in zip(names, fronts):
            real = [p for p in f if not p.trivial]
            picks[name] = real[0] if real else f[0]
        use = ResourceUse()
        for p in picks.values():
            use = use + p.use
        if stats_out is not None:
            stats_out["nodes"] = len(names)
        return JointSelection(picks, use,
                              sum(p.score for p in picks.values()), True)
    # admissible suffix lower bounds: min score and per-axis min use of
    # every memory still to be decided
    n = len(names)
    suf_score = [0.0] * (n + 1)
    suf_use = [ResourceUse()] * (n + 1)
    for i in range(n - 1, -1, -1):
        suf_score[i] = suf_score[i + 1] + min(p.score for p in fronts[i])
        mins = {a: min(p.use.axis(a) for p in fronts[i])
                for a in BUDGET_AXES}
        suf_use[i] = suf_use[i + 1] + ResourceUse(**mins)
    best: List[Optional[Tuple[float, List[FrontierPoint]]]] = [None]

    def admissible(use: ResourceUse, i: int) -> bool:
        floor = use + suf_use[i]
        return budget.admits(ResourceUse(
            banks=int(floor.banks), volume=int(floor.volume),
            lut=floor.lut, ff=floor.ff,
            bram=int(floor.bram), dsp=int(floor.dsp)))

    def dfs(i: int, use: ResourceUse, score: float,
            picks: List[FrontierPoint]) -> None:
        if stats_out is not None:
            stats_out["nodes"] += 1
        if best[0] is not None and score + suf_score[i] >= best[0][0]:
            if stats_out is not None:
                stats_out["pruned"] += 1
            return
        if not admissible(use, i):
            if stats_out is not None:
                stats_out["pruned"] += 1
            return
        if i == n:
            best[0] = (score, list(picks))
            return
        for p in fronts[i]:
            picks.append(p)
            dfs(i + 1, use + p.use, score + p.score, picks)
            picks.pop()

    dfs(0, ResourceUse(), 0.0, [])
    if best[0] is None:
        # infeasible even at the floor: honest all-trivial fallback
        picks = {}
        for name, f in zip(names, fronts):
            trivials = [p for p in f if p.trivial]
            picks[name] = trivials[0] if trivials else f[-1]
        use = ResourceUse()
        for p in picks.values():
            use = use + p.use
        return JointSelection(picks, use,
                              sum(p.score for p in picks.values()), False)
    score, chosen = best[0]
    picks = dict(zip(names, chosen))
    use = ResourceUse()
    for p in picks.values():
        use = use + p.use
    return JointSelection(picks, use, score, True)


# ---------------------------------------------------------------------------
# Requests and signatures
# ---------------------------------------------------------------------------


@dataclass
class JointRequest:
    """One whole-model planning problem: a program's memories + budget."""

    program: Program
    memories: Optional[Sequence[str]] = None   # None = every program memory
    budget: Optional[ResourceBudget] = None
    opts: Optional[SolverOptions] = None
    scorer: object = None                      # ScorerLike
    use_cache: bool = True
    frontier_cap: int = 8

    def memory_names(self) -> List[str]:
        names = (list(self.memories) if self.memories is not None
                 else list(self.program.memories))
        missing = [m for m in names if m not in self.program.memories]
        if missing:
            raise KeyError(f"unknown memories {missing!r}; program has "
                           f"{sorted(self.program.memories)}")
        return names


def joint_signature(member_signatures: Dict[str, str], scorer_name: str,
                    budget: Optional[ResourceBudget]) -> str:
    """Stable content hash of a joint problem: the member signatures
    (which already hash each memory's access structure + options), the
    scorer, and the budget.  The ``jp1-`` prefix keeps joint entries
    disjoint from per-memory ``bp*`` plans in any shared directory."""
    payload = {
        "members": sorted(member_signatures.items()),
        "scorer": scorer_name,
        "budget": budget.as_dict() if budget is not None else None,
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return (JOINT_SIGNATURE_PREFIX
            + hashlib.sha256(blob.encode()).hexdigest()[:32])


# ---------------------------------------------------------------------------
# The durable joint plan
# ---------------------------------------------------------------------------


@dataclass
class JointMember:
    """One memory's slice of a joint plan: provenance + chosen scheme."""

    memory: str
    signature: str
    status: str                  # solved | cached | cached-disk | error
    chosen: Optional[BankingSolution]
    trivial: bool = False
    certified: bool = False
    certificate: Optional[dict] = None   # ConflictCertificate JSON
    score: float = 0.0           # the scheme's raw (unpenalized) score
    use: ResourceUse = field(default_factory=ResourceUse)
    error: str = ""

    def to_json(self) -> dict:
        from .planner import _solution_to_json

        return {
            "memory": self.memory,
            "signature": self.signature,
            "status": self.status,
            "chosen": (_solution_to_json(self.chosen)
                       if self.chosen is not None else None),
            "trivial": self.trivial,
            "certified": self.certified,
            "certificate": self.certificate,
            "score": self.score,
            "use": self.use.as_dict(),
            "error": self.error,
        }

    @staticmethod
    def from_json(d: dict, opts: SolverOptions) -> "JointMember":
        from .planner import _solution_from_json

        chosen = (_solution_from_json(d["chosen"], opts)
                  if d.get("chosen") else None)
        return JointMember(
            memory=d["memory"], signature=d["signature"],
            status=d.get("status", "solved"), chosen=chosen,
            trivial=d.get("trivial", False),
            certified=d.get("certified", False),
            certificate=d.get("certificate"),
            score=d.get("score", 0.0),
            use=ResourceUse.from_json(d.get("use", {})),
            error=d.get("error", ""),
        )


@dataclass
class JointPlan:
    """A durable whole-model banking decision.

    Member signatures pin the exact per-memory problems this selection
    answers; ``feasible`` records whether the budget admitted any
    selection (False = the all-trivial honest fallback was returned).
    """

    signature: str
    members: Dict[str, JointMember]
    budget: Optional[ResourceBudget] = None
    feasible: bool = True
    scorer_name: str = "proxy"
    status: str = "solved"       # solved | cached | cached-disk
    solve_seconds: float = 0.0
    created_at: float = 0.0
    opts: SolverOptions = field(default_factory=SolverOptions)

    @property
    def total_use(self) -> ResourceUse:
        use = ResourceUse()
        for m in self.members.values():
            use = use + m.use
        return use

    @property
    def total_score(self) -> float:
        return sum(m.score for m in self.members.values())

    def selection(self) -> Dict[str, BankingSolution]:
        return {name: m.chosen for name, m in self.members.items()
                if m.chosen is not None}

    def fits(self) -> bool:
        return self.budget is None or self.budget.admits(self.total_use)

    def as_dict(self) -> dict:
        """Budget-accounting view (no solution graphs): totals, budget,
        and one row per member -- what benches and reports consume."""
        return {
            "signature": self.signature,
            "feasible": self.feasible,
            "fits": self.fits(),
            "budget": (self.budget.as_dict()
                       if self.budget is not None else None),
            "total_use": self.total_use.as_dict(),
            "total_score": self.total_score,
            "members": {
                name: {"status": m.status, "trivial": m.trivial,
                       "certified": m.certified, "score": m.score,
                       "use": m.use.as_dict()}
                for name, m in sorted(self.members.items())
            },
        }

    def to_json(self) -> dict:
        return {
            "format": JOINT_FORMAT,
            "signature": self.signature,
            "budget": (self.budget.as_dict()
                       if self.budget is not None else None),
            "feasible": self.feasible,
            "scorer_name": self.scorer_name,
            "status": self.status,
            "solve_seconds": self.solve_seconds,
            "created_at": self.created_at,
            "opts": asdict(self.opts),
            "members": {name: m.to_json()
                        for name, m in self.members.items()},
        }

    @staticmethod
    def from_json(d: dict) -> "JointPlan":
        if d.get("format") != JOINT_FORMAT:
            raise ValueError(f"not a joint plan: format={d.get('format')!r}")
        opts_d = dict(d.get("opts") or {})
        for k in ("b_candidates", "duplication_factors"):
            if k in opts_d:
                opts_d[k] = tuple(opts_d[k])
        opts = SolverOptions(**opts_d)
        return JointPlan(
            signature=d["signature"],
            members={name: JointMember.from_json(m, opts)
                     for name, m in d.get("members", {}).items()},
            budget=ResourceBudget.from_json(d.get("budget")),
            feasible=d.get("feasible", True),
            scorer_name=d.get("scorer_name", "proxy"),
            status=d.get("status", "solved"),
            solve_seconds=d.get("solve_seconds", 0.0),
            created_at=d.get("created_at", 0.0),
            opts=opts,
        )


# ---------------------------------------------------------------------------
# Convenience: independent totals (what joint planning is compared to)
# ---------------------------------------------------------------------------


def independent_use(plans: Dict[str, object]) -> ResourceUse:
    """Summed budget draw of per-memory plans' independent argmins --
    the baseline a budget-constrained joint selection beats."""
    use = ResourceUse()
    for plan in plans.values():
        best = getattr(plan, "best", None)
        if best is not None:
            use = use + ResourceUse.of_solution(best)
    return use


ScorerFn = Callable[[BankingSolution], float]


def score_solutions(sols: Sequence[BankingSolution],
                    scorer_fn: Optional[ScorerFn]) -> None:
    """(Re)score in place with the member scorer, proxy fallback --
    frontier points must carry comparable scores across memories."""
    for s in sols:
        if scorer_fn is not None:
            s.score = float(scorer_fn(s))
        elif s.resources is not None:
            s.score = s.resources.total.weighted()


def now() -> float:
    return time.time()


__all__ = [
    "BUDGET_AXES",
    "TRIVIAL_PENALTY",
    "FrontierPoint",
    "JointMember",
    "JointPlan",
    "JointRequest",
    "JointSelection",
    "ResourceBudget",
    "ResourceUse",
    "co_select",
    "independent_use",
    "is_trivial",
    "joint_signature",
    "pareto_frontier",
    "trivial_solution",
]
