"""FPGA resource proxy model.

The paper evaluates banking schemes by LUT/FF/BRAM/DSP after place-and-route.
We have no Vivado here, so the *paper-faithful* benchmarks (Tables 2/3) are
scored with this proxy: a structural estimator of the generated circuit --
crossbars sized by fan-out/fan-in (Table 1 metrics), bank-resolution
arithmetic costed from the (transformed) op graphs of Sec 3.4, and BRAM
quantization by 18Kb blocks.  The same features feed the ML cost model of
Sec 3.5, whose *labels* on the TPU side come from real compiled-HLO costs
instead (see core/dataset.py).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Sequence

from .transforms import Cost

BRAM_BITS = 18 * 1024


@dataclass
class ResourceEstimate:
    lut: float = 0.0
    ff: float = 0.0
    bram: int = 0
    dsp: int = 0
    # TPU-side analogue: scalar ops on the hot index path
    tpu_index_ops: int = 0

    def __add__(self, o: "ResourceEstimate") -> "ResourceEstimate":
        return ResourceEstimate(
            self.lut + o.lut, self.ff + o.ff, self.bram + o.bram,
            self.dsp + o.dsp, self.tpu_index_ops + o.tpu_index_ops,
        )

    def scaled(self, k: float) -> "ResourceEstimate":
        return ResourceEstimate(
            self.lut * k, self.ff * k, int(self.bram * k), int(self.dsp * k),
            int(self.tpu_index_ops * k),
        )

    def weighted(self, w_lut=1.0, w_ff=0.4, w_bram=200.0, w_dsp=400.0) -> float:
        """Scalar ranking score (used only as non-ML fallback ranking)."""
        return (self.lut * w_lut + self.ff * w_ff + self.bram * w_bram
                + self.dsp * w_dsp)


def bram_blocks(bank_volume: int, word_bits: int) -> int:
    """BRAM18K blocks for one bank, with the narrow-deep quantization FPGAs
    actually impose (a 18Kb block is at most 16K deep at 1 bit)."""
    if bank_volume <= 0:
        return 1
    by_bits = math.ceil(bank_volume * word_bits / BRAM_BITS)
    by_depth = math.ceil(bank_volume / (16 * 1024))
    return max(1, by_bits, by_depth)


def crossbar_cost(fan: int, width_bits: int) -> Cost:
    """fan-to-1 one-hot mux tree on a ``width_bits`` bus."""
    if fan <= 1:
        return Cost()
    lut = (fan - 1) * width_bits * 0.5
    ff = width_bits  # registered output
    return Cost(lut=lut, ff=ff, dsp=0, tpu_ops=max(1, fan.bit_length()))


def resolution_cost(ba_cost: Cost, bo_cost: Cost, ba_is_const: bool) -> Cost:
    c = bo_cost if ba_is_const else (ba_cost + bo_cost)
    return c


@dataclass
class SchemeResources:
    """Breakdown for one banking solution."""

    total: ResourceEstimate
    crossbar: ResourceEstimate
    resolution: ResourceEstimate
    storage: ResourceEstimate
    notes: Dict[str, float] = field(default_factory=dict)


def estimate_scheme(
    *,
    num_banks: int,
    bank_volume: int,
    word_bits: int,
    addr_bits: int,
    fan_outs: Sequence[int],
    fan_ins: Sequence[int],
    writes_fan_outs: Sequence[int],
    resolution_costs: Sequence[Cost],
    duplicates: int = 1,
) -> SchemeResources:
    xb = Cost()
    for fo in fan_outs:  # read-data return muxes
        xb = xb + crossbar_cost(fo, word_bits)
    for fi in fan_ins:   # per-bank request arbitration (addr + enables)
        xb = xb + crossbar_cost(fi, addr_bits + 2)
    for fo in writes_fan_outs:  # write data+addr distribution
        xb = xb + crossbar_cost(fo, word_bits + addr_bits)

    res = Cost()
    for c in resolution_costs:
        res = res + c

    storage_bram = duplicates * num_banks * bram_blocks(bank_volume, word_bits)
    storage = ResourceEstimate(
        lut=duplicates * num_banks * 6.0,   # per-bank control glue
        ff=duplicates * num_banks * (addr_bits + 4.0),
        bram=storage_bram,
        dsp=0,
    )
    xbr = ResourceEstimate(xb.lut, xb.ff, 0, xb.dsp, xb.tpu_ops).scaled(duplicates)
    resr = ResourceEstimate(res.lut, res.ff, 0, res.dsp, res.tpu_ops)
    total = xbr + resr + storage
    return SchemeResources(total=total, crossbar=xbr, resolution=resr,
                           storage=storage)
