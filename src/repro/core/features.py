"""Feature extraction for the ML resource estimator (paper Sec 3.5.1).

Two classes of raw features, as in Fig. 10:

* **Template features** -- primitives and derived parameters of the banking
  scheme itself (N, B, alpha, fan-out/fan-in, bank volume, op histogram of
  the transformed resolution graph, ...).
* **Subgraph features** -- neighbours/accessors of the memory node in the
  dataflow (#readers, #writers, group structure, iterator space, dims).

The first pipeline stage then takes degree-2 polynomial combinations of
these (e.g. the product of per-dimension bank counts), exactly as the paper
describes.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from .solver import BankingSolution
from .transforms import Node

TEMPLATE_FEATURES = [
    "num_banks", "blocking", "alpha_max", "alpha_nnz", "bank_volume",
    "log_bank_volume", "fo_max", "fo_sum", "fo_mean", "fan_in_max",
    "required_ports", "duplicates", "pad_total", "word_bits",
    "n_add", "n_select", "n_shift", "n_mul_raw", "n_div_raw", "n_mod_raw",
    "graph_depth", "is_multidim",
]

SUBGRAPH_FEATURES = [
    "n_readers", "n_writers", "n_groups", "max_group", "n_dims",
    "mem_volume", "log_mem_volume", "n_accesses",
]

FEATURE_NAMES = TEMPLATE_FEATURES + SUBGRAPH_FEATURES


def _graph_histogram(node) -> Dict[str, int]:
    hist = {"add": 0, "sub": 0, "select": 0, "ge": 0, "shl": 0, "shr": 0,
            "and": 0, "mul": 0, "div": 0, "mod": 0}
    depth = 0
    seen = set()

    def walk(n: Node, d: int):
        nonlocal depth
        depth = max(depth, d)
        if id(n) in seen:
            return
        seen.add(id(n))
        if n.op in hist:
            hist[n.op] += 1
        for a in n.args:
            walk(a, d + 1)

    if node is not None:
        nodes = node if isinstance(node, tuple) else (node,)
        for nd in nodes:
            walk(nd, 0)
    hist["_depth"] = depth
    return hist


def extract_features(sol: BankingSolution, groups=None) -> np.ndarray:
    geo = sol.geometry
    if sol.kind == "flat":
        blocking = geo.B
        alpha = geo.alpha
        multidim = 0.0
    else:
        blocking = int(np.prod(geo.Bs))
        alpha = geo.alphas
        multidim = 1.0
    hist = _graph_histogram(sol.resolution_ba)
    hist_bo = _graph_histogram(sol.resolution_bo)
    for k in hist:
        if k != "_depth":
            hist[k] += hist_bo.get(k, 0)
    hist["_depth"] = max(hist["_depth"], hist_bo["_depth"])

    fos = np.asarray(sol.fan_outs or (1,), dtype=np.float64)
    groups = groups or []
    readers = writers = naccess = 0
    max_group = 0
    for g in groups:
        max_group = max(max_group, len(g))
        for a in g:
            naccess += 1
            if a.is_write:
                writers += 1
            else:
                readers += 1
    if naccess == 0:
        naccess = len(fos)
        readers = naccess

    tmpl = [
        sol.num_banks, blocking, max(abs(a) for a in alpha),
        sum(1 for a in alpha if a), sol.bank_volume,
        np.log1p(sol.bank_volume), fos.max(), fos.sum(), fos.mean(),
        sol.max_fan_in, sol.required_ports, sol.duplicates,
        sum(sol.pad), sol.memory.word_bits,
        hist["add"] + hist["sub"], hist["select"] + hist["ge"],
        hist["shl"] + hist["shr"] + hist["and"],
        hist["mul"], hist["div"], hist["mod"],
        hist["_depth"], multidim,
    ]
    sub = [
        readers, writers, max(1, len(groups)), max_group, sol.memory.n,
        sol.memory.volume, np.log1p(sol.memory.volume), naccess,
    ]
    return np.asarray(tmpl + sub, dtype=np.float64)


def poly2_expand(X: np.ndarray, names: Sequence[str] = FEATURE_NAMES
                 ) -> Tuple[np.ndarray, List[str]]:
    """Degree-2 polynomial combinations (paper: first pipeline stage)."""
    n, d = X.shape
    cols = [X]
    out_names = list(names)
    for i in range(d):
        for j in range(i, d):
            cols.append((X[:, i] * X[:, j])[:, None])
            out_names.append(f"{names[i]}*{names[j]}")
    return np.concatenate(cols, axis=1), out_names
