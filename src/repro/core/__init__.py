"""Core banking system: the paper's contribution as a composable library."""

from .api import BankingReport, partition_all, partition_memory, rank_solutions
from .controller import AccessDecl, Counter, Ctrl, Program, Sched, Unroll, unroll
from .geometry import FlatGeometry, MultiDimGeometry
from .grouping import build_groups
from .polytope import Access, AccessGroup, Affine, Iterator, MemorySpec
from .solver import BankingSolution, SolverOptions, solve

__all__ = [
    "Access", "AccessDecl", "AccessGroup", "Affine", "BankingReport",
    "BankingSolution", "Counter", "Ctrl", "FlatGeometry", "Iterator",
    "MemorySpec", "MultiDimGeometry", "Program", "Sched", "SolverOptions",
    "Unroll", "build_groups", "partition_all", "partition_memory",
    "rank_solutions", "solve", "unroll",
]
