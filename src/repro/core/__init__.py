"""Core banking system: the paper's contribution as a composable library.

The front door is the **service subsystem**: ``PlanService.submit`` poses
a banking problem and returns a ``PlanTicket`` -- warm caches/stores
answer before the ticket is returned, cold solves run on a worker pool,
and ``ticket.fallback()`` gives an immediately executable trivial-scheme
artifact to serve from until the solved one lands (hot-swap).  The
blocking ``BankingPlanner.plan`` is a thin ``submit(...).result()`` over
the same code path.  Plans *execute* through compiled artifacts:
``plan.compile()`` lowers the chosen scheme once into a
``CompiledBankingPlan`` owning the physical layout, the jit-ready BA/BO
resolution callables, pack/unpack, the (batched) Pallas gather binding,
and the PartitionSpec bridge -- every consumer outside ``core/`` goes
through it.  Durability is a pluggable ``PlanStore``: ``MemoryStore`` in
process, lock-file-guarded ``DirectoryStore`` across processes (the old
``cache_dir=`` JSON layout).
"""

from .artifact import (
    BankingLayout,
    CompiledBankingPlan,
    as_compiled,
    compile_geometry,
    compile_plan,
    compile_solution,
    compile_trivial,
    lane_compile,
)
from .candidates import (
    Candidate,
    CandidateSpace,
    CutGate,
    SolutionReducer,
    SolveShard,
    evaluate,
    evaluate_parallel,
    shard_from_indices,
    solve_space,
    space_from_wire,
    space_to_wire,
)
from ..runtime.tenancy import (
    AdmissionError,
    QOS_CLASSES,
    QoSClass,
    TenantRegistry,
)
from .fabric import SolveFabric, spawn_local_workers
from .controller import AccessDecl, Counter, Ctrl, Program, Sched, Unroll, unroll
from .geometry import FlatGeometry, MultiDimGeometry
from .planner import (
    BankingPlan,
    BankingPlanner,
    PlanRequest,
    PreparedRequest,
    canonical_signature,
    default_planner,
    family_signature,
    program_signature,
    rank_solutions,
    register_scorer,
    registered_scorers,
    resolve_scorer,
    set_ml_scorer_path,
)
from .jointplan import (
    FrontierPoint,
    JointMember,
    JointPlan,
    JointRequest,
    JointSelection,
    ResourceBudget,
    ResourceUse,
    co_select,
    joint_signature,
    pareto_frontier,
    trivial_solution,
)
from .polytope import Access, AccessGroup, Affine, Iterator, MemorySpec
from .service import (
    JointTicket,
    PlanService,
    PlanTicket,
    StaleWhileRevalidate,
    default_service,
)
from .solver import BankingSolution, SolverOptions, solve, solve_monolithic
from .store import DirectoryStore, MemoryStore, PlanStore
from .tracing import (
    FlightRecorder,
    MetricsRegistry,
    Span,
    TicketTrace,
    Tracer,
    chrome_trace_events,
    new_trace_id,
    start_observability_server,
)
from .telemetry import (
    MeasuredCost,
    MeasuredScorer,
    ServiceTelemetry,
    TelemetryConfig,
    TelemetryLog,
    default_telemetry_log,
    roofline_prior_seconds,
    scheme_hash,
)
from .grouping import build_groups

__all__ = [
    "Access", "AccessDecl", "AccessGroup", "AdmissionError", "Affine",
    "BankingLayout",
    "BankingPlan", "BankingPlanner", "BankingSolution", "Candidate",
    "CandidateSpace", "CompiledBankingPlan", "Counter", "Ctrl", "CutGate",
    "DirectoryStore", "FlatGeometry", "FlightRecorder", "FrontierPoint",
    "Iterator",
    "JointMember", "JointPlan", "JointRequest", "JointSelection",
    "JointTicket", "MeasuredCost",
    "MeasuredScorer", "MemorySpec", "MemoryStore", "MetricsRegistry",
    "MultiDimGeometry",
    "PlanRequest", "PlanService", "PlanStore", "PlanTicket",
    "PreparedRequest", "Program", "QOS_CLASSES", "QoSClass",
    "ResourceBudget", "ResourceUse", "Sched",
    "ServiceTelemetry",
    "SolutionReducer", "SolveFabric", "SolveShard", "SolverOptions",
    "Span", "StaleWhileRevalidate", "TelemetryConfig", "TelemetryLog",
    "TenantRegistry", "TicketTrace", "Tracer", "Unroll",
    "as_compiled", "build_groups", "canonical_signature",
    "chrome_trace_events", "co_select",
    "compile_geometry", "compile_plan", "compile_solution",
    "compile_trivial", "default_planner", "default_service",
    "default_telemetry_log", "evaluate", "evaluate_parallel",
    "family_signature", "joint_signature", "lane_compile",
    "new_trace_id", "pareto_frontier", "program_signature",
    "rank_solutions", "register_scorer", "registered_scorers",
    "resolve_scorer", "roofline_prior_seconds", "scheme_hash",
    "set_ml_scorer_path", "shard_from_indices", "solve",
    "solve_monolithic", "solve_space", "space_from_wire", "space_to_wire",
    "spawn_local_workers", "start_observability_server",
    "trivial_solution", "unroll",
]
