"""Core banking system: the paper's contribution as a composable library.

The front door is the planner subsystem (``BankingPlanner`` /
``BankingPlan`` / ``PlanRequest``).  Plans *execute* through compiled
artifacts: ``plan.compile()`` lowers the chosen scheme once into a
``CompiledBankingPlan`` owning the physical layout, the jit-ready BA/BO
resolution callables, pack/unpack, the Pallas gather binding, and the
PartitionSpec bridge -- every consumer outside ``core/`` goes through it.
The free functions ``partition_memory`` / ``partition_all`` are deprecated
shims kept for compatibility.
"""

from .api import BankingReport, partition_all, partition_memory
from .artifact import (
    BankingLayout,
    CompiledBankingPlan,
    as_compiled,
    compile_geometry,
    compile_plan,
    compile_solution,
    lane_compile,
)
from .controller import AccessDecl, Counter, Ctrl, Program, Sched, Unroll, unroll
from .geometry import FlatGeometry, MultiDimGeometry
from .grouping import build_groups
from .planner import (
    BankingPlan,
    BankingPlanner,
    PlanRequest,
    canonical_signature,
    default_planner,
    program_signature,
    rank_solutions,
    register_scorer,
    registered_scorers,
    resolve_scorer,
    set_ml_scorer_path,
)
from .polytope import Access, AccessGroup, Affine, Iterator, MemorySpec
from .solver import BankingSolution, SolverOptions, solve

__all__ = [
    "Access", "AccessDecl", "AccessGroup", "Affine", "BankingLayout",
    "BankingPlan", "BankingPlanner", "BankingReport", "BankingSolution",
    "CompiledBankingPlan", "Counter", "Ctrl", "FlatGeometry", "Iterator",
    "MemorySpec", "MultiDimGeometry", "PlanRequest", "Program", "Sched",
    "SolverOptions", "Unroll", "as_compiled", "build_groups",
    "canonical_signature", "compile_geometry", "compile_plan",
    "compile_solution", "default_planner", "lane_compile", "partition_all",
    "partition_memory", "program_signature", "rank_solutions",
    "register_scorer", "registered_scorers", "resolve_scorer",
    "set_ml_scorer_path", "solve", "unroll",
]
