"""Core banking system: the paper's contribution as a composable library.

The front door is the planner subsystem (``BankingPlanner`` /
``BankingPlan`` / ``PlanRequest``); the free functions ``partition_memory``
and ``partition_all`` are deprecated shims kept for compatibility.
"""

from .api import BankingReport, partition_all, partition_memory
from .controller import AccessDecl, Counter, Ctrl, Program, Sched, Unroll, unroll
from .geometry import FlatGeometry, MultiDimGeometry
from .grouping import build_groups
from .planner import (
    BankingPlan,
    BankingPlanner,
    PlanRequest,
    canonical_signature,
    default_planner,
    program_signature,
    rank_solutions,
    register_scorer,
    registered_scorers,
    resolve_scorer,
)
from .polytope import Access, AccessGroup, Affine, Iterator, MemorySpec
from .solver import BankingSolution, SolverOptions, solve

__all__ = [
    "Access", "AccessDecl", "AccessGroup", "Affine", "BankingPlan",
    "BankingPlanner", "BankingReport", "BankingSolution", "Counter", "Ctrl",
    "FlatGeometry", "Iterator", "MemorySpec", "MultiDimGeometry",
    "PlanRequest", "Program", "Sched", "SolverOptions", "Unroll",
    "build_groups", "canonical_signature", "default_planner",
    "partition_all", "partition_memory", "program_signature",
    "rank_solutions", "register_scorer", "registered_scorers",
    "resolve_scorer", "solve", "unroll",
]
