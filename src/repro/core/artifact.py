"""CompiledBankingPlan: the executable artifact a plan lowers to.

The paper's deliverable is not the partitioning *scheme* but the
**resolution circuit** it generates -- the BA/BO arithmetic (Eq. 1-2,
strength-reduced per Sec 3.4) placed in front of the memory.  Before this
module every consumption site re-derived that lowering by hand: the Pallas
kernel rebuilt resolution callables from raw ``BankingSolution`` graphs,
the server re-did "pages = banks" arithmetic, and the sharding bridge
reverse-engineered geometries into ``PartitionSpec``s.

``plan.compile()`` (or ``BankingPlanner.compile(plan)``) now produces a
durable :class:`CompiledBankingPlan` that owns everything execution needs:

* the **physical layout** (bank count, bank volume, padding, bank-major
  table shape) as a :class:`BankingLayout`;
* jit-ready **ba/bo callables** lowered once from the transform graphs;
* ``pack`` / ``unpack`` between logical row-major arrays and bank-major
  storage (reference Eq. 1-2 arithmetic, vectorized);
* ``gather(table, rows)`` binding the Pallas banked-gather kernel with the
  compiled resolution arithmetic in its index map;
* ``scatter(table, rows, values)`` -- the write path through the same
  circuit (full rows, or single columns for per-slot record writes);
* ``to_partition_spec(mesh_axes)`` mapping the banked dimensions onto mesh
  axes for device-level banking.

Artifacts serialize to JSON (including the op graphs, DAG-preserving) so a
warm-started planner skips re-lowering entirely.  No code outside ``core/``
touches ``BankingSolution.resolution_ba/_bo`` or ``.geometry`` anymore --
the compiled artifact is the only execution interface.
"""

from __future__ import annotations

import json
import time
import warnings
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .geometry import FlatGeometry, MultiDimGeometry
from .polytope import MemorySpec
from .solver import BankingSolution, _flat_in_bits
from .transforms import (
    Node,
    build_flat_resolution,
    build_multidim_resolution,
    lower_jnp,
    lower_np,
)

FORMAT = "compiled-banking-plan/v1"

BACKENDS = ("jax", "numpy")


# ---------------------------------------------------------------------------
# Op-graph (Node DAG) serialization -- shared subexpressions stay shared
# ---------------------------------------------------------------------------


def graph_to_json(roots: Sequence[Node]) -> dict:
    """Serialize Node DAGs as a topo-ordered node list + root indices."""
    order: List[Node] = []
    index: Dict[int, int] = {}

    def visit(n: Node) -> int:
        key = id(n)
        if key in index:
            return index[key]
        arg_ids = [visit(a) for a in n.args]
        index[key] = len(order)
        order.append(n)
        # stash resolved arg indices alongside (parallel list below)
        arg_lists.append(arg_ids)
        return index[key]

    arg_lists: List[List[int]] = []
    root_ids = [visit(r) for r in roots]
    nodes = [
        {"op": n.op, "args": args, "value": n.value, "name": n.name,
         "width": n.width}
        for n, args in zip(order, arg_lists)
    ]
    return {"nodes": nodes, "roots": root_ids}


def graph_from_json(d: dict) -> List[Node]:
    built: List[Node] = []
    for nd in d["nodes"]:
        args = tuple(built[i] for i in nd["args"])
        built.append(Node(op=nd["op"], args=args, value=nd["value"],
                          name=nd["name"], width=nd["width"]))
    return [built[i] for i in d["roots"]]


# ---------------------------------------------------------------------------
# Physical layout
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BankingLayout:
    """The physical shape a compiled plan stores data in.

    Logical ``dims`` (row-major addressing) map onto ``n_banks`` banks of
    ``bank_volume`` rows each; ``pad`` is the per-dimension padding the
    partition parallelotope requires (padded slots exist in the bank-major
    table but hold no logical row).
    """

    dims: Tuple[int, ...]
    pad: Tuple[int, ...]
    n_banks: int
    bank_volume: int

    @property
    def padded_dims(self) -> Tuple[int, ...]:
        return tuple(d + p for d, p in zip(self.dims, self.pad))

    @property
    def logical_size(self) -> int:
        return int(np.prod(self.dims))

    def table_shape(self, row_width: int) -> Tuple[int, int, int]:
        """Bank-major storage shape for rows of ``row_width`` elements."""
        return (self.n_banks, self.bank_volume, row_width)


# ---------------------------------------------------------------------------
# The compiled artifact
# ---------------------------------------------------------------------------


class CompiledBankingPlan:
    """Executable lowering of one banking plan (see module docstring).

    Construct via :func:`compile_plan` / :func:`compile_solution` /
    :func:`compile_geometry` or ``BankingPlan.compile()`` -- not directly.
    """

    def __init__(self, *, memory: str, signature: str, backend: str,
                 kind: str, geometry, P: Tuple[int, ...],
                 layout: BankingLayout,
                 ba_graphs: Tuple[Node, ...], bo_graph: Node,
                 fan_outs: Tuple[int, ...] = (), max_fan_in: int = 1,
                 required_ports: int = 1, duplicates: int = 1,
                 scorer_name: str = "", note: str = ""):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
        self.memory = memory
        self.signature = signature
        self.backend = backend
        self.kind = kind
        self.geometry = geometry
        self.P = tuple(P)
        self.layout = layout
        self.ba_graphs = tuple(ba_graphs)
        self.bo_graph = bo_graph
        self.fan_outs = tuple(fan_outs)
        self.max_fan_in = max_fan_in
        self.required_ports = required_ports
        self.duplicates = duplicates
        self.scorer_name = scorer_name
        self.note = note
        self._tables_cache: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._telemetry = None   # opt-in timing sink (see enable_telemetry)
        self._lower()

    # -- lowering ----------------------------------------------------------
    def _lower(self) -> None:
        lower = lower_jnp if self.backend == "jax" else lower_np
        ba_fns = [lower(g) for g in self.ba_graphs]
        bo_fn = lower(self.bo_graph)
        if self.kind == "multidim":
            Ns = self.geometry.Ns

            def ba(*xs):
                env = {f"x{i}": x for i, x in enumerate(xs)}
                out = None
                for f, n in zip(ba_fns, Ns):
                    b = f(**env)
                    out = b if out is None else out * n + b
                return out
        else:
            f0 = ba_fns[0]

            def ba(*xs):
                return f0(**{f"x{i}": x for i, x in enumerate(xs)})

        def bo(*xs):
            return bo_fn(**{f"x{i}": x for i, x in enumerate(xs)})

        self.ba = ba   # bank address from logical coordinates x0..x{n-1}
        self.bo = bo   # intra-bank offset from logical coordinates

    # -- convenience metadata ----------------------------------------------
    @property
    def n_banks(self) -> int:
        return self.layout.n_banks

    @property
    def bank_volume(self) -> int:
        return self.layout.bank_volume

    @property
    def max_fan_out(self) -> int:
        return max(self.fan_outs) if self.fan_outs else 1

    def describe(self) -> str:
        g = self.geometry
        if self.kind == "flat":
            head = f"compiled flat N={g.N} B={g.B} alpha={g.alpha} P={self.P}"
        else:
            head = f"compiled multidim N={g.Ns} B={g.Bs} alpha={g.alphas}"
        return (f"{head} banks={self.n_banks} vol={self.bank_volume} "
                f"FOmax={self.max_fan_out} backend={self.backend}")

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<CompiledBankingPlan {self.describe()}>"

    # -- address resolution ------------------------------------------------
    def _split(self, addr):
        """Flat row-major logical address -> per-dimension coordinates."""
        dims = self.layout.dims
        if len(dims) == 1:
            return (addr,)
        strides = []
        s = 1
        for d in reversed(dims):
            strides.append(s)
            s *= d
        strides = strides[::-1]
        return tuple((addr // st) % d for st, d in zip(strides, dims))

    def resolve(self, addr):
        """(bank, offset) of a flat logical address (scalar or array).

        This is the Eq. 1-2 resolution circuit, lowered through the Sec-3.4
        transforms -- the same callables the gather kernel's index map runs.
        """
        xs = self._split(addr)
        return self.ba(*xs), self.bo(*xs)

    # -- layout conversion -------------------------------------------------
    def _tables(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-address (bank, offset) tables from the *reference* (raw
        Eq. 1-2) arithmetic -- tests assert the transformed circuit agrees
        with this layout, so pack must not use the transformed graphs."""
        if self._tables_cache is not None:
            return self._tables_cache
        dims = self.layout.dims
        addr = np.arange(self.layout.logical_size, dtype=np.int64)
        xs = self._split(addr)
        g = self.geometry
        if self.kind == "flat":
            y = np.zeros_like(addr)
            for x, a in zip(xs, g.alpha):
                y = y + x * a
            ba = (y // g.B) % g.N
            acc = np.zeros_like(addr)
            for i in range(len(dims)):
                stride = 1
                for j in range(i + 1, len(dims)):
                    stride *= -(-dims[j] // self.P[j])
                acc = acc + (xs[i] // self.P[i]) * stride
            bo = g.B * acc + y % g.B
        else:
            ba = None
            bo = np.zeros_like(addr)
            for x, a, b, n, d in zip(xs, g.alphas, g.Bs, g.Ns, dims):
                y = x * a
                ba_d = (y // b) % n
                ba = ba_d if ba is None else ba * n + ba_d
                blocks = -(-d * a // b)
                per_bank = -(-blocks // n)
                coord = (y // (b * n)) * b + y % b
                bo = bo * (per_bank * b) + coord
        self._tables_cache = (ba.astype(np.int64), bo.astype(np.int64))
        return self._tables_cache

    def pack(self, flat):
        """Logical (A, D) rows -> bank-major (n_banks, bank_volume, D).

        Rows land where the layout's reference BA/BO equations place them;
        padded slots stay zero.  ``A`` must equal the logical size.
        """
        import jax.numpy as jnp

        flat = jnp.asarray(flat)
        A, D = flat.shape
        if A != self.layout.logical_size:
            raise ValueError(
                f"pack expects {self.layout.logical_size} logical rows "
                f"(dims={self.layout.dims}), got {A}")
        ba, bo = self._tables()
        table = jnp.zeros(self.layout.table_shape(D), flat.dtype)
        return table.at[ba, bo].set(flat)

    def unpack(self, table):
        """Bank-major (n_banks, bank_volume, D) -> logical (A, D) rows.

        Exact inverse of :meth:`pack`: padding slots are dropped, so
        ``unpack(pack(x)) == x``.
        """
        import jax.numpy as jnp

        table = jnp.asarray(table)
        if tuple(table.shape[:2]) != (self.n_banks, self.bank_volume):
            raise ValueError(
                f"table shape {tuple(table.shape)} does not match layout "
                f"{self.layout.table_shape(-1)[:2]}")
        ba, bo = self._tables()
        return table[ba, bo]

    # -- telemetry hooks ---------------------------------------------------
    def enable_telemetry(self, sink) -> None:
        """Attach a timing sink: every gather/scatter call is wall-timed
        (result synchronized first) and reported as
        ``sink.observe(self, op, index_shape, seconds)``.  The sink is
        duck-typed -- normally a
        :class:`~repro.core.telemetry.ServiceTelemetry` hub.  With no
        sink attached (the default) the execution paths are untouched.
        """
        self._telemetry = sink

    def disable_telemetry(self) -> None:
        self._telemetry = None

    def _timed(self, op: str, rows, fn):
        sink = self._telemetry
        if sink is None:
            return fn()
        t0 = time.perf_counter()
        out = fn()
        block = getattr(out, "block_until_ready", None)
        if block is not None:
            block()   # async dispatch would otherwise time the enqueue
        sink.observe(self, op, np.shape(rows), time.perf_counter() - t0)
        return out

    # -- execution ---------------------------------------------------------
    def gather(self, table, rows, *, interpret: Optional[bool] = None):
        """Gather logical rows from bank-major storage.  With a telemetry
        sink attached (:meth:`enable_telemetry`) the call is wall-timed
        and the latency logged under this artifact's scheme."""
        return self._timed("gather", rows,
                           lambda: self._gather(table, rows,
                                                interpret=interpret))

    def scatter(self, table, rows, values, *, col=None,
                interpret: Optional[bool] = None):
        """Write logical rows into bank-major storage (see
        :meth:`_scatter`); wall-timed when a telemetry sink is attached."""
        return self._timed("scatter", rows,
                           lambda: self._scatter(table, rows, values,
                                                 col=col,
                                                 interpret=interpret))

    def _gather(self, table, rows, *, interpret: Optional[bool] = None):
        """Gather logical rows from bank-major storage.

        ``rows`` is a ``(T,)`` vector of flat logical addresses -- or a
        stacked ``(T, R)`` matrix of T row-sets (e.g. one decode tick's
        reads for every active sequence), which issues ONE kernel launch
        for the whole batch and returns ``(T, R, D)`` instead of T
        per-row-set calls.

        ``jax`` backend: binds the Pallas banked-gather kernel -- the
        compiled BA/BO arithmetic runs in the scalar-prefetch index map,
        exactly where an FPGA would place the resolution circuit.
        ``numpy`` backend: direct advanced indexing through the same
        compiled (numpy-lowered) resolution callables.
        """
        if self.backend == "numpy":
            # resolution callables are shape-preserving: (T,) and (T, R)
            # index arrays both work through one advanced-indexing gather
            ba, bo = self.resolve(np.asarray(rows, dtype=np.int64))
            return np.asarray(table)[ba, bo]
        from ..kernels.banked_gather import banked_gather

        if interpret is None:
            import jax
            interpret = jax.default_backend() != "tpu"

        def ba_fn(addr):
            return self.ba(*self._split(addr))

        def bo_fn(addr):
            return self.bo(*self._split(addr))

        import jax.numpy as jnp
        rows = jnp.asarray(rows)
        if rows.ndim == 2:
            # stacked row-sets: flatten into a single grid so the whole
            # batch is one pallas_call, then restore the (T, R) structure
            T, R = rows.shape
            flat = banked_gather(table, rows.reshape(T * R), ba_fn, bo_fn,
                                 interpret=interpret)
            return flat.reshape(T, R, flat.shape[-1])
        return banked_gather(table, rows, ba_fn, bo_fn, interpret=interpret)

    def _scatter(self, table, rows, values, *, col=None,
                 interpret: Optional[bool] = None):
        """Write logical rows into bank-major storage -- the write-path
        analogue of :meth:`gather`.

        ``rows`` is a ``(T,)`` vector of flat logical addresses.  With
        ``col=None``, ``values`` is a ``(T, D)`` matrix of replacement
        rows; with ``col`` a ``(T,)`` vector of column indices,
        ``values`` is a ``(T,)`` vector of scalars written at
        ``table[ba, bo, col]`` -- one kernel launch for a whole batch of
        per-slot token-record writes, no read-modify-write.  Returns the
        updated table (duplicates resolve last-write-wins).

        ``jax`` backend: binds the Pallas banked-scatter kernel -- the
        compiled BA/BO arithmetic runs in the out-spec index map, in
        front of the memory like the gather's.  ``numpy`` backend:
        advanced-indexing assignment through the same compiled
        resolution callables.
        """
        if self.backend == "numpy":
            ba, bo = self.resolve(np.asarray(rows, dtype=np.int64))
            out = np.array(table, copy=True)
            if col is None:
                out[ba, bo] = values
            else:
                out[ba, bo, np.asarray(col, dtype=np.int64)] = values
            return out
        from ..kernels.banked_gather import (banked_scatter,
                                             banked_scatter_elems)

        if interpret is None:
            import jax
            interpret = jax.default_backend() != "tpu"

        def ba_fn(addr):
            return self.ba(*self._split(addr))

        def bo_fn(addr):
            return self.bo(*self._split(addr))

        import jax.numpy as jnp
        rows = jnp.asarray(rows)
        values = jnp.asarray(values, dtype=table.dtype)
        if col is None:
            return banked_scatter(table, rows, values, ba_fn, bo_fn,
                                  interpret=interpret)
        return banked_scatter_elems(table, rows, jnp.asarray(col), values,
                                    ba_fn, bo_fn, interpret=interpret)

    # -- device-level banking ----------------------------------------------
    def banked_dims(self) -> Tuple[int, ...]:
        """Logical dimensions this scheme actually splits across banks."""
        if self.kind == "multidim":
            return tuple(d for d, n in enumerate(self.geometry.Ns) if n > 1)
        if self.n_banks <= 1:
            return ()
        nz = tuple(d for d, a in enumerate(self.geometry.alpha) if a != 0)
        return nz

    def to_partition_spec(self, mesh_axes):
        """Map the banked dimensions onto mesh axes as a ``PartitionSpec``.

        ``mesh_axes``: one axis name (or a tuple of names, sharded jointly)
        for a scheme banking a single dimension, or a sequence with one
        entry per banked dimension for multidimensional schemes.  Raises
        ``ValueError`` for geometries with no orthogonal device analogue
        (diagonal hyperplanes touch every dim at once -- there is no mesh
        axis assignment that reproduces them).
        """
        from jax.sharding import PartitionSpec

        nd = len(self.layout.dims)
        banked = self.banked_dims()
        spec: List[object] = [None] * nd
        if not banked:
            return PartitionSpec(*spec)
        if self.kind == "flat":
            if len(banked) > 1:
                raise ValueError(
                    f"flat scheme with diagonal alpha={self.geometry.alpha} "
                    f"has no orthogonal PartitionSpec")
            spec[banked[0]] = mesh_axes  # str or tuple both legal entries
            return PartitionSpec(*spec)
        axes = ([mesh_axes] if isinstance(mesh_axes, str) else
                list(mesh_axes))
        if len(axes) != len(banked):
            raise ValueError(
                f"scheme banks dims {banked} but got {len(axes)} mesh "
                f"axes ({axes})")
        for d, ax in zip(banked, axes):
            spec[d] = ax
        return PartitionSpec(*spec)

    # -- serialization -----------------------------------------------------
    def to_json(self) -> dict:
        g = self.geometry
        if self.kind == "flat":
            geo = {"N": g.N, "B": g.B, "alpha": list(g.alpha),
                   "P": list(g.P)}
        else:
            geo = {"Ns": list(g.Ns), "Bs": list(g.Bs),
                   "alphas": list(g.alphas)}
        return {
            "format": FORMAT,
            "memory": self.memory,
            "signature": self.signature,
            "backend": self.backend,
            "kind": self.kind,
            "geometry": geo,
            "P": list(self.P),
            "layout": {
                "dims": list(self.layout.dims),
                "pad": list(self.layout.pad),
                "n_banks": self.layout.n_banks,
                "bank_volume": self.layout.bank_volume,
            },
            "graphs": graph_to_json(list(self.ba_graphs) + [self.bo_graph]),
            "fan_outs": list(self.fan_outs),
            "max_fan_in": self.max_fan_in,
            "required_ports": self.required_ports,
            "duplicates": self.duplicates,
            "scorer_name": self.scorer_name,
            "note": self.note,
        }

    @staticmethod
    def from_json(d: dict, backend: Optional[str] = None
                  ) -> "CompiledBankingPlan":
        if d.get("format") != FORMAT:
            raise ValueError(
                f"not a compiled banking plan: format={d.get('format')!r}")
        gd = d["geometry"]
        if d["kind"] == "flat":
            geo = FlatGeometry(N=gd["N"], B=gd["B"],
                               alpha=tuple(gd["alpha"]),
                               P=tuple(gd["P"]))
        else:
            geo = MultiDimGeometry(Ns=tuple(gd["Ns"]), Bs=tuple(gd["Bs"]),
                                   alphas=tuple(gd["alphas"]))
        ld = d["layout"]
        layout = BankingLayout(dims=tuple(ld["dims"]), pad=tuple(ld["pad"]),
                               n_banks=ld["n_banks"],
                               bank_volume=ld["bank_volume"])
        graphs = graph_from_json(d["graphs"])
        return CompiledBankingPlan(
            memory=d["memory"], signature=d["signature"],
            backend=backend or d["backend"], kind=d["kind"], geometry=geo,
            P=tuple(d["P"]), layout=layout,
            ba_graphs=tuple(graphs[:-1]), bo_graph=graphs[-1],
            fan_outs=tuple(d.get("fan_outs", ())),
            max_fan_in=d.get("max_fan_in", 1),
            required_ports=d.get("required_ports", 1),
            duplicates=d.get("duplicates", 1),
            scorer_name=d.get("scorer_name", ""),
            note=d.get("note", ""),
        )

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(path.suffix + ".tmp")
        tmp.write_text(json.dumps(self.to_json(), indent=1, sort_keys=True))
        tmp.replace(path)
        return path

    @staticmethod
    def load(path, backend: Optional[str] = None) -> "CompiledBankingPlan":
        return CompiledBankingPlan.from_json(
            json.loads(Path(path).read_text()), backend=backend)


# ---------------------------------------------------------------------------
# Compilation entry points
# ---------------------------------------------------------------------------


def compile_solution(sol: BankingSolution, *, signature: str = "",
                     backend: str = "jax", scorer_name: str = ""
                     ) -> CompiledBankingPlan:
    """Lower one BankingSolution into an executable artifact.

    Reuses the solution's Sec-3.4 resolution graphs when present (the
    solver and plan deserialization both attach them); rebuilds them from
    the geometry otherwise.
    """
    mem = sol.memory
    if sol.kind == "flat":
        g = sol.geometry
        if sol.resolution_ba is not None and sol.resolution_bo is not None:
            ba_graphs: Tuple[Node, ...] = (sol.resolution_ba,)
            bo = sol.resolution_bo
        else:
            in_bits = _flat_in_bits(mem, g.alpha)
            ba, bo = build_flat_resolution(g.N, g.B, g.alpha, sol.P,
                                           mem.dims, in_bits)
            ba_graphs = (ba,)
    else:
        g = sol.geometry
        if sol.resolution_ba is not None and sol.resolution_bo is not None:
            ba_graphs = tuple(sol.resolution_ba)
            bo = sol.resolution_bo
        else:
            in_bits = max(_flat_in_bits(mem, g.alphas), 8)
            bas, bo = build_multidim_resolution(g.Ns, g.Bs, g.alphas,
                                                mem.dims, in_bits)
            ba_graphs = tuple(bas)
    layout = BankingLayout(dims=tuple(mem.dims), pad=tuple(sol.pad),
                           n_banks=sol.num_banks,
                           bank_volume=sol.bank_volume)
    return CompiledBankingPlan(
        memory=mem.name, signature=signature, backend=backend,
        kind=sol.kind, geometry=sol.geometry, P=tuple(sol.P), layout=layout,
        ba_graphs=ba_graphs, bo_graph=bo, fan_outs=tuple(sol.fan_outs),
        max_fan_in=sol.max_fan_in, required_ports=sol.required_ports,
        duplicates=sol.duplicates, scorer_name=scorer_name, note=sol.note)


def compile_plan(plan, *, backend: str = "jax") -> CompiledBankingPlan:
    """Lower a BankingPlan's chosen scheme.  Prefer ``plan.compile()`` /
    ``BankingPlanner.compile(plan)``, which cache and persist artifacts."""
    if plan.best is None:
        raise ValueError(
            f"plan for {plan.memory!r} has no solution to compile "
            f"(status={plan.status})")
    return compile_solution(plan.best, signature=plan.signature,
                            backend=backend, scorer_name=plan.scorer_name)


def compile_geometry(mem: MemorySpec, geometry, *,
                     P: Optional[Tuple[int, ...]] = None,
                     backend: str = "jax", transform_level: str = "full",
                     signature: str = "") -> CompiledBankingPlan:
    """Lower a bare geometry (test/tooling entry: no solver run needed)."""
    from .geometry import padding as geom_padding

    if isinstance(geometry, FlatGeometry):
        P = tuple(P if P is not None else geometry.P)
        in_bits = _flat_in_bits(mem, geometry.alpha)
        ba, bo = build_flat_resolution(geometry.N, geometry.B,
                                       geometry.alpha, P, mem.dims, in_bits,
                                       level=transform_level)
        ba_graphs: Tuple[Node, ...] = (ba,)
        kind = "flat"
        n_banks = geometry.N
    else:
        P = tuple(P if P is not None else
                  (max(1, -(-d // n))
                   for d, n in zip(mem.dims, geometry.Ns)))
        in_bits = max(_flat_in_bits(mem, geometry.alphas), 8)
        bas, bo = build_multidim_resolution(geometry.Ns, geometry.Bs,
                                            geometry.alphas, mem.dims,
                                            in_bits, level=transform_level)
        ba_graphs = tuple(bas)
        kind = "multidim"
        n_banks = geometry.num_banks
    layout = BankingLayout(dims=tuple(mem.dims),
                           pad=geom_padding(mem, P), n_banks=n_banks,
                           bank_volume=geometry.bank_volume(mem.dims))
    return CompiledBankingPlan(
        memory=mem.name, signature=signature, backend=backend, kind=kind,
        geometry=geometry, P=P, layout=layout, ba_graphs=ba_graphs,
        bo_graph=bo)


def compile_trivial(mem: MemorySpec, *, backend: str = "jax",
                    signature: str = "") -> CompiledBankingPlan:
    """The zero-solve fallback artifact: one bank, row-major offsets.

    ``FlatGeometry(N=1, B=1)`` with a unit parallelotope places every
    logical row at ``(bank 0, offset = flat address)`` -- always valid
    (it just serializes concurrent accesses), needs no solver or search,
    and compiles in microseconds.  ``PlanTicket.fallback()`` hands this
    out so a consumer can pack/gather *immediately* and hot-swap to the
    solved artifact when the ticket resolves.
    """
    nd = len(mem.dims)
    alpha = tuple(1 if i == 0 else 0 for i in range(nd))
    geo = FlatGeometry(N=1, B=1, alpha=alpha, P=(1,) * nd)
    art = compile_geometry(mem, geo, P=(1,) * nd, backend=backend,
                           signature=signature)
    art.note = "trivial single-bank fallback"
    return art


def lane_compile(plan, lanes: int, *, backend: str = "jax"
                 ) -> Optional[CompiledBankingPlan]:
    """Compile the first candidate suitable for device-lane banking.

    Device-level banking (the sharding bridge) needs a *flat* scheme whose
    bank count is a lane multiple with fan-out 1 -- each lane owns one
    shard, so no crossbar = no collective on the access path.  Returns the
    compiled artifact, or None when no candidate qualifies.
    """
    for s in plan.solutions:
        if (s.kind == "flat" and lanes > 0 and s.num_banks % lanes == 0
                and s.fan_outs and max(s.fan_outs) == 1):
            return compile_solution(s, signature=plan.signature,
                                    backend=backend,
                                    scorer_name=plan.scorer_name)
    return None


def as_compiled(obj, *, backend: str = "jax") -> CompiledBankingPlan:
    """Coerce to a CompiledBankingPlan.

    Accepts an artifact (pass-through), a BankingPlan (compiled through its
    planner's cache when it has one), or -- deprecated -- a raw
    BankingSolution, which is compiled ad hoc.
    """
    if isinstance(obj, CompiledBankingPlan):
        return obj
    compile_method = getattr(obj, "compile", None)
    if compile_method is not None:          # BankingPlan
        return compile_method(backend=backend)
    if isinstance(obj, BankingSolution):
        warnings.warn(
            "passing a raw BankingSolution to kernels is deprecated; "
            "compile the plan (plan.compile()) and pass the "
            "CompiledBankingPlan artifact",
            DeprecationWarning, stacklevel=3)
        return compile_solution(obj, backend=backend)
    raise TypeError(f"cannot compile {type(obj).__name__}")


__all__ = [
    "BankingLayout",
    "CompiledBankingPlan",
    "as_compiled",
    "compile_geometry",
    "compile_plan",
    "compile_solution",
    "compile_trivial",
    "graph_from_json",
    "graph_to_json",
    "lane_compile",
    "lower_np",
]
