"""Measured-cost telemetry: close the cost-model loop on real hardware.

The Sec-3.5 ML cost model ranks partitioning schemes from *static*
features -- it never learns that a scheme the hardware proved slow should
lose its cache slot (ROADMAP open item 3).  This module is the missing
feedback half:

* :class:`MeasuredCost` -- one aggregated observation record, keyed by
  plan signature + **scheme hash** (a content hash of the geometry, so the
  same scheme measured under any plan informs every ranking) + backend +
  op + (T, R) shape bucket, carrying count / mean / bounded samples for
  p50 / p95.
* :class:`TelemetryLog` -- the in-process observation log.  ``observe``
  updates both a cumulative view (what scorers and demotion read) and a
  **pending-delta** view that :meth:`drain` hands to the store layer, so
  repeated cross-process merges never double-count.
* :func:`roofline_prior_seconds` -- an analytic bytes-moved / bandwidth
  prior (constants lifted from ``launch/roofline.py``) with serialization,
  crossbar and resolution-tree overhead terms, so schemes never yet run
  still rank against measured ones in comparable units.
* :class:`MeasuredScorer` -- the ``"measured"`` scorer-registry entry:
  blends observed latency with the calibrated roofline prior
  (``w = n/(n+k)`` confidence weighting); with an empty log it falls back
  to the static GBT model, so it is always a drop-in for ``"ml"``.
* :class:`ServiceTelemetry` -- the hub a :class:`PlanService` enables:
  instruments compiled artifacts with opt-in timing hooks, registers
  served plans, flushes the log through the plan store's ``telemetry/``
  sidecar, periodically refits ``ml_scorer.json`` from accumulated
  (features, measured) pairs, and **demotes** stored plans whose measured
  cost persistently exceeds the best alternative -- evicting the loser
  and resubmitting a speculative re-solve whose replacement ticket the
  serving runtime adopts between decode ticks.
"""

from __future__ import annotations

import hashlib
import json
import math
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

from .planner import register_scorer

TELEMETRY_FORMAT = "measured-cost/v1"

# ops that move table data (tick timings ride along but never feed
# scheme-vs-scheme comparisons: a whole tick is not a gather)
DATA_OPS = ("gather", "scatter")

# roofline-prior overhead coefficients: a fan-in-F crossbar port costs
# ~F/2 extra muxing per access, and the BA/BO resolution tree deepens
# with log2(banks).  Chosen so conflict-free schemes stay well under the
# default demotion ratio of their ideal floor.
XBAR_OVERHEAD = 0.5
TREE_OVERHEAD = 0.125

# canonical row count the prior is quoted at -- priors are per-*scheme*
# constants so ratios between schemes are exact, not per-shape estimates
PRIOR_ROWS = 64

_MAX_SAMPLES = 64


def roofline_bandwidth() -> float:
    """HBM bytes/s from ``launch/roofline.py``'s constants (cached;
    falls back to the TPU v5e figure if the launch stack won't import)."""
    cached = roofline_bandwidth.__dict__.get("_bw")
    if cached is None:
        try:
            from ..launch.roofline import HBM_BW as bw
        except Exception:  # headless core-only installs
            bw = 819e9
        cached = float(bw)
        roofline_bandwidth.__dict__["_bw"] = cached
    return cached


def scheme_hash(obj) -> str:
    """Content hash of a scheme's geometry -- the telemetry key that lets
    a measurement taken on one compiled artifact inform the ranking of
    the structurally identical candidate in any later solve.

    Accepts a ``BankingSolution`` or a ``CompiledBankingPlan`` (both carry
    ``kind`` / ``geometry`` / ``P`` / ``duplicates``); cached on the object.
    """
    cached = getattr(obj, "_scheme_hash", None)
    if cached is not None:
        return cached
    g = obj.geometry
    if obj.kind == "flat":
        geo = ("flat", g.N, g.B, tuple(g.alpha))
    else:
        geo = ("multidim", tuple(g.Ns), tuple(g.Bs), tuple(g.alphas))
    payload = repr((geo, tuple(obj.P), getattr(obj, "duplicates", 1)))
    h = hashlib.sha256(payload.encode()).hexdigest()[:16]
    try:
        obj._scheme_hash = h
    except (AttributeError, TypeError):
        pass  # frozen/slotted objects just re-hash
    return h


def shape_bucket(shape) -> str:
    """Pow2-ceiled bucket label for a gather/scatter index shape, so a
    (3,) and a (4,) call aggregate into one record instead of fragmenting
    the log per request count."""
    try:
        dims = tuple(int(d) for d in shape)
    except TypeError:
        dims = (int(shape),)
    if not dims:
        return "scalar"
    return "x".join(str(1 << max(0, (d - 1).bit_length())) for d in dims)


def roofline_floor_seconds() -> float:
    """The ideal conflict-free latency floor: canonical bytes moved over
    HBM bandwidth, no serialization, no crossbar, no resolution tree."""
    return PRIOR_ROWS * 16 / 8.0 / roofline_bandwidth()


def roofline_prior_seconds(scheme) -> float:
    """Analytic latency prior for one scheme, in seconds.

    bytes-moved / bandwidth (canonical ``PRIOR_ROWS`` accesses), scaled by
    the scheme's serialization factor (max fan-out: conflicting accesses
    replay the port) and by crossbar + resolution-tree overhead -- so
    never-run schemes rank in the same units measurements arrive in.
    """
    mem = getattr(scheme, "memory", None)
    word_bits = getattr(mem, "word_bits", None) or 16
    banks = getattr(scheme, "num_banks", None)
    if banks is None:
        banks = getattr(scheme, "n_banks", 1)
    banks = max(1, int(banks))
    fan_outs = tuple(getattr(scheme, "fan_outs", ()) or ())
    serial = max(fan_outs) if fan_outs else 1
    fan_in = max(1, int(getattr(scheme, "max_fan_in", 1)))
    base = PRIOR_ROWS * word_bits / 8.0 / roofline_bandwidth()
    return base * serial * (1.0 + XBAR_OVERHEAD * (fan_in - 1)
                            + TREE_OVERHEAD * math.log2(banks))


# ---------------------------------------------------------------------------
# Observation records
# ---------------------------------------------------------------------------


@dataclass
class MeasuredCost:
    """Aggregated latency observations for one (signature, scheme,
    backend, op, shape-bucket) cell.

    ``count``/``mean`` are exact over every observation; ``samples`` is a
    bounded sketch (deterministic slot replacement past ``_MAX_SAMPLES``)
    that p50/p95 read.  ``prior`` records the analytic roofline prior of
    the measured scheme, which is what calibrates priors of never-run
    schemes into measured-seconds units.
    """

    signature: str
    scheme: str
    backend: str
    op: str
    bucket: str
    count: int = 0
    mean: float = 0.0
    prior: float = 0.0
    samples: List[float] = field(default_factory=list)

    @property
    def key(self) -> Tuple[str, str, str, str]:
        return (self.scheme, self.backend, self.op, self.bucket)

    def observe(self, seconds: float, prior: float = 0.0) -> None:
        seconds = float(seconds)
        self.count += 1
        self.mean += (seconds - self.mean) / self.count
        if prior > 0.0:
            self.prior = float(prior)
        if len(self.samples) < _MAX_SAMPLES:
            self.samples.append(seconds)
        else:
            self.samples[self.count % _MAX_SAMPLES] = seconds

    def merge(self, other: "MeasuredCost") -> None:
        """Fold another record for the same key in (store-side merge of
        cross-process deltas): counts add, means weight, samples top up."""
        total = self.count + other.count
        if total:
            self.mean = ((self.mean * self.count
                          + other.mean * other.count) / total)
        self.count = total
        for s in other.samples:
            if len(self.samples) >= _MAX_SAMPLES:
                break
            self.samples.append(float(s))
        if other.prior > 0.0:
            self.prior = other.prior

    def p50(self) -> float:
        return float(np.median(self.samples)) if self.samples else self.mean

    def p95(self) -> float:
        if not self.samples:
            return self.mean
        return float(np.percentile(self.samples, 95))

    def copy(self) -> "MeasuredCost":
        return MeasuredCost(signature=self.signature, scheme=self.scheme,
                            backend=self.backend, op=self.op,
                            bucket=self.bucket, count=self.count,
                            mean=self.mean, prior=self.prior,
                            samples=list(self.samples))

    def to_json(self) -> dict:
        return {
            "signature": self.signature,
            "scheme": self.scheme,
            "backend": self.backend,
            "op": self.op,
            "bucket": self.bucket,
            "count": self.count,
            "mean": self.mean,
            "prior": self.prior,
            "samples": list(self.samples),
        }

    @staticmethod
    def from_json(d: dict) -> "MeasuredCost":
        return MeasuredCost(
            signature=d["signature"], scheme=d["scheme"],
            backend=d["backend"], op=d["op"], bucket=d["bucket"],
            count=int(d.get("count", 0)), mean=float(d.get("mean", 0.0)),
            prior=float(d.get("prior", 0.0)),
            samples=[float(s) for s in d.get("samples", ())],
        )


class TelemetryLog:
    """Thread-safe per-process observation log.

    Every ``observe`` lands twice: in the cumulative records (what
    :class:`MeasuredScorer` and demotion read) and in a pending-delta
    table that :meth:`drain` empties for the store layer -- so flushing
    the same log repeatedly merges only *new* observations into the
    shared ``telemetry/`` sidecar, never re-counting old ones.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._records: Dict[Tuple, MeasuredCost] = {}
        self._pending: Dict[Tuple, MeasuredCost] = {}

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    def observe(self, signature: str, scheme: str, backend: str, op: str,
                shape, seconds: float, prior: float = 0.0) -> MeasuredCost:
        bucket = shape if isinstance(shape, str) else shape_bucket(shape)
        key = (signature, scheme, backend, op, bucket)
        with self._lock:
            rec = self._records.get(key)
            if rec is None:
                rec = self._records[key] = MeasuredCost(
                    signature=signature, scheme=scheme, backend=backend,
                    op=op, bucket=bucket)
            rec.observe(seconds, prior)
            pend = self._pending.get(key)
            if pend is None:
                pend = self._pending[key] = MeasuredCost(
                    signature=signature, scheme=scheme, backend=backend,
                    op=op, bucket=bucket)
            pend.observe(seconds, prior)
        return rec

    def observe_artifact(self, art, op: str, shape,
                         seconds: float) -> MeasuredCost:
        """Record one timed call on a compiled artifact, tagging the
        record with the artifact's analytic prior (the calibration
        anchor)."""
        return self.observe(art.signature, scheme_hash(art), art.backend,
                            op, shape, seconds,
                            prior=roofline_prior_seconds(art))

    # -- queries -------------------------------------------------------------
    def records(self, *, signature: Optional[str] = None,
                scheme: Optional[str] = None,
                ops: Optional[Tuple[str, ...]] = None) -> List[MeasuredCost]:
        with self._lock:
            recs = list(self._records.values())
        return [r for r in recs
                if (signature is None or r.signature == signature)
                and (scheme is None or r.scheme == scheme)
                and (ops is None or r.op in ops)]

    def scheme_measured(self, scheme: str, *,
                        signature: Optional[str] = None,
                        ops: Tuple[str, ...] = DATA_OPS
                        ) -> Tuple[int, Optional[float]]:
        """(total observations, count-weighted p50) for one scheme."""
        recs = [r for r in self.records(signature=signature, scheme=scheme,
                                        ops=ops) if r.count > 0]
        if not recs:
            return 0, None
        total = sum(r.count for r in recs)
        return total, sum(r.p50() * r.count for r in recs) / total

    def best_rival(self, signature: str, exclude_scheme: str, *,
                   ops: Tuple[str, ...] = DATA_OPS
                   ) -> Optional[Tuple[str, float]]:
        """The fastest *measured* sibling scheme under the same plan
        signature -- demotion's strongest evidence when one exists."""
        schemes = {r.scheme for r in self.records(signature=signature,
                                                  ops=ops)
                   if r.count > 0 and r.scheme != exclude_scheme}
        best: Optional[Tuple[str, float]] = None
        for s in schemes:
            _, p50 = self.scheme_measured(s, signature=signature, ops=ops)
            if p50 is not None and (best is None or p50 < best[1]):
                best = (s, p50)
        return best

    def calibration(self) -> float:
        """Median measured/prior ratio -- the factor that converts
        analytic priors into this host's measured-seconds units.  1.0
        with no evidence."""
        ratios = [r.p50() / r.prior
                  for r in self.records(ops=DATA_OPS)
                  if r.count > 0 and r.prior > 0.0]
        return float(np.median(ratios)) if ratios else 1.0

    def has_measurements(self, ops: Tuple[str, ...] = DATA_OPS) -> bool:
        with self._lock:
            return any(r.count > 0 and r.op in ops
                       for r in self._records.values())

    # -- store exchange --------------------------------------------------------
    def drain(self) -> Dict[str, List[MeasuredCost]]:
        """Take (and clear) the pending deltas, grouped by signature --
        what :meth:`ServiceTelemetry.flush` hands to
        ``store.merge_telemetry``.  Cumulative records are untouched."""
        with self._lock:
            pend, self._pending = self._pending, {}
        out: Dict[str, List[MeasuredCost]] = {}
        for rec in pend.values():
            out.setdefault(rec.signature, []).append(rec)
        return out

    def hydrate(self, records: Iterable[MeasuredCost]) -> int:
        """Merge store-side records (other processes' history) into the
        cumulative view.  Never touches the pending deltas, so hydrated
        history is not re-flushed."""
        n = 0
        with self._lock:
            for rec in records:
                key = (rec.signature, rec.scheme, rec.backend, rec.op,
                       rec.bucket)
                mine = self._records.get(key)
                if mine is None:
                    self._records[key] = rec.copy()
                else:
                    mine.merge(rec)
                n += 1
        return n

    def to_json(self) -> dict:
        with self._lock:
            recs = [r.to_json() for r in self._records.values()]
        return {"format": TELEMETRY_FORMAT, "records": recs}

    @staticmethod
    def from_json(d: dict) -> "TelemetryLog":
        if d.get("format") != TELEMETRY_FORMAT:
            raise ValueError(f"not a telemetry log: {d.get('format')!r}")
        log = TelemetryLog()
        log.hydrate(MeasuredCost.from_json(r) for r in d["records"])
        return log


_DEFAULT_LOG: Optional[TelemetryLog] = None
_DEFAULT_LOG_LOCK = threading.Lock()


def default_telemetry_log() -> TelemetryLog:
    """Process-wide log backing ``scorer="measured"`` outside a service
    (a :class:`ServiceTelemetry` hub rebinds scorers to its own log)."""
    global _DEFAULT_LOG
    with _DEFAULT_LOG_LOCK:
        if _DEFAULT_LOG is None:
            _DEFAULT_LOG = TelemetryLog()
        return _DEFAULT_LOG


# ---------------------------------------------------------------------------
# The "measured" scorer
# ---------------------------------------------------------------------------


class MeasuredScorer:
    """Rank schemes by observed latency, calibrated priors, or the static
    GBT model -- in that order of evidence.

    * a scheme with ``n`` observations scores
      ``w * p50 + (1 - w) * cal * prior`` with ``w = n / (n + k)`` --
      measurement dominates as evidence accumulates;
    * a never-run scheme scores ``cal * prior`` (its analytic roofline
      prior scaled by the log's measured/prior calibration);
    * with an empty log the static scorer ranks (the persisted/trained
      ``"ml"`` pipeline unless one is passed explicitly), so
      ``scorer="measured"`` is safe from the very first cold solve.
    """

    __name__ = "measured"

    def __init__(self, log: Optional[TelemetryLog] = None,
                 static: Optional[Callable] = None, k: float = 4.0):
        self.log = log if log is not None else default_telemetry_log()
        self.static = static
        self.k = float(k)

    def with_log(self, log: TelemetryLog) -> "MeasuredScorer":
        """The same scorer reading a different log (how a service hub
        rebinds registry-resolved scorers to its private log)."""
        return MeasuredScorer(log=log, static=self.static, k=self.k)

    def _static(self) -> Optional[Callable]:
        if self.static is not None:
            return self.static
        try:
            from . import planner as planner_mod

            factory = planner_mod._ml_scorer_factory
            if factory.__dict__.get("_cached") is None:
                path = planner_mod._ML_SCORER_PATH
                if path is None or not path.exists():
                    # no trained model anywhere: the factory would train
                    # the corpus GBT from scratch -- never block a
                    # serving-path solve on that; the resource proxy /
                    # roofline prior rank until refresh() persists one
                    return None
            self.static = factory()
        except Exception:
            return None
        return self.static

    def __call__(self, sol) -> float:
        log = self.log
        sh = scheme_hash(sol)
        count, p50 = log.scheme_measured(sh)
        if count and p50 is not None:
            w = count / (count + self.k)
            return (w * p50
                    + (1.0 - w) * log.calibration()
                    * roofline_prior_seconds(sol))
        if log.has_measurements():
            return log.calibration() * roofline_prior_seconds(sol)
        static = self._static()
        if static is not None:
            return float(static(sol))
        if sol.resources is not None:   # proxy-of-last-resort
            return float(sol.resources.total.weighted())
        return roofline_prior_seconds(sol)


register_scorer("measured", MeasuredScorer)


# ---------------------------------------------------------------------------
# The service hub: instrument -> observe -> flush / refresh / demote
# ---------------------------------------------------------------------------


@dataclass
class TelemetryConfig:
    """Knobs for the feedback loop.

    ``min_observations``: measured evidence required before a plan may be
    demoted.  ``demote_ratio``: the served scheme's measured p50 must
    exceed the best alternative's (measured or calibrated-prior) estimate
    by this factor.  ``flush_every`` / ``refresh_every``: observations
    between store flushes / ``ml_scorer.json`` refits (0 disables the
    periodic refit; :meth:`ServiceTelemetry.refresh` still works on
    demand).
    """

    min_observations: int = 8
    demote_ratio: float = 2.0
    flush_every: int = 32
    refresh_every: int = 0
    sample_limit: int = _MAX_SAMPLES


class ServiceTelemetry:
    """The measured-cost hub one :class:`~repro.core.service.PlanService`
    owns (see :meth:`PlanService.enable_telemetry`).

    Wiring: the planner instruments every artifact it compiles
    (:meth:`instrument` attaches this hub as the artifact's timing sink);
    the service registers every plan it answers (:meth:`register` captures
    the served scheme, its prior, and the ranked runner-up's prior while
    the in-process solutions list is still attached); gather / scatter /
    tick timings arrive through :meth:`observe`, which feeds the log,
    bumps ``ServiceStats.observations``, flushes to the store's
    ``telemetry/`` sidecar every ``flush_every`` observations, and runs
    the demotion check.  Demotion fires **exactly once** per (signature,
    scorer): the stored loser is evicted and its prepared request
    resubmitted at high priority; the serving runtime polls
    :meth:`replacement` between ticks and hot-swaps when the re-solve
    lands.
    """

    def __init__(self, service=None, planner=None,
                 config: Optional[TelemetryConfig] = None,
                 log: Optional[TelemetryLog] = None):
        self.config = config if config is not None else TelemetryConfig()
        self.log = log if log is not None else TelemetryLog()
        self.service = service
        self.planner = (planner if planner is not None
                        else getattr(service, "planner", None))
        self._lock = threading.Lock()
        self._plans: Dict[Tuple[str, str], dict] = {}
        self._features: Dict[str, np.ndarray] = {}
        self._demoted: set = set()
        self._replacements: Dict[Tuple[str, str], object] = {}
        self._hydrated: set = set()
        self._since_flush = 0
        self._since_refresh = 0

    # -- registration ----------------------------------------------------------
    def register(self, prep, plan) -> None:
        """Note a plan the service just answered with: remember the served
        scheme's hash + prior, the ranked runner-up's prior (only fresh
        solves still carry ``solutions``), and static features for the
        refresh path; hydrate any persisted telemetry for the signature."""
        if plan is None or plan.best is None:
            return
        key = (plan.signature, plan.scorer_name)
        entry = {
            "prep": prep,
            "scheme": scheme_hash(plan.best),
            "prior": roofline_prior_seconds(plan.best),
        }
        for sol in plan.solutions[1:]:
            sh = scheme_hash(sol)
            if sh != entry["scheme"]:
                entry["runner_scheme"] = sh
                entry["runner_prior"] = roofline_prior_seconds(sol)
                break
        with self._lock:
            self._plans[key] = entry
        for sol in ([plan.best] + list(plan.solutions))[:16]:
            sh = scheme_hash(sol)
            with self._lock:
                if sh in self._features:
                    continue
            try:
                from .features import extract_features
                x = extract_features(sol)
            except Exception:
                continue
            with self._lock:
                self._features.setdefault(sh, x)
        self._hydrate(plan.signature)

    def _hydrate(self, signature: str) -> None:
        store = getattr(self.planner, "store", None)
        if store is None:
            return
        with self._lock:
            if signature in self._hydrated:
                return
            self._hydrated.add(signature)
        recs = store.get_telemetry(signature)
        if recs:
            self.log.hydrate(recs)

    def instrument(self, art) -> None:
        """Attach this hub as ``art``'s timing sink (opt-in hooks on
        gather/scatter).  The trivial fallback has no signature to key
        observations under, so it stays unhooked."""
        if art is not None and art.signature:
            art.enable_telemetry(self)

    def adapt_scorer(self, name: str, fn):
        """Rebind a registry-resolved :class:`MeasuredScorer` to this
        hub's log, so a service's solves rank on the service's own
        measurements rather than the process-default log."""
        if isinstance(fn, MeasuredScorer) and fn.log is not self.log:
            return fn.with_log(self.log)
        return fn

    # -- observation -----------------------------------------------------------
    def observe(self, art, op: str, shape, seconds: float) -> None:
        """One timed call (the artifact hooks and ``Server.tick`` both
        land here).  Log it, then run the flush / refresh / demote checks
        outside the log lock."""
        self.log.observe_artifact(art, op, shape, seconds)
        with self._lock:
            self._since_flush += 1
            self._since_refresh += 1
            do_flush = (self.config.flush_every > 0
                        and self._since_flush >= self.config.flush_every)
            if do_flush:
                self._since_flush = 0
            do_refresh = (self.config.refresh_every > 0
                          and self._since_refresh
                          >= self.config.refresh_every)
            if do_refresh:
                self._since_refresh = 0
        svc = self.service
        if svc is not None:
            with svc._lock:
                svc.stats.bump("observations")
            metrics = svc.metrics
            if metrics is not None:
                metrics.observe(f"observed_{op}_us", seconds * 1e6)
        if do_flush:
            self.flush()
        if do_refresh:
            self.refresh()
        if op in DATA_OPS:
            self._maybe_demote(art)

    # -- persistence -----------------------------------------------------------
    def flush(self) -> int:
        """Drain pending deltas into the store's telemetry sidecar.
        Returns the number of records merged (0 without a store: deltas
        keep accumulating for a later flush)."""
        store = getattr(self.planner, "store", None)
        if store is None:
            return 0
        drained = self.log.drain()
        n = 0
        for sig, recs in drained.items():
            store.merge_telemetry(sig, recs)
            n += len(recs)
        return n

    # -- online refresh --------------------------------------------------------
    def refresh(self) -> bool:
        """Refit the persisted ML scorer from accumulated (features,
        measured-microseconds) pairs.

        Fits a :class:`~repro.core.cost_model.ResourcePipeline` on every
        scheme with both static features (captured at register time) and
        measurements, grafts it onto the current ``"ml"`` scorer as a
        ``measured_us`` resource, and persists the result to the
        ``ml_scorer.json`` path -- the mtime advance makes every later
        ``"ml"`` resolution (satellite: mtime reload) pick it up.
        Returns False when fewer than two schemes are measured.
        """
        with self._lock:
            feats = dict(self._features)
        pairs = []
        for sh, x in feats.items():
            count, p50 = self.log.scheme_measured(sh)
            if count and p50 is not None:
                pairs.append((x, p50 * 1e6))
        if len(pairs) < 2:
            return False
        from . import planner as planner_mod
        from .cost_model import MLScorer, ResourcePipeline

        X = np.asarray([p[0] for p in pairs], dtype=float)
        y = np.asarray([p[1] for p in pairs], dtype=float)
        pipe = ResourcePipeline(
            gbt_params=dict(n_estimators=8, min_leaf=1)).fit(X, y)
        with planner_mod._ML_TRAIN_LOCK:
            base = planner_mod._ml_scorer_factory.__dict__.get("_cached")
            if isinstance(base, MLScorer):
                scorer = base.with_pipeline("measured_us", pipe, weight=1.0)
            else:
                scorer = MLScorer({"measured_us": pipe},
                                  weights={"measured_us": 1.0})
            planner_mod._ml_scorer_factory.__dict__["_cached"] = scorer
            path = planner_mod._ML_SCORER_PATH
            if path is not None:
                try:
                    path.parent.mkdir(parents=True, exist_ok=True)
                    tmp = path.with_suffix(".json.tmp")
                    tmp.write_text(json.dumps(scorer.to_json()))
                    tmp.replace(path)
                    planner_mod._ml_scorer_factory.__dict__[
                        "_cached_mtime"] = path.stat().st_mtime_ns
                except OSError:
                    pass  # persistence best-effort, like training's
        svc = self.service
        if svc is not None:
            with svc._lock:
                svc.stats.bump("refreshes")
        return True

    # -- demotion --------------------------------------------------------------
    def _demotion_threshold(self, key: Tuple[str, str],
                            entry: dict) -> Optional[float]:
        """Best alternative estimate, strongest evidence first: a measured
        sibling's p50; else the registered runner-up's calibrated prior;
        else the calibrated conflict-free floor."""
        signature = key[0]
        rival = self.log.best_rival(signature,
                                    exclude_scheme=entry["scheme"])
        if rival is not None:
            return rival[1]
        cal = self.log.calibration()
        runner = entry.get("runner_prior")
        if runner:
            return cal * runner
        return cal * roofline_floor_seconds()

    def _maybe_demote(self, art) -> None:
        key = (art.signature, art.scorer_name)
        with self._lock:
            entry = self._plans.get(key)
            if entry is None or key in self._demoted:
                return
        if scheme_hash(art) != entry["scheme"]:
            return   # not the stored best (already swapped / promoted)
        count, p50 = self.log.scheme_measured(entry["scheme"],
                                              signature=art.signature)
        if count < self.config.min_observations or p50 is None:
            return
        threshold = self._demotion_threshold(key, entry)
        if threshold is None or threshold <= 0.0:
            return
        if p50 <= self.config.demote_ratio * threshold:
            return
        with self._lock:
            if key in self._demoted:     # exactly-once under racing ticks
                return
            self._demoted.add(key)
        svc = self.service
        planner = self.planner
        if planner is not None:
            planner.evict(*key)
        if svc is not None:
            with svc._lock:
                svc.stats.bump("demotions")
            tr = svc.tracer
            if tr is not None:
                # a demotion is an anomaly the flight recorder should
                # dump: a stored plan measured slower than its rival
                tr.note_anomaly("demotion", detail=art.signature[:16])
            # speculative re-solve through the normal revalidation path:
            # the eviction above turned this into a cold submit, and the
            # scorer (rebound to this hub's log) now knows the loser lost
            ticket = svc.submit_prepared(entry["prep"], priority=-1)
            with self._lock:
                self._replacements[key] = ticket

    def replacement(self, key: Tuple[str, str]):
        """Pop the demotion re-solve ticket for ``key``, if one is
        waiting -- the serving runtime polls this between decode ticks
        and adopts the ticket like its original one."""
        with self._lock:
            return self._replacements.pop(key, None)


__all__ = [
    "DATA_OPS",
    "MeasuredCost",
    "MeasuredScorer",
    "ServiceTelemetry",
    "TELEMETRY_FORMAT",
    "TelemetryConfig",
    "TelemetryLog",
    "default_telemetry_log",
    "roofline_prior_seconds",
    "scheme_hash",
    "shape_bucket",
]
