"""Access grouping (paper Sec 3.2, Fig. 8).

A *group* is a set of accesses that can be live during the same cycle on the
same buffer of a memory.  Banking only needs to satisfy each group in
isolation.  We implement the paper's greedy algorithm with the obvious
correctness completion: when an access clashes with members of several
existing groups those groups are merged (concurrency must be handled jointly),
and when it clashes with none it founds a new group.

Reads and writes are grouped separately only insofar as the paper's port
model allows: a group mixes reads and writes freely; the port constraint k is
enforced later by Def 2.9.
"""

from __future__ import annotations

from typing import List

from .controller import UnrolledProgram, is_concurrent
from .polytope import AccessGroup


def build_groups(up: UnrolledProgram, memory: str) -> List[AccessGroup]:
    idxs = [i for i, a in enumerate(up.accesses) if a.memory == memory]
    groups: List[List[int]] = []
    for ia in idxs:
        clashing = []
        for g_id, grp in enumerate(groups):
            if any(is_concurrent(up, ia, ib) for ib in grp):
                clashing.append(g_id)
        if not clashing:
            groups.append([ia])
        else:
            keep = clashing[0]
            groups[keep].append(ia)
            # transitive merge of any other clashing group
            for g_id in reversed(clashing[1:]):
                groups[keep].extend(groups[g_id])
                del groups[g_id]
    return [AccessGroup([up.accesses[i] for i in grp]) for grp in groups]


def group_sizes(groups: List[AccessGroup]) -> List[int]:
    return [len(g) for g in groups]
