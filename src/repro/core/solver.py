"""Banking solution-set construction (paper Sec 3.3).

Searches the (N, B, alpha, P) space for valid hyperplane geometries plus the
multidimensional (orthogonal-lattice) subset, with the paper's heuristics:

* prioritize N among the first multiples of the LCM of group sizes (small
  fan-out schemes come first),
* de-prioritize constants that the Sec 3.4 transforms cannot break down,
* drop (alpha, B) pairs that are not mutually co-prime (the same geometry is
  reachable by dividing out the GCD),
* record *fewer-ported* solutions (required_ports < available ports), and
* *bank-by-duplication* solutions that split heavy reader groups across
  array duplicates.

Fan-out / fan-in metrics are computed exactly from reachable residue sets
(not sampling): for geometry (N, B), an access's bank set is
``{ r // B  :  r in residues(x . alpha  mod N*B) }``.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from functools import reduce
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .geometry import (
    ConflictCache,
    FlatGeometry,
    MultiDimGeometry,
    _max_conflict_clique,
    flat_conflict_edges,
    multidim_conflict_edges,
    padding as geom_padding,
    propose_P,
)
from .polytope import (
    Access,
    AccessGroup,
    Iterator,
    MemorySpec,
    linearize,
    reachable_residues
)
from .resources import SchemeResources, estimate_scheme
from .transforms import (
    Cost,
    build_flat_resolution,
    build_multidim_resolution,
    cost as graph_cost,
    count_raw_ops,
    transform_friendliness,
)


# ---------------------------------------------------------------------------
# Options / solution containers
# ---------------------------------------------------------------------------


@dataclass
class SolverOptions:
    max_solutions: int = 32
    n_cap_factor: int = 4          # search N up to cap_factor * max group size
    n_budget: int = 48             # max distinct N values examined
    b_candidates: Tuple[int, ...] = (1, 2, 4, 3, 8, 7)
    allow_multidim: bool = True
    allow_duplication: bool = True
    duplication_factors: Tuple[int, ...] = (2, 4)
    # "full" = Sec-3.4 rewrites; "basic" = pow2-only (ordinary codegen,
    # what the baseline/spatial/merlin comparison systems get)
    transform_level: str = "full"
    alpha_budget: int = 12
    multidim_combo_budget: int = 256


@dataclass
class BankingSolution:
    memory: MemorySpec
    kind: str                      # "flat" | "multidim"
    geometry: object               # FlatGeometry | MultiDimGeometry
    P: Tuple[int, ...]
    pad: Tuple[int, ...]
    required_ports: int
    num_banks: int
    bank_volume: int
    fan_outs: Tuple[int, ...]      # per grouped access (reads+writes)
    max_fan_in: int
    duplicates: int = 1
    resolution_ba: object = None   # Node | tuple of Nodes
    resolution_bo: object = None   # Node
    arith_cost: Cost = field(default_factory=Cost)
    raw_ops: Dict[str, int] = field(default_factory=dict)
    resources: Optional[SchemeResources] = None
    score: float = float("inf")    # ranking score (ML cost model or proxy)
    note: str = ""

    @property
    def dsp_free(self) -> bool:
        return (self.resources is None) or self.resources.total.dsp == 0

    def describe(self) -> str:
        g = self.geometry
        if self.kind == "flat":
            head = f"flat N={g.N} B={g.B} alpha={g.alpha} P={self.P}"
        else:
            head = f"multidim N={g.Ns} B={g.Bs} alpha={g.alphas}"
        r = self.resources.total if self.resources else None
        tail = (f" banks={self.num_banks} vol={self.bank_volume}"
                f" FOmax={max(self.fan_outs) if self.fan_outs else 1}"
                f" ports={self.required_ports} dup={self.duplicates}")
        if r:
            tail += (f" | LUT={r.lut:.0f} FF={r.ff:.0f} BRAM={r.bram}"
                     f" DSP={r.dsp}")
        return head + tail


# ---------------------------------------------------------------------------
# Candidate sets
# ---------------------------------------------------------------------------


def _lcm(vals: Sequence[int]) -> int:
    return reduce(lambda a, b: a * b // math.gcd(a, b), [v for v in vals if v], 1)


def n_candidates(group_sizes: Sequence[int], ports: int, opts: SolverOptions) -> List[int]:
    ell = max(group_sizes) if group_sizes else 1
    lcm = _lcm(group_sizes)
    need = max(1, -(-ell // max(1, ports)))
    cand = set()
    for m in range(1, 5):
        if lcm * m >= need:
            cand.add(lcm * m)
    hi = max(need + 1, opts.n_cap_factor * ell + 1)
    cand.update(range(need, hi))
    ordered = sorted(
        cand,
        key=lambda n: (
            0 if (lcm and n % lcm == 0) else 1,   # LCM multiples first (paper)
            transform_friendliness(n),             # then Sec 3.4-friendly
            n,
        ),
    )
    return ordered[: opts.n_budget]


def alpha_candidates(mem: MemorySpec, groups: Sequence[AccessGroup],
                     opts: SolverOptions) -> List[Tuple[int, ...]]:
    n = mem.n
    cands: List[Tuple[int, ...]] = []

    def add(v: Tuple[int, ...]):
        g = reduce(math.gcd, [abs(x) for x in v if x], 0)
        if g > 1:
            v = tuple(x // g for x in v)
        if any(v) and v not in cands:
            cands.append(v)

    for d in range(n):
        e = [0] * n
        e[d] = 1
        add(tuple(e))
    add(tuple([1] * n))
    add(linearize(mem.dims))
    # strides observed in the accesses, per dim
    for d in range(n):
        coeffs = set()
        for g in groups:
            for a in g:
                for _, c in a.exprs[d].terms:
                    coeffs.add(abs(c))
        for c in sorted(coeffs)[:2]:
            if c > 1:
                e = [0] * n
                e[d] = c
                add(tuple(e))
    # diagonal-ish mixes for 2-D memories (wavefront patterns e.g. sw)
    if n == 2:
        add((1, 2))
        add((2, 1))
    return cands[: opts.alpha_budget]


# ---------------------------------------------------------------------------
# Exact fan metrics from residues
# ---------------------------------------------------------------------------


def flat_bank_set(a: Access, alpha, N: int, B: int,
                  iters: Dict[str, Iterator]) -> frozenset:
    y = a.dot(alpha)
    res = reachable_residues(y, iters, N * B)
    return frozenset(int(r) // B for r in res)


def multidim_bank_sets(a: Access, geo: MultiDimGeometry,
                       iters: Dict[str, Iterator]) -> Tuple[frozenset, ...]:
    out = []
    for d in range(len(geo.Ns)):
        y = a.exprs[d].scale(geo.alphas[d])
        res = reachable_residues(y, iters, geo.Ns[d] * geo.Bs[d])
        out.append(frozenset(int(r) // geo.Bs[d] for r in res))
    return tuple(out)


def _fan_metrics_flat(groups, alpha, N, B, iters):
    fos, fis = [], {}
    write_fos = []
    for g in groups:
        bank_touch: Dict[int, int] = {}
        for a in g:
            banks = flat_bank_set(a, alpha, N, B, iters)
            fos.append(len(banks))
            if a.is_write:
                write_fos.append(len(banks))
            for b in banks:
                bank_touch[b] = bank_touch.get(b, 0) + 1
        for b, c in bank_touch.items():
            fis[b] = max(fis.get(b, 0), c)
    return fos, write_fos, (max(fis.values()) if fis else 1), fis


def _fan_metrics_multidim(groups, geo, iters):
    fos, fis = [], {}
    write_fos = []
    for g in groups:
        bank_touch: Dict[Tuple, int] = {}
        for a in g:
            sets = multidim_bank_sets(a, geo, iters)
            fo = int(np.prod([len(s) for s in sets]))
            fos.append(fo)
            if a.is_write:
                write_fos.append(fo)
            for combo in itertools.product(*sets):
                bank_touch[combo] = bank_touch.get(combo, 0) + 1
        for b, c in bank_touch.items():
            fis[b] = max(fis.get(b, 0), c)
    return fos, write_fos, (max(fis.values()) if fis else 1), fis


# ---------------------------------------------------------------------------
# Resolution circuits + resource estimation for a geometry
# ---------------------------------------------------------------------------


def _flat_in_bits(mem: MemorySpec, alpha) -> int:
    span = sum(abs(a) * (d - 1) for a, d in zip(alpha, mem.dims)) + 1
    return max(4, span.bit_length() + 1)


def _attach_flat(sol_groups, mem, geo: FlatGeometry, P, iters,
                 required_ports, opts: SolverOptions, duplicates=1,
                 note="") -> BankingSolution:
    fos, wfos, max_fi, _ = _fan_metrics_flat(sol_groups, geo.alpha, geo.N, geo.B, iters)
    in_bits = _flat_in_bits(mem, geo.alpha)
    ba, bo = build_flat_resolution(geo.N, geo.B, geo.alpha, P, mem.dims,
                                   in_bits, level=opts.transform_level)
    ba_cost, bo_cost = graph_cost(ba, in_bits), graph_cost(bo, in_bits)
    res_costs = []
    # BA circuit elided for accesses pinned to one bank (constant-foldable)
    i = 0
    for g in sol_groups:
        for a in g:
            c = bo_cost if fos[i] == 1 else (ba_cost + bo_cost)
            res_costs.append(c)
            i += 1
    bank_vol = geo.bank_volume(mem.dims)
    resources = estimate_scheme(
        num_banks=geo.N,
        bank_volume=bank_vol,
        word_bits=mem.word_bits,
        addr_bits=max(1, (max(bank_vol - 1, 1)).bit_length()),
        fan_outs=[f for f in fos],
        fan_ins=[max_fi] * sum(1 for f in fos if f > 1) or [1],
        writes_fan_outs=wfos,
        resolution_costs=res_costs,
        duplicates=duplicates,
    )
    arith = Cost()
    for c in res_costs:
        arith = arith + c
    raw = count_raw_ops(ba)
    raw_bo = count_raw_ops(bo)
    raw = {k: raw[k] + raw_bo[k] for k in raw}
    return BankingSolution(
        memory=mem, kind="flat", geometry=geo, P=P,
        pad=geom_padding(mem, P), required_ports=required_ports,
        num_banks=geo.N, bank_volume=bank_vol, fan_outs=tuple(fos),
        max_fan_in=max_fi, duplicates=duplicates,
        resolution_ba=ba, resolution_bo=bo, arith_cost=arith, raw_ops=raw,
        resources=resources, note=note,
    )


def _attach_multidim(sol_groups, mem, geo: MultiDimGeometry, iters,
                     required_ports, opts: SolverOptions,
                     note="") -> BankingSolution:
    fos, wfos, max_fi, _ = _fan_metrics_multidim(sol_groups, geo, iters)
    in_bits = max(_flat_in_bits(mem, geo.alphas), 8)
    bas, bo = build_multidim_resolution(geo.Ns, geo.Bs, geo.alphas, mem.dims,
                                        in_bits, level=opts.transform_level)
    ba_cost = Cost()
    for b in bas:
        ba_cost = ba_cost + graph_cost(b, in_bits)
    bo_cost = graph_cost(bo, in_bits)
    res_costs = []
    i = 0
    for g in sol_groups:
        for a in g:
            res_costs.append(bo_cost if fos[i] == 1 else ba_cost + bo_cost)
            i += 1
    bank_vol = geo.bank_volume(mem.dims)
    resources = estimate_scheme(
        num_banks=geo.num_banks,
        bank_volume=bank_vol,
        word_bits=mem.word_bits,
        addr_bits=max(1, (max(bank_vol - 1, 1)).bit_length()),
        fan_outs=list(fos),
        fan_ins=[max_fi] * sum(1 for f in fos if f > 1) or [1],
        writes_fan_outs=wfos,
        resolution_costs=res_costs,
    )
    arith = Cost()
    for c in res_costs:
        arith = arith + c
    raw = {"mul": 0, "div": 0, "mod": 0}
    for g_ in list(bas) + [bo]:
        r = count_raw_ops(g_)
        raw = {k: raw[k] + r[k] for k in raw}
    P = tuple(max(1, -(-d // n)) for d, n in zip(mem.dims, geo.Ns))
    return BankingSolution(
        memory=mem, kind="multidim", geometry=geo, P=P,
        pad=geom_padding(mem, P), required_ports=required_ports,
        num_banks=geo.num_banks, bank_volume=bank_vol, fan_outs=tuple(fos),
        max_fan_in=max_fi, resolution_ba=bas, resolution_bo=bo,
        arith_cost=arith, raw_ops=raw, resources=resources, note=note,
    )


# ---------------------------------------------------------------------------
# Searches
# ---------------------------------------------------------------------------


def search_flat(mem: MemorySpec, groups: List[AccessGroup],
                iters: Dict[str, Iterator], opts: SolverOptions,
                duplicates: int = 1, note: str = "") -> List[BankingSolution]:
    cache = ConflictCache(iters)
    sizes = [len(g) for g in groups]
    out: List[BankingSolution] = []
    for alpha in alpha_candidates(mem, groups, opts):
        a_gcd = reduce(math.gcd, [abs(x) for x in alpha if x], 0)
        for B in opts.b_candidates:
            if B > 1 and math.gcd(a_gcd, B) != 1:
                continue  # co-primality pruning (paper Sec 3.3)
            for N in n_candidates(sizes, mem.ports, opts):
                geo = FlatGeometry(N=N, B=B, alpha=tuple(alpha), P=(1,) * mem.n)
                worst = 1
                ok = True
                for g in groups:
                    edges = flat_conflict_edges(list(g), geo, cache)
                    clique = _max_conflict_clique(len(g), edges)
                    worst = max(worst, clique)
                    if clique > mem.ports:
                        ok = False
                        break
                if not ok:
                    continue
                for P in propose_P(mem, N, B, alpha)[:2]:
                    geoP = FlatGeometry(N=N, B=B, alpha=tuple(alpha), P=P)
                    out.append(
                        _attach_flat(groups, mem, geoP, P, iters, worst, opts,
                                     duplicates=duplicates, note=note)
                    )
                if len(out) >= opts.max_solutions:
                    return out
    return out


def _dim_value_counts(groups: List[AccessGroup], dim: int) -> int:
    """Distinct projections of the accesses on one dimension."""
    seen = set()
    for g in groups:
        local = set()
        for a in g:
            e = a.exprs[dim]
            local.add((e.terms, e.syms, e.const))
        seen.add(len(local))
    return max(seen) if seen else 1


def search_multidim(mem: MemorySpec, groups: List[AccessGroup],
                    iters: Dict[str, Iterator], opts: SolverOptions
                    ) -> List[BankingSolution]:
    if mem.n < 2:
        return []
    cache = ConflictCache(iters)
    ell = max((len(g) for g in groups), default=1)
    cap = max(4 * ell, 8)
    per_dim: List[List[int]] = []
    for d in range(mem.n):
        k = _dim_value_counts(groups, d)
        cands = {1, k}
        cands.add(1 << max(0, (k - 1)).bit_length())  # next pow2
        if k + 1 <= mem.dims[d]:
            cands.add(k + 1)
        per_dim.append(sorted(c for c in cands if 1 <= c <= max(mem.dims[d], 1)))
    out: List[BankingSolution] = []
    combos = 0
    for Ns in itertools.product(*per_dim):
        combos += 1
        if combos > opts.multidim_combo_budget or len(out) >= opts.max_solutions:
            break
        if int(np.prod(Ns)) > cap or int(np.prod(Ns)) < 2:
            continue
        for Bs in ((1,) * mem.n, (2,) + (1,) * (mem.n - 1)):
            geo = MultiDimGeometry(Ns=tuple(Ns), Bs=Bs, alphas=(1,) * mem.n)
            worst = 1
            ok = True
            for g in groups:
                edges = multidim_conflict_edges(list(g), geo, cache)
                clique = _max_conflict_clique(len(g), edges)
                worst = max(worst, clique)
                if clique > mem.ports:
                    ok = False
                    break
            if ok:
                out.append(_attach_multidim(groups, mem, geo, iters, worst, opts))
    return out


def search_duplication(mem: MemorySpec, groups: List[AccessGroup],
                       iters: Dict[str, Iterator], opts: SolverOptions
                       ) -> List[BankingSolution]:
    """Split the heaviest read group across duplicates and re-solve
    (paper: best when LUTs are scarce but BRAMs are abundant)."""
    if not groups:
        return []
    read_groups = [g for g in groups if not any(a.is_write for a in g)]
    if not read_groups:
        return []
    big = max(read_groups, key=len)
    if len(big) < 4:
        return []
    others = [g for g in groups if g is not big]
    out: List[BankingSolution] = []
    cache = ConflictCache(iters)
    for D in opts.duplication_factors:
        if len(big) < 2 * D:
            continue
        subsets = [AccessGroup(list(big)[i::D]) for i in range(D)]
        worst_subset = max(subsets, key=len)
        sub_opts = SolverOptions(
            max_solutions=8, n_budget=24,
            transform_level=opts.transform_level,
            allow_multidim=False, allow_duplication=False,
        )
        sols = search_flat(mem, others + [worst_subset], iters, sub_opts,
                           duplicates=D, note=f"dup x{D}")
        # the SAME geometry must be conflict-free for EVERY duplicate's
        # subset (writes are broadcast to all duplicates).  The `others`
        # groups don't change per duplicate -- the sub-search above
        # already verified them for every emitted geometry -- so only
        # each duplicate's subset needs re-checking, and a geometry's
        # verdict is shared across its P-proposal variants.
        verdicts: Dict[Tuple, bool] = {}
        valid = []
        for s in sols:
            gkey = (s.geometry.N, s.geometry.B, s.geometry.alpha)
            ok = verdicts.get(gkey)
            if ok is None:
                ok = True
                for sub in subsets:
                    edges = flat_conflict_edges(list(sub), s.geometry,
                                                cache)
                    if _max_conflict_clique(len(sub), edges) > mem.ports:
                        ok = False
                        break
                verdicts[gkey] = ok
            if ok:
                valid.append(s)
        out.extend(valid[:2])
    return out


def solve_monolithic(mem: MemorySpec, groups: List[AccessGroup],
                     iters: Dict[str, Iterator],
                     opts: Optional[SolverOptions] = None
                     ) -> List[BankingSolution]:
    """The pre-pipeline single-threaded nested-loop search.

    Kept as the reference implementation: the shard-equivalence property
    asserts that merging ``evaluate()`` streams reproduces this
    function's chosen scheme for any shard count -- whether the shards
    ran in-thread (tests/test_candidates.py), on a fork pool
    (``evaluate_parallel``), or on remote solve-fabric workers over the
    wire (tests/test_fabric.py).
    """
    opts = opts or SolverOptions()
    sols = search_flat(mem, groups, iters, opts)
    if opts.allow_multidim:
        sols += search_multidim(mem, groups, iters, opts)
    if opts.allow_duplication:
        sols += search_duplication(mem, groups, iters, opts)
    return sols


def solve(mem: MemorySpec, groups: List[AccessGroup],
          iters: Dict[str, Iterator],
          opts: Optional[SolverOptions] = None) -> List[BankingSolution]:
    """Construct the banking solution set for one problem.

    Since the candidate-space redesign this is the single-shard run of
    the shardable pipeline (enumerate -> evaluate -> reduce; see
    :mod:`repro.core.candidates`): the same code path the service's
    sharded workers fan out across, with the reducer's section cuts
    reproducing the classic early-exit budgets, so the result matches
    :func:`solve_monolithic` exactly.
    """
    from .candidates import CandidateSpace, solve_space

    space = CandidateSpace(mem, groups, iters, opts or SolverOptions())
    return solve_space(space)
