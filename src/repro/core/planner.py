"""Plan-oriented front door for the banking system.

The original free-function API re-ran the full
unroll -> group -> solve -> rank pipeline on every call -- including in the
serving hot path, where every decode tick poses the *same* KV-pool banking
problem.  This module makes memory configuration a reusable, durable
artifact instead of an inline computation:

* ``BankingPlanner.plan`` keys each problem by a **canonical program
  signature** -- a stable content hash of the unrolled access polytopes
  (post-grouping), the memory spec, and the solver options -- so
  structurally identical programs hit a cache instead of re-solving.
* A ``BankingPlan`` carries the chosen scheme plus provenance (candidates
  considered, scorer used, solve time) and serializes to/from JSON, so
  benchmark runs and servers can warm-start from plans on disk
  (``cache_dir=...`` / ``warm_start``).  Deserialization rebuilds the
  Sec-3.4 resolution graphs, so a loaded plan drives the Pallas
  banked-gather kernel exactly like a freshly solved one.
* Scorers are resolved through a **registry** (``"proxy"``, ``"ml"``, or
  any callable registered with ``register_scorer``) instead of ad-hoc
  ``scorer=`` callable threading.
* ``BankingPlanner.plan_all`` solves independent memories concurrently on
  a thread pool with a per-memory timeout.

Since the service redesign, ``BankingPlanner.plan`` is itself a thin
``service.submit(...).result()`` over the planner's inline
:class:`repro.core.service.PlanService` -- the synchronous and asynchronous
front doors share one code path (prepare -> lookup -> solve), and
durability is delegated to a pluggable :class:`repro.core.store.PlanStore`
(``cache_dir=`` is sugar for a cross-process ``DirectoryStore``).
"""

from __future__ import annotations

import copy
import hashlib
import json
import threading
import time
from dataclasses import asdict, dataclass, field, replace
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple, Union

from .artifact import CompiledBankingPlan, compile_plan
from .controller import Program, unroll
from .grouping import build_groups
from .store import PlanStore, as_store
from .polytope import AccessGroup, Affine, Iterator, MemorySpec
from .resources import ResourceEstimate, SchemeResources
from .solver import (
    BankingSolution,
    SolverOptions,
    _flat_in_bits,
    solve,
)
from .transforms import (
    Cost,
    build_flat_resolution,
    build_multidim_resolution,
    cost as graph_cost,
    count_raw_ops,
)

SIGNATURE_VERSION = 1

ScorerLike = Union[str, Callable[[BankingSolution], float], None]


# ---------------------------------------------------------------------------
# Scorer registry
# ---------------------------------------------------------------------------

_SCORER_FACTORIES: Dict[str, Callable[[], Optional[Callable]]] = {}
_SCORER_LOCK = threading.Lock()


def register_scorer(name: str,
                    factory: Callable[[], Optional[Callable]]) -> None:
    """Register ``factory`` under ``name``.

    ``factory`` is called (once per resolution) to produce a
    ``BankingSolution -> float`` callable, or ``None`` for the built-in
    weighted-resource proxy ranking.
    """
    with _SCORER_LOCK:
        _SCORER_FACTORIES[name] = factory


def registered_scorers() -> Tuple[str, ...]:
    return tuple(sorted(_SCORER_FACTORIES))


def scorer_key(spec: ScorerLike) -> str:
    """Cache-key name for a scorer spec, with NO factory side effects.

    Unregistered callables are keyed by name *and* object identity so two
    different lambdas never alias each other's cached rankings; raises
    ``ValueError`` for unknown registry names.
    """
    if spec is None:
        spec = "proxy"
    if callable(spec):
        name = getattr(spec, "__name__", None) or type(spec).__name__
        return f"custom:{name}:{id(spec):x}"
    if spec not in _SCORER_FACTORIES:
        raise ValueError(
            f"unknown scorer {spec!r}; registered scorers: "
            f"{', '.join(registered_scorers())}"
        )
    return spec


def resolve_scorer(spec: ScorerLike) -> Tuple[str, Optional[Callable]]:
    """Resolve a scorer spec to ``(name, callable-or-None)``.

    ``None`` means the proxy; a callable passes through; a string looks up
    the registry (invoking its factory) and raises ``ValueError`` for
    unknown names.
    """
    name = scorer_key(spec)
    if callable(spec):
        return name, spec
    if spec is None:
        spec = "proxy"
    return name, _SCORER_FACTORIES[spec]()


_ML_SCORER_PATH: Optional[Path] = None


def set_ml_scorer_path(path: Optional[Union[str, Path]]) -> None:
    """Where the trained ``"ml"`` scorer pipeline persists as JSON.

    ``BankingPlanner(cache_dir=...)`` points this next to the plan cache
    (``cache_dir/ml_scorer.json``) so one process's training warm-starts
    every later one; ``None`` disables persistence.  Switching to a
    *different* path drops the cached scorer, so the next ``"ml"``
    resolution loads (or trains for) the new location instead of serving
    the first-loaded pipeline forever.
    """
    global _ML_SCORER_PATH
    with _ML_TRAIN_LOCK:
        new = Path(path) if path is not None else None
        if new != _ML_SCORER_PATH:
            _ml_scorer_factory.__dict__.pop("_cached", None)
            _ml_scorer_factory.__dict__.pop("_cached_mtime", None)
        _ML_SCORER_PATH = new


def _ml_scorer_factory() -> Callable:
    """The Sec-3.5 ML cost model: load a persisted pipeline when present,
    otherwise train on a small synthetic corpus (heavy: one GBT pipeline
    per resource) and persist it next to the plan cache.

    Cached for the process lifetime -- but a persisted file whose mtime
    advanced past the load (another process refreshed ``ml_scorer.json``
    from measured telemetry) is reloaded, so refits propagate without a
    restart.  The lock is held end-to-end so concurrent planners share one
    model instead of each training their own.
    """
    with _ML_TRAIN_LOCK:
        cached = _ml_scorer_factory.__dict__.get("_cached")
        if cached is not None and _ML_SCORER_PATH is not None:
            known = _ml_scorer_factory.__dict__.get("_cached_mtime")
            try:
                disk = _ML_SCORER_PATH.stat().st_mtime_ns
            except OSError:
                disk = None
            if known is not None and disk is not None and disk > known:
                cached = None   # file refreshed on disk: reload below
        if cached is not None:
            return cached
        if _ML_SCORER_PATH is not None and _ML_SCORER_PATH.exists():
            from .cost_model import MLScorer

            try:
                scorer = MLScorer.from_json(
                    json.loads(_ML_SCORER_PATH.read_text()))
                mtime = _ML_SCORER_PATH.stat().st_mtime_ns
            except (ValueError, KeyError, TypeError, json.JSONDecodeError,
                    OSError):
                pass  # damaged/unreadable pipeline file: retrain below
            else:
                _ml_scorer_factory.__dict__["_cached"] = scorer
                _ml_scorer_factory.__dict__["_cached_mtime"] = mtime
                return scorer
        scorer = _train_ml_scorer()
        if _ML_SCORER_PATH is not None:
            try:
                _ML_SCORER_PATH.parent.mkdir(parents=True, exist_ok=True)
                tmp = _ML_SCORER_PATH.with_suffix(".json.tmp")
                tmp.write_text(json.dumps(scorer.to_json()))
                tmp.replace(_ML_SCORER_PATH)
                _ml_scorer_factory.__dict__["_cached_mtime"] = \
                    _ML_SCORER_PATH.stat().st_mtime_ns
            except OSError:
                pass  # persistence is best-effort; the in-memory cache holds
        return scorer


def _train_ml_scorer() -> Callable:
    import numpy as np

    from .cost_model import MLScorer, ResourcePipeline
    from .dataset import corpus_programs, synthetic_pnr
    from .features import extract_features

    opts = SolverOptions(max_solutions=8, n_budget=8, allow_duplication=False)
    rows, labels = [], {"lut": [], "ff": [], "bram": []}
    for _name, prog in corpus_programs(seed=0)[:6]:
        up = unroll(prog)
        for memname, mem in prog.memories.items():
            groups = build_groups(up, memname)
            for s in solve(mem, groups, up.iterators, opts)[:8]:
                rows.append(extract_features(s, groups))
                lab = synthetic_pnr(s)
                for k in labels:
                    labels[k].append(lab[k])
    X = np.asarray(rows)
    pipes = {
        k: ResourcePipeline(gbt_params=dict(n_estimators=40)).fit(
            X, np.asarray(v))
        for k, v in labels.items()
    }
    scorer = MLScorer(pipes)
    _ml_scorer_factory.__dict__["_cached"] = scorer
    return scorer


_ML_TRAIN_LOCK = threading.Lock()

register_scorer("proxy", lambda: None)
register_scorer("ml", _ml_scorer_factory)


def rank_solutions(
    sols: List[BankingSolution],
    scorer: Optional[Callable[[BankingSolution], float]] = None,
) -> List[BankingSolution]:
    """Order candidate schemes best-first.

    ``scorer`` is normally the ML cost model (core.cost_model.MLScorer);
    without one we fall back to the weighted resource proxy -- this fallback
    is exactly the 'first-order rules' behaviour the paper improves upon.
    """
    for s in sols:
        if scorer is not None:
            s.score = float(scorer(s))
        elif s.resources is not None:
            s.score = s.resources.total.weighted()
    return sorted(sols, key=lambda s: s.score)


# ---------------------------------------------------------------------------
# Canonical program signatures
# ---------------------------------------------------------------------------


def _affine_payload(e: Affine) -> list:
    return [list(map(list, e.terms)), list(map(list, e.syms)), e.const]


def _groups_payload(groups: List[AccessGroup]) -> list:
    return [
        [
            {
                "exprs": [_affine_payload(e) for e in a.exprs],
                "write": a.is_write,
                "cycle": a.sched_cycle,
            }
            for a in g
        ]
        for g in groups
    ]


def _iterators_payload(groups: List[AccessGroup],
                       iters: Dict[str, Iterator]) -> list:
    used = set()
    for g in groups:
        for a in g:
            for e in a.exprs:
                used.update(e.iterator_names)
    return [
        [it.name, it.start, it.step, it.count]
        for name in sorted(used)
        if (it := iters.get(name)) is not None
    ]


def _problem_payload(mem: MemorySpec, groups: List[AccessGroup],
                     iters: Dict[str, Iterator]) -> dict:
    return {
        "v": SIGNATURE_VERSION,
        "memory": [list(mem.dims), mem.word_bits, mem.ports],
        "groups": _groups_payload(groups),
        "iterators": _iterators_payload(groups, iters),
    }


def _hash_payload(prefix: str, payload: dict) -> str:
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"),
                      default=list)
    return prefix + hashlib.sha256(blob.encode()).hexdigest()[:32]


def canonical_signature(mem: MemorySpec, groups: List[AccessGroup],
                        iters: Dict[str, Iterator],
                        opts: SolverOptions) -> str:
    """Stable content hash of one banking problem.

    Hashes exactly the inputs ``solve`` consumes -- the unrolled,
    concurrency-grouped access polytopes, the memory spec (minus its name:
    identity is structural), the iterator domains the accesses reference,
    and the solver options -- so structurally identical programs collide by
    construction.  The prefix encodes ``SIGNATURE_VERSION``, which is what
    ``DirectoryStore.sweep()`` keys stale-entry garbage collection on.
    """
    payload = _problem_payload(mem, groups, iters)
    payload["opts"] = asdict(opts)
    return _hash_payload(f"bp{SIGNATURE_VERSION}-", payload)


def family_signature(mem: MemorySpec, groups: List[AccessGroup],
                     iters: Dict[str, Iterator]) -> str:
    """Signature of the problem *family*: the access structure without the
    solver options.  Two submits whose canonical signatures differ only in
    options share a family -- any member's scheme is a valid (if possibly
    suboptimal) scheme for the others, which is what lets the service's
    stale-while-revalidate policy answer from a stored near-match while
    the exact solve runs in the background."""
    return _hash_payload(f"bf{SIGNATURE_VERSION}-",
                         _problem_payload(mem, groups, iters))


def program_signature(program: Program, memory: str,
                      opts: Optional[SolverOptions] = None) -> str:
    """Convenience wrapper: signature of ``(program, memory)`` as posed."""
    up = unroll(program)
    groups = build_groups(up, memory)
    return canonical_signature(program.memories[memory], groups,
                               up.iterators, opts or SolverOptions())


# ---------------------------------------------------------------------------
# Plans
# ---------------------------------------------------------------------------


@dataclass
class PlanRequest:
    """One banking problem posed to the planner."""

    program: Program
    memory: str
    opts: Optional[SolverOptions] = None
    scorer: ScorerLike = None      # None -> planner default
    use_cache: bool = True


@dataclass
class BankingPlan:
    """A durable banking decision: chosen scheme + provenance.

    ``solutions`` and ``groups`` are retained in-memory for fresh solves
    (and memory-cache hits) but are not serialized; a plan loaded from disk
    carries only the chosen scheme.
    """

    memory: str
    signature: str
    best: Optional[BankingSolution]
    solve_seconds: float = 0.0
    num_candidates: int = 0
    scorer_name: str = "proxy"
    status: str = "solved"   # solved | cached | cached-disk | timeout | error
    created_at: float = 0.0
    opts: SolverOptions = field(default_factory=SolverOptions)
    solutions: List[BankingSolution] = field(default_factory=list)
    groups: List[AccessGroup] = field(default_factory=list)
    error: str = ""
    family: str = ""         # options-free problem-family signature

    # -- compilation ---------------------------------------------------------
    def compile(self, backend: str = "jax") -> "CompiledBankingPlan":
        """Lower the chosen scheme to an executable CompiledBankingPlan.

        Plans produced by a planner route through that planner's compile
        cache (keyed by plan signature + backend, persisted alongside the
        JSON plan cache); detached plans compile standalone.
        """
        owner = getattr(self, "_planner", None)
        if owner is not None:
            return owner.compile(self, backend=backend)
        return compile_plan(self, backend=backend)

    # -- tabulation ------------------------------------------------------------
    def table_row(self) -> Dict[str, float]:
        """One benchmark-table row for the chosen scheme, including the
        budget axes joint planning accounts in (physical banks x
        duplicates, total bank volume)."""
        b = self.best
        r = b.resources.total if b is not None and b.resources else None
        banks = (b.num_banks * max(1, b.duplicates)) if b else 0
        return {
            "memory": self.memory,
            "lut": r.lut if r else float("nan"),
            "ff": r.ff if r else float("nan"),
            "bram": r.bram if r else 0,
            "dsp": r.dsp if r else 0,
            "banks": banks,
            "volume": banks * (b.bank_volume if b else 0),
            "seconds": self.solve_seconds,
        }

    def as_dict(self) -> dict:
        """Budget-accounting view of the chosen scheme: provenance plus
        the full :class:`~repro.core.resources.SchemeResources`
        breakdown, so budget sums and the joint bench never reach into
        ``core/`` internals."""
        def est(e: Optional[ResourceEstimate]) -> Optional[dict]:
            if e is None:
                return None
            return {"lut": e.lut, "ff": e.ff, "bram": e.bram, "dsp": e.dsp}

        b = self.best
        res = b.resources if b is not None else None
        banks = (b.num_banks * max(1, b.duplicates)) if b else 0
        return {
            "memory": self.memory,
            "signature": self.signature,
            "status": self.status,
            "scorer": self.scorer_name,
            "seconds": self.solve_seconds,
            "score": float(b.score) if b is not None else None,
            "kind": b.kind if b is not None else None,
            "banks": banks,
            "bank_volume": b.bank_volume if b is not None else 0,
            "volume": banks * (b.bank_volume if b else 0),
            "duplicates": b.duplicates if b is not None else 0,
            "resources": None if res is None else {
                "total": est(res.total),
                "crossbar": est(res.crossbar),
                "resolution": est(res.resolution),
                "storage": est(res.storage),
            },
            "error": self.error,
        }

    # -- serialization -------------------------------------------------------
    def to_json(self) -> dict:
        return {
            "format": "banking-plan/v1",
            "memory": self.memory,
            "signature": self.signature,
            "solve_seconds": self.solve_seconds,
            "num_candidates": self.num_candidates,
            "scorer_name": self.scorer_name,
            "status": self.status,
            "created_at": self.created_at,
            "opts": asdict(self.opts),
            "best": _solution_to_json(self.best) if self.best else None,
            "error": self.error,
            "family": self.family,
        }

    @staticmethod
    def from_json(d: dict) -> "BankingPlan":
        if d.get("format") != "banking-plan/v1":
            raise ValueError(f"not a banking plan: format={d.get('format')!r}")
        opts_d = dict(d.get("opts") or {})
        for k in ("b_candidates", "duplication_factors"):
            if k in opts_d:
                opts_d[k] = tuple(opts_d[k])
        opts = SolverOptions(**opts_d)
        best = _solution_from_json(d["best"], opts) if d.get("best") else None
        return BankingPlan(
            memory=d["memory"],
            signature=d["signature"],
            best=best,
            solve_seconds=d.get("solve_seconds", 0.0),
            num_candidates=d.get("num_candidates", 0),
            scorer_name=d.get("scorer_name", "proxy"),
            status=d.get("status", "solved"),
            created_at=d.get("created_at", 0.0),
            opts=opts,
            error=d.get("error", ""),
            family=d.get("family", ""),
        )

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(path.suffix + ".tmp")
        tmp.write_text(json.dumps(self.to_json(), indent=1, sort_keys=True))
        tmp.replace(path)
        return path

    @staticmethod
    def load(path) -> "BankingPlan":
        return BankingPlan.from_json(json.loads(Path(path).read_text()))


# -- BankingSolution <-> JSON ------------------------------------------------


def _solution_to_json(sol: BankingSolution) -> dict:
    from .geometry import FlatGeometry, MultiDimGeometry  # noqa: F401

    g = sol.geometry
    if sol.kind == "flat":
        geo = {"N": g.N, "B": g.B, "alpha": list(g.alpha), "P": list(g.P)}
    else:
        geo = {"Ns": list(g.Ns), "Bs": list(g.Bs), "alphas": list(g.alphas)}
    res = None
    if sol.resources is not None:
        res = {
            part: asdict(getattr(sol.resources, part))
            for part in ("total", "crossbar", "resolution", "storage")
        }
    return {
        "memory": {"name": sol.memory.name, "dims": list(sol.memory.dims),
                   "word_bits": sol.memory.word_bits,
                   "ports": sol.memory.ports},
        "kind": sol.kind,
        "geometry": geo,
        "P": list(sol.P),
        "pad": list(sol.pad),
        "required_ports": sol.required_ports,
        "num_banks": sol.num_banks,
        "bank_volume": sol.bank_volume,
        "fan_outs": list(sol.fan_outs),
        "max_fan_in": sol.max_fan_in,
        "duplicates": sol.duplicates,
        "raw_ops": dict(sol.raw_ops),
        "score": sol.score,
        "note": sol.note,
        "resources": res,
    }


def _solution_from_json(d: dict, opts: SolverOptions) -> BankingSolution:
    """Rebuild a solution, including its Sec-3.4 resolution graphs, so the
    loaded plan is directly usable by the banked-gather kernel."""
    from .geometry import FlatGeometry, MultiDimGeometry

    m = d["memory"]
    mem = MemorySpec(m["name"], dims=tuple(m["dims"]),
                     word_bits=m["word_bits"], ports=m["ports"])
    level = opts.transform_level
    P = tuple(d["P"])
    if d["kind"] == "flat":
        gd = d["geometry"]
        geo = FlatGeometry(N=gd["N"], B=gd["B"], alpha=tuple(gd["alpha"]),
                           P=P)
        in_bits = _flat_in_bits(mem, geo.alpha)
        ba, bo = build_flat_resolution(geo.N, geo.B, geo.alpha, P, mem.dims,
                                       in_bits, level=level)
        graphs = [ba]
    else:
        gd = d["geometry"]
        geo = MultiDimGeometry(Ns=tuple(gd["Ns"]), Bs=tuple(gd["Bs"]),
                               alphas=tuple(gd["alphas"]))
        in_bits = max(_flat_in_bits(mem, geo.alphas), 8)
        ba, bo = build_multidim_resolution(geo.Ns, geo.Bs, geo.alphas,
                                           mem.dims, in_bits, level=level)
        graphs = list(ba)
    arith = Cost()
    for node in graphs + [bo]:
        arith = arith + graph_cost(node, in_bits)
    raw = {"mul": 0, "div": 0, "mod": 0}
    for node in graphs + [bo]:
        r = count_raw_ops(node)
        raw = {k: raw[k] + r[k] for k in raw}
    resources = None
    if d.get("resources"):
        parts = {
            part: ResourceEstimate(**d["resources"][part])
            for part in ("total", "crossbar", "resolution", "storage")
        }
        resources = SchemeResources(**parts)
    return BankingSolution(
        memory=mem,
        kind=d["kind"],
        geometry=geo,
        P=P,
        pad=tuple(d["pad"]),
        required_ports=d["required_ports"],
        num_banks=d["num_banks"],
        bank_volume=d["bank_volume"],
        fan_outs=tuple(d["fan_outs"]),
        max_fan_in=d["max_fan_in"],
        duplicates=d.get("duplicates", 1),
        resolution_ba=ba,
        resolution_bo=bo,
        arith_cost=arith,
        raw_ops=raw,
        resources=resources,
        score=d.get("score", float("inf")),
        note=d.get("note", ""),
    )


# ---------------------------------------------------------------------------
# The planner
# ---------------------------------------------------------------------------


@dataclass
class PlannerStats:
    hits: int = 0
    disk_hits: int = 0
    misses: int = 0
    solves: int = 0
    compiles: int = 0
    compile_hits: int = 0
    compile_disk_hits: int = 0


@dataclass
class PreparedRequest:
    """A ``PlanRequest`` after the cheap synchronous half of planning:
    unroll + grouping + signatures, ready for a cache probe or a solve.

    ``PlanService.submit`` runs this part inline (so tickets carry real
    signatures and errors surface synchronously) and hands the prepared
    request to a worker for the expensive half.
    """

    request: PlanRequest
    mem: MemorySpec
    groups: List[AccessGroup]
    iterators: Dict[str, Iterator]
    opts: SolverOptions
    scorer_spec: ScorerLike
    scorer_name: str
    signature: str
    family: str

    @property
    def memory(self) -> str:
        return self.request.memory


class BankingPlanner:
    """Plan-oriented entry point: signature-keyed cache over the solver.

    Parameters
    ----------
    opts : default ``SolverOptions`` for requests that don't carry their own
    scorer : default scorer spec (registry name or callable)
    cache_dir : sugar for ``store=DirectoryStore(cache_dir)`` -- the legacy
        directory-of-JSON-plans layout, now shareable across processes
    store : a ``PlanStore`` consulted on in-memory misses; solved plans and
        compiled artifacts are persisted there
    max_workers : thread-pool width for ``plan_all`` and the inline service
    """

    def __init__(self, *, opts: Optional[SolverOptions] = None,
                 scorer: ScorerLike = "proxy",
                 cache_dir: Optional[Union[str, Path]] = None,
                 store: Optional[Union[PlanStore, str, Path]] = None,
                 max_workers: Optional[int] = None):
        from .store import DirectoryStore

        self.opts = opts or SolverOptions()
        self.scorer = scorer
        self.store = as_store(store)
        if self.store is None and cache_dir is not None:
            self.store = DirectoryStore(cache_dir)
        # legacy attribute: the directory plans persist in, when any
        self.cache_dir = (self.store.path
                          if isinstance(self.store, DirectoryStore) else
                          (Path(cache_dir) if cache_dir is not None else None))
        self.max_workers = max_workers
        self.stats = PlannerStats()
        self._cache: Dict[str, BankingPlan] = {}
        self._compiled: Dict[str, CompiledBankingPlan] = {}
        # strong refs to callable scorers keyed by their cache name: keeps
        # the id() embedded in the key unique for the cache's lifetime
        # (a GC'd lambda's address could otherwise be reused by a new one)
        self._scorer_pins: Dict[str, object] = {}
        self._lock = threading.Lock()
        self._service = None
        # measured-cost hub (see PlanService.enable_telemetry): when set,
        # every artifact compile() hands out is instrumented for timing
        self.telemetry = None
        if self.cache_dir is not None:
            # trained "ml" pipelines persist next to the plan cache.
            # First planner with a cache_dir wins: a later throwaway
            # planner must not silently redirect where the process-wide
            # scorer persists (set_ml_scorer_path overrides explicitly).
            with _ML_TRAIN_LOCK:
                global _ML_SCORER_PATH
                if _ML_SCORER_PATH is None:
                    _ML_SCORER_PATH = self.cache_dir / "ml_scorer.json"

    # -- the inline service ----------------------------------------------------
    @property
    def service(self):
        """The planner's inline :class:`PlanService` -- ``plan()`` is a
        thin ``service.submit(...).result()`` so the blocking and async
        front doors share one prepare -> lookup -> solve code path."""
        if self._service is None:
            from .service import PlanService
            # constructing a service claims the planner's slot under the
            # planner lock (first one wins; a racing loser is discarded)
            PlanService(planner=self, workers=self.max_workers or 8)
        return self._service

    # -- cache plumbing ------------------------------------------------------
    def _cache_key(self, signature: str, scorer_name: str) -> str:
        return f"{signature}/{scorer_name}"

    def _adopt(self, plan: BankingPlan) -> BankingPlan:
        """Attach the planner backref so plan.compile() hits our caches."""
        plan._planner = self
        return plan

    def _hit_copy(self, hit: BankingPlan, memory: str,
                  status: str) -> BankingPlan:
        """Cache-hit view: own lists (so caller mutations can't poison the
        cache) relabeled for the requesting memory.  Signatures are
        structural, so the underlying solutions may carry the name of the
        memory that first posed this problem."""
        out = copy.copy(hit)
        out.status = status
        out.memory = memory
        out.solutions = list(hit.solutions)
        out.groups = list(hit.groups)
        return self._adopt(out)

    def warm_start(self, source: Union[str, Path, PlanStore]) -> int:
        """Preload plans -- and their compiled artifacts -- from a store,
        a directory, or a single JSON file into the in-memory caches.
        Returns the number of plans + artifacts loaded; a warm-started
        planner skips both re-solving and re-lowering."""
        if not isinstance(source, PlanStore):
            path = Path(source)
            if not path.is_dir():
                if path.name.endswith(".compiled.json"):
                    try:
                        art = CompiledBankingPlan.load(path)
                    except (ValueError, KeyError, json.JSONDecodeError,
                            OSError):
                        return 0
                    with self._lock:
                        self._compiled[self._compile_key(
                            art.signature, art.scorer_name,
                            art.backend)] = art
                    return 1
                try:
                    plan = BankingPlan.load(path)
                except (ValueError, KeyError, json.JSONDecodeError, OSError):
                    return 0
                with self._lock:
                    self._cache[self._cache_key(plan.signature,
                                                plan.scorer_name)] = plan
                return 1
            source = as_store(path)
        n = 0
        for plan in source.plans():
            with self._lock:
                self._cache[self._cache_key(plan.signature,
                                            plan.scorer_name)] = plan
            n += 1
        for art in source.artifacts():
            with self._lock:
                self._compiled[self._compile_key(
                    art.signature, art.scorer_name, art.backend)] = art
            n += 1
        return n

    def clear_cache(self) -> None:
        with self._lock:
            self._cache.clear()
            self._compiled.clear()
            self._scorer_pins.clear()

    # -- compilation ---------------------------------------------------------
    def _compile_key(self, signature: str, scorer_name: str,
                     backend: str) -> str:
        return f"{signature}/{scorer_name}/{backend}"

    def compile(self, plan: BankingPlan,
                backend: str = "jax") -> CompiledBankingPlan:
        """Lower ``plan`` to a CompiledBankingPlan through the compile
        cache.

        Artifacts are keyed by (plan signature, scorer, backend) and
        persist in the plan store (for a ``DirectoryStore``, as
        ``<sig>.<scorer>.<backend>.compiled.json`` beside the JSON plans),
        so a warm-started planner skips re-lowering the resolution
        circuits as well as re-solving."""
        key = self._compile_key(plan.signature, plan.scorer_name, backend)
        with self._lock:
            hit = self._compiled.get(key)
        if hit is not None:
            self.stats.compile_hits += 1
            return self._maybe_instrument(hit)
        if self.store is not None:
            art = self.store.get_artifact(plan.signature, plan.scorer_name,
                                          backend)
            if art is not None:
                with self._lock:
                    self._compiled[key] = art
                self.stats.compile_disk_hits += 1
                return self._maybe_instrument(art)
        art = compile_plan(plan, backend=backend)
        art.scorer_name = plan.scorer_name
        self.stats.compiles += 1
        with self._lock:
            self._compiled[key] = art
        if self.store is not None:
            self.store.put_artifact(art)
        return self._maybe_instrument(art)

    def _maybe_instrument(self, art: CompiledBankingPlan
                          ) -> CompiledBankingPlan:
        """Attach the telemetry hub's timing sink to an artifact we hand
        out (no-op without an enabled hub)."""
        if self.telemetry is not None:
            self.telemetry.instrument(art)
        return art

    def evict(self, signature: str, scorer_name: str) -> None:
        """Forget a (signature, scorer) plan everywhere we cache it: the
        in-memory plan cache, the compile cache, and the plan store --
        demotion's eviction of a measured loser.  The next submit for the
        signature cold-solves."""
        with self._lock:
            self._cache.pop(self._cache_key(signature, scorer_name), None)
            prefix = f"{signature}/{scorer_name}/"
            for key in [k for k in self._compiled
                        if k.startswith(prefix)]:
                self._compiled.pop(key, None)
        if self.store is not None:
            self.store.delete(signature, scorer_name)

    # -- planning ------------------------------------------------------------
    def signature(self, program: Program, memory: str,
                  opts: Optional[SolverOptions] = None) -> str:
        return program_signature(program, memory, opts or self.opts)

    def prepare(self, request: Union[PlanRequest, Program],
                memory: Optional[str] = None, *,
                opts: Optional[SolverOptions] = None,
                scorer: ScorerLike = None,
                use_cache: bool = True) -> PreparedRequest:
        """The cheap synchronous half of planning: normalize the request,
        unroll + group the program, and compute signatures.  Raises for
        unknown memories and unregistered scorers -- submit-time errors
        must surface to the caller, not inside a worker thread."""
        if isinstance(request, PlanRequest):
            req = request
        else:
            if memory is None:
                raise TypeError("plan(program, memory) requires a memory name")
            req = PlanRequest(program=request, memory=memory, opts=opts,
                              scorer=scorer, use_cache=use_cache)
        opts = req.opts or self.opts
        spec = req.scorer if req.scorer is not None else self.scorer
        # key only; the factory (e.g. "ml" lazy training) runs on miss
        scorer_name = scorer_key(spec)
        if callable(spec):
            with self._lock:
                self._scorer_pins[scorer_name] = spec
        up = unroll(req.program)
        groups = build_groups(up, req.memory)
        mem = req.program.memories[req.memory]
        return PreparedRequest(
            request=req, mem=mem, groups=groups, iterators=up.iterators,
            opts=opts, scorer_spec=spec, scorer_name=scorer_name,
            signature=canonical_signature(mem, groups, up.iterators, opts),
            family=family_signature(mem, groups, up.iterators),
        )

    def lookup(self, prep: PreparedRequest) -> Optional[BankingPlan]:
        """Cache probe for a prepared request: the in-memory cache first,
        then the plan store.  Returns a relabeled hit copy or ``None``."""
        key = self._cache_key(prep.signature, prep.scorer_name)
        with self._lock:
            hit = self._cache.get(key)
        if hit is not None:
            self.stats.hits += 1
            return self._hit_copy(hit, prep.memory, "cached")
        if self.store is not None:
            plan = self.store.get(prep.signature, prep.scorer_name)
            if plan is not None:
                with self._lock:
                    self._cache[key] = plan
                self.stats.disk_hits += 1
                return self._hit_copy(plan, prep.memory, "cached-disk")
        return None

    def build_space(self, prep: PreparedRequest):
        """Enumerate the pruned candidate space for a prepared request.

        The single cold-solve chokepoint: the service's sharded workers,
        the blocking ``plan()``, and direct ``solve_prepared`` calls all
        start a solve here -- one place to instrument (or gate, in
        tests) every path that is about to do solver work.
        """
        from .candidates import CandidateSpace

        return CandidateSpace(prep.mem, prep.groups, prep.iterators,
                              prep.opts)

    def complete_solve(self, prep: PreparedRequest, solutions:
                       List[BankingSolution], solve_seconds: float,
                       scorer_fn: Optional[Callable] = None,
                       verify: Optional[Callable] = None
                       ) -> BankingPlan:
        """Rank merged solutions, build the plan, cache, persist.

        The back half of every solve: the sharded service reducer and
        the in-thread ``solve_prepared`` both end here.  ``verify`` is
        the service's certify-before-cache hook: called with
        ``(plan, prep)`` before anything is cached or persisted, and a
        raise (``repro.analysis.CertificationError``) aborts both --
        an uncertified scheme never enters the cache or the store."""
        if scorer_fn is None:
            _, scorer_fn = resolve_scorer(prep.scorer_spec)
        ranked = rank_solutions(solutions, scorer_fn)
        self.stats.solves += 1
        plan = BankingPlan(
            memory=prep.memory,
            signature=prep.signature,
            best=ranked[0] if ranked else None,
            solve_seconds=solve_seconds,
            num_candidates=len(solutions),
            scorer_name=prep.scorer_name,
            status="solved",
            created_at=time.time(),
            opts=prep.opts,
            solutions=ranked,
            groups=prep.groups,
            family=prep.family,
        )
        if verify is not None:
            verify(plan, prep)
        with self._lock:
            self._cache[self._cache_key(prep.signature,
                                        prep.scorer_name)] = plan
        if self.store is not None:
            self.store.put(plan)
        return self._adopt(plan)

    def solve_prepared(self, prep: PreparedRequest) -> BankingPlan:
        """The expensive half, in-thread: enumerate -> evaluate (one
        shard) -> reduce -> rank -> persist."""
        from .candidates import solve_space

        self.stats.misses += 1
        _, scorer_fn = resolve_scorer(prep.scorer_spec)
        t0 = time.perf_counter()
        space = self.build_space(prep)
        sols = solve_space(space, scorer=scorer_fn)
        dt = time.perf_counter() - t0
        return self.complete_solve(prep, sols, dt, scorer_fn)

    def plan_prepared(self, prep: PreparedRequest) -> BankingPlan:
        """lookup-or-solve for an already-prepared request (worker path)."""
        if prep.request.use_cache:
            hit = self.lookup(prep)
            if hit is not None:
                return hit
        return self.solve_prepared(prep)

    def find_family(self, family: str, *,
                    exclude_signature: str = "") -> Optional[BankingPlan]:
        """Newest known plan of the same problem family (any solver
        options): in-memory cache first, then the store.  This is the
        near-match feeding stale-while-revalidate submits."""
        if not family:
            return None
        with self._lock:
            cands = [p for p in self._cache.values()
                     if p.family == family and p.best is not None
                     and p.signature != exclude_signature]
        if cands:
            return max(cands, key=lambda p: p.created_at)
        if self.store is not None:
            return self.store.find_family(
                family, exclude_signature=exclude_signature)
        return None

    def plan(self, request: Union[PlanRequest, Program],
             memory: Optional[str] = None, *,
             opts: Optional[SolverOptions] = None,
             scorer: ScorerLike = None,
             use_cache: bool = True) -> BankingPlan:
        """Plan one memory: cache hit or unroll->group->solve->rank.

        A thin ``submit(...).result()`` over the inline service: cache
        hits resolve synchronously inside ``submit``; misses run on the
        service's worker pool while this thread blocks on the ticket."""
        prep = self.prepare(request, memory, opts=opts, scorer=scorer,
                            use_cache=use_cache)
        return self.service.submit_prepared(prep).result()

    def plan_all(self, program: Program, *,
                 opts: Optional[SolverOptions] = None,
                 scorer: ScorerLike = None,
                 timeout: Optional[float] = None,
                 max_workers: Optional[int] = None,
                 budget=None) -> Dict[str, BankingPlan]:
        """Plan every memory of ``program`` concurrently.

        Rides the service's joint ticket graph: one
        :meth:`PlanService.submit_joint` fans the member solves across
        the service's own worker pool (or fabric).  ``budget=None``
        keeps the historical independent selection -- each memory's plan
        carries its own argmin scheme.  With a
        :class:`~repro.core.jointplan.ResourceBudget`, each returned
        plan's ``best`` is instead the **jointly co-selected** scheme
        for that memory (possibly a cheaper point off its Pareto
        frontier, or the trivial single-bank fallback under pressure);
        the full :class:`~repro.core.jointplan.JointPlan` is available
        via ``submit_joint`` directly.

        Each memory gets its own ``timeout`` budget (measured from when
        its result is collected, so memories queued behind a full pool
        are not charged for earlier solves); a memory that exceeds it
        yields a plan with ``status='timeout'`` and ``best=None`` (its
        solve keeps running in the background and will populate the
        cache for the next request).  ``max_workers`` is accepted for
        compatibility; the service pool sizes the fan-out.
        """
        del max_workers   # the service's worker pool drains the graph
        names = list(program.memories)
        out: Dict[str, BankingPlan] = {}
        try:
            joint = self.service.submit_joint(program, opts=opts,
                                              scorer=scorer, budget=budget)
        except Exception as e:   # prepare-time refusal: honest per-memory
            return {name: BankingPlan(
                memory=name, signature="", best=None,
                status="error", created_at=time.time(),
                opts=opts or self.opts, error=repr(e)) for name in names}
        for name in names:
            ticket = joint.members.get(name)
            if ticket is None:   # store-answered joint: members in plan
                continue
            try:
                out[name] = ticket.result(timeout=timeout)
            except TimeoutError:
                out[name] = BankingPlan(
                    memory=name, signature="", best=None,
                    status="timeout", created_at=time.time(),
                    opts=opts or self.opts,
                    error=f"exceeded {timeout}s budget")
            except Exception as e:  # solver bug: report, don't kill batch
                out[name] = BankingPlan(
                    memory=name, signature="", best=None,
                    status="error", created_at=time.time(),
                    opts=opts or self.opts, error=repr(e))
        if budget is not None or not out:
            # co-selected schemes replace the independent argmins; a
            # member that timed out here keeps its honest timeout plan
            # (the joint selection holds its trivial stand-in)
            if joint.wait(timeout=timeout):
                jplan = joint.result()
                for name, m in jplan.members.items():
                    p = out.get(name)
                    if p is None:
                        out[name] = BankingPlan(
                            memory=name, signature=m.signature,
                            best=m.chosen, status=jplan.status,
                            scorer_name=jplan.scorer_name,
                            created_at=jplan.created_at,
                            opts=opts or self.opts, error=m.error)
                    elif budget is not None \
                            and p.status not in ("timeout", "error") \
                            and m.chosen is not None:
                        out[name] = replace(p, best=m.chosen)
        return out


# ---------------------------------------------------------------------------
# Process-wide default planner (shims, serving, sharding)
# ---------------------------------------------------------------------------

_DEFAULT_PLANNER: Optional[BankingPlanner] = None
_DEFAULT_LOCK = threading.Lock()


def default_planner() -> BankingPlanner:
    """The shared in-memory-cached planner used by the default service,
    the serving hot path, and the sharding bridge."""
    global _DEFAULT_PLANNER
    with _DEFAULT_LOCK:
        if _DEFAULT_PLANNER is None:
            _DEFAULT_PLANNER = BankingPlanner()
        return _DEFAULT_PLANNER


__all__ = [
    "BankingPlan",
    "BankingPlanner",
    "CompiledBankingPlan",
    "PlanRequest",
    "PlannerStats",
    "PreparedRequest",
    "canonical_signature",
    "compile_plan",
    "default_planner",
    "family_signature",
    "program_signature",
    "rank_solutions",
    "register_scorer",
    "registered_scorers",
    "resolve_scorer",
    "set_ml_scorer_path",
]
