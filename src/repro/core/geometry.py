"""Hyperplane banking geometries (paper Sec 2.2, Table 1, Eqs 1-2).

A *flat* geometry banks the whole array with one hyperplane family:

    BA = floor((x . alpha) / B) mod N                                   (Eq 1)
    BO = B * sum_i( floor(x_i / P_i) * prod_{j>i} ceil(D_j / P_j) )
         + (x . alpha mod B)                                            (Eq 2)

A *multidimensional* geometry (Sec 3.3) banks each array dimension with its
own 1-D hyperplane geometry over the access projections; this captures the
orthogonal-parallelotope subset of lattice partitioning.  BA is then a vector
(one per dimension) and BO remains a scalar intra-bank offset.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .polytope import (
    Access,
    Affine,
    Iterator,
    MemorySpec,
    delta_can_hit_window,
)

# ---------------------------------------------------------------------------
# Geometry containers
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FlatGeometry:
    """(N, B, alpha, P) for a flat hyperplane scheme."""

    N: int
    B: int
    alpha: Tuple[int, ...]
    P: Tuple[int, ...]  # partition parallelotope (orthotope side lengths)

    @property
    def num_banks(self) -> int:
        return self.N

    def bank_address(self, x: Sequence[int]) -> int:
        y = int(np.dot(np.asarray(x, dtype=np.int64), np.asarray(self.alpha)))
        return (y // self.B) % self.N

    def bank_offset(self, x: Sequence[int], dims: Sequence[int]) -> int:
        acc = 0
        for i in range(len(dims)):
            stride = 1
            for j in range(i + 1, len(dims)):
                stride *= -(-dims[j] // self.P[j])  # ceil
            acc += (int(x[i]) // self.P[i]) * stride
        y = int(np.dot(np.asarray(x, dtype=np.int64), np.asarray(self.alpha)))
        return self.B * acc + (y % self.B)

    def bank_volume(self, dims: Sequence[int]) -> int:
        vol = self.B
        for j in range(len(dims)):
            vol *= -(-dims[j] // self.P[j])
        return vol


@dataclass(frozen=True)
class MultiDimGeometry:
    """Per-dimension 1-D hyperplane geometries (orthogonal lattice subset)."""

    Ns: Tuple[int, ...]
    Bs: Tuple[int, ...]
    alphas: Tuple[int, ...]  # scalar alpha per dimension

    @property
    def num_banks(self) -> int:
        return int(np.prod(self.Ns))

    def bank_address(self, x: Sequence[int]) -> Tuple[int, ...]:
        return tuple(
            ((int(xi) * a) // b) % n
            for xi, a, b, n in zip(x, self.alphas, self.Bs, self.Ns)
        )

    def bank_offset(self, x: Sequence[int], dims: Sequence[int]) -> int:
        # intra-bank offset: row-major over per-dim intra-bank coordinates
        coords = []
        sizes = []
        for xi, a, b, n, d in zip(x, self.alphas, self.Bs, self.Ns, dims):
            y = int(xi) * a
            block = y // (b * n)  # which repetition of the N-bank period
            within = y % b        # position inside the blocking factor
            blocks = -(-d * a // b)            # total B-blocks along this dim
            per_bank = -(-blocks // n)         # blocks landing in each bank
            coords.append(block * b + within)
            sizes.append(per_bank * b)
        off = 0
        for c, s in zip(coords, sizes):
            off = off * s + c
        return off

    def bank_volume(self, dims: Sequence[int]) -> int:
        vol = 1
        for a, b, n, d in zip(self.alphas, self.Bs, self.Ns, dims):
            blocks = -(-d * a // b)
            per_bank = -(-blocks // n)
            vol *= per_bank * b
        return vol


Geometry = "FlatGeometry | MultiDimGeometry"


# ---------------------------------------------------------------------------
# Validity (Def 2.9) -- conflict graph + clique bound
# ---------------------------------------------------------------------------


def _pair_delta(a: Access, b: Access, alpha: Sequence[int]) -> Affine:
    return a.dot(alpha) - b.dot(alpha)


def _dim_delta(a: Access, b: Access, dim: int, alpha_d: int) -> Affine:
    return a.exprs[dim].scale(alpha_d) - b.exprs[dim].scale(alpha_d)


def _max_conflict_clique(n_nodes: int, edges: set) -> int:
    """Size of the largest clique in the pairwise-conflict graph.

    Groups are small (tens of accesses); greedy + exact fallback via
    networkx when the greedy bound straddles the port limit.
    """
    if not edges:
        return 1
    adj: Dict[int, set] = {i: set() for i in range(n_nodes)}
    for u, v in edges:
        adj[u].add(v)
        adj[v].add(u)
    # Greedy lower bound
    best = 2
    order = sorted(adj, key=lambda u: -len(adj[u]))
    for u in order[: min(len(order), 16)]:
        clique = {u}
        for v in sorted(adj[u], key=lambda w: -len(adj[w])):
            if all(v in adj[c] for c in clique):
                clique.add(v)
        best = max(best, len(clique))
    return best


class ConflictCache:
    """Memoizes residue analyses keyed by canonical delta signature.

    Lanes of a vectorized access differ only in constants, so the same
    symbolic delta recurs across many pairs; caching makes the candidate
    sweep cheap (the paper's 'quickly identify valid schemes').

    Also memoizes the *pair deltas themselves*: for a fixed access pair
    and alpha, the symbolic delta is independent of (N, B), so the affine
    arithmetic runs once per (pair, alpha) instead of once per candidate
    geometry -- the dominant cost of a cold candidate sweep.

    One cache may be shared by every shard of a candidate-space solve:
    entries are pure functions of their keys, so racing threads at worst
    recompute a value (dict reads/writes are individually atomic).
    """

    def __init__(self, iters: Dict[str, Iterator]):
        self.iters = iters
        self._memo: Dict[Tuple, bool] = {}
        self._deltas: Dict[Tuple, Affine] = {}
        # pin delta-key accesses: keys embed id(), which must stay unique
        self._pins: Dict[int, Access] = {}

    def conflicts(self, delta: Affine, N: int, B: int) -> bool:
        key = (delta.terms, delta.syms, delta.const % (N * B), N, B)
        hit = self._memo.get(key)
        if hit is None:
            hit = delta_can_hit_window(delta, self.iters, N, B)
            self._memo[key] = hit
        return hit

    def pair_delta(self, a: Access, b: Access,
                   alpha: Tuple[int, ...]) -> Affine:
        key = (id(a), id(b), alpha)
        d = self._deltas.get(key)
        if d is None:
            d = _pair_delta(a, b, alpha)
            self._deltas[key] = d
            self._pins[id(a)] = a
            self._pins[id(b)] = b
        return d

    def dim_delta(self, a: Access, b: Access, dim: int,
                  alpha_d: int) -> Affine:
        key = (id(a), id(b), dim, alpha_d)
        d = self._deltas.get(key)
        if d is None:
            d = _dim_delta(a, b, dim, alpha_d)
            self._deltas[key] = d
            self._pins[id(a)] = a
            self._pins[id(b)] = b
        return d


def flat_conflict_edges(
    group: Sequence[Access],
    geo: FlatGeometry,
    cache: ConflictCache,
) -> set:
    edges = set()
    for i, j in itertools.combinations(range(len(group)), 2):
        d = cache.pair_delta(group[i], group[j], geo.alpha)
        if cache.conflicts(d, geo.N, geo.B):
            edges.add((i, j))
    return edges


def flat_is_valid(
    group: Sequence[Access],
    geo: FlatGeometry,
    cache: ConflictCache,
    ports: int,
) -> bool:
    """Def 2.9: no >ports accesses may simultaneously resolve to one bank."""
    edges = flat_conflict_edges(group, geo, cache)
    return _max_conflict_clique(len(group), edges) <= ports


def multidim_conflict_edges(
    group: Sequence[Access],
    geo: MultiDimGeometry,
    cache: ConflictCache,
) -> set:
    """A pair conflicts only if it conflicts on EVERY dimension (the paper's
    'regrouping': guaranteed-different BA on one dim rules the pair out)."""
    edges = set()
    for i, j in itertools.combinations(range(len(group)), 2):
        all_dims = True
        for d in range(len(geo.Ns)):
            delta = cache.dim_delta(group[i], group[j], d, geo.alphas[d])
            if not cache.conflicts(delta, geo.Ns[d], geo.Bs[d]):
                all_dims = False
                break
        if all_dims:
            edges.add((i, j))
    return edges


def multidim_is_valid(
    group: Sequence[Access],
    geo: MultiDimGeometry,
    cache: ConflictCache,
    ports: int,
) -> bool:
    edges = multidim_conflict_edges(group, geo, cache)
    return _max_conflict_clique(len(group), edges) <= ports


# ---------------------------------------------------------------------------
# Partition parallelotope P + padding (Table 1: delta) for flat geometries
# ---------------------------------------------------------------------------


def propose_P(mem: MemorySpec, N: int, B: int, alpha: Sequence[int]) -> List[Tuple[int, ...]]:
    """Candidate P orthotopes for a flat geometry.

    P must tile a region in which every BA appears >=1 and <=B times
    (Sec 2.2).  We propose concentrating the N*B period along each dimension
    with nonzero alpha and verify by enumeration of one period region.
    """
    n = mem.n
    out: List[Tuple[int, ...]] = []
    period = N * B
    for d in range(n):
        if alpha[d] == 0:
            continue
        a = abs(alpha[d])
        span = period // math.gcd(period, a)
        P = [1] * n
        P[d] = max(1, span)
        if P[d] <= mem.dims[d] * 2:
            out.append(tuple(P))
    if not out:
        out.append(tuple([1] * n))
    # verify each candidate; keep those covering every bank <= B times
    ok = []
    for P in out:
        if _verify_P(mem, N, B, alpha, P):
            ok.append(P)
    return ok or [_fallback_P(mem, N, B, alpha)]


def _verify_P(mem: MemorySpec, N: int, B: int, alpha, P) -> bool:
    region = [min(p, d) for p, d in zip(P, mem.dims)]
    if int(np.prod(region)) > 65536:
        return False
    counts = np.zeros(N, dtype=np.int64)
    for x in itertools.product(*[range(r) for r in region]):
        y = sum(xi * a for xi, a in zip(x, alpha))
        counts[(y // B) % N] += 1
    return bool((counts >= 1).all() and (counts <= B).all())


def _fallback_P(mem: MemorySpec, N: int, B: int, alpha) -> Tuple[int, ...]:
    # Degenerate but always-correct: one element per P-cell along dim with
    # largest |alpha| spanning the whole dimension (bank volume = whole array
    # over N after padding).  Used only when no structured P verifies.
    n = mem.n
    d = int(np.argmax([abs(a) for a in alpha])) if any(alpha) else 0
    P = [1] * n
    P[d] = mem.dims[d]
    return tuple(P)


def padding(mem: MemorySpec, P: Sequence[int]) -> Tuple[int, ...]:
    """Per-dimension pad so P tiles the (padded) array exactly."""
    return tuple((-d) % p for d, p in zip(mem.dims, P))


# ---------------------------------------------------------------------------
# Metrics: fan-out / fan-in (Table 1)
# ---------------------------------------------------------------------------


def _sample_iters(iters: Dict[str, Iterator], n_samples: int, seed: int) -> List[Dict[str, int]]:
    rng = np.random.default_rng(seed)
    envs = []
    for _ in range(n_samples):
        env = {}
        for name, it in iters.items():
            cnt = it.count if it.count is not None else 64
            t = int(rng.integers(0, max(cnt, 1)))
            env[name] = it.start + it.step * t
        envs.append(env)
    return envs


def fan_out(
    access: Access,
    geo,
    dims: Sequence[int],
    iters: Dict[str, Iterator],
    sym_env: Optional[Dict[str, int]] = None,
    n_samples: int = 128,
) -> int:
    """FO_a: number of distinct banks an access can touch (sampled exactly
    for bounded iterator spaces, statistically otherwise)."""
    names = set(access.dot(getattr(geo, "alpha", tuple([1] * len(dims)))).iterator_names)
    for e in access.exprs:
        names.update(e.iterator_names)
    bounded = all(
        iters.get(nm) is not None and iters[nm].count is not None and iters[nm].count <= 64
        for nm in names
    )
    banks = set()
    sym_env = dict(sym_env or {})
    for e in access.exprs:
        for k, _ in e.syms:
            sym_env.setdefault(k, 0)
    if bounded and names:
        spaces = [iters[nm].values(64) for nm in names]
        total = int(np.prod([len(s) for s in spaces]))
        if total <= 4096:
            for combo in itertools.product(*spaces):
                env = dict(zip(names, (int(c) for c in combo)))
                env.update(sym_env)
                x = [e.evaluate(env) for e in access.exprs]
                banks.add(geo.bank_address(x))
            return len(banks)
    for env in _sample_iters(iters, n_samples, seed=0xB4):
        env = dict(env)
        env.update(sym_env)
        x = [e.evaluate(env) for e in access.exprs]
        banks.add(geo.bank_address(x))
    return len(banks)


def fan_ins(
    group: Sequence[Access],
    geo,
    dims: Sequence[int],
    iters: Dict[str, Iterator],
) -> Dict:
    """FI_b per bank, sampled: how many accesses can feed each bank."""
    fi: Dict = {}
    for acc in group:
        sym_env = {}
        for e in acc.exprs:
            for k, _ in e.syms:
                sym_env.setdefault(k, 0)
        touched = set()
        for env in _sample_iters(iters, 64, seed=0x5EED):
            env = dict(env)
            env.update(sym_env)
            x = [e.evaluate(env) for e in acc.exprs]
            touched.add(geo.bank_address(x))
        for b in touched:
            fi[b] = fi.get(b, 0) + 1
    return fi
