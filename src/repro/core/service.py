"""PlanService: the asynchronous front door of the banking system.

The paper's pitch is that partitioning is *fast enough to sit inside a
compiler loop* -- but "fast" still means hundreds of milliseconds for a
cold solve, which is an eternity on a serving hot path.  Every consumer
used to eat that cost inline by calling ``BankingPlanner.plan()``.  This
module turns the front door into **submit -> ticket -> compile ->
execute**:

* :meth:`PlanService.submit` runs only the cheap half of planning inline
  (unroll + grouping + signatures + cache probe) and returns a
  :class:`PlanTicket`.  Warm caches and warm :class:`~repro.core.store`
  stores resolve the ticket *before* it is returned -- zero solver work,
  no thread hop.
* Misses are queued (priority-ordered) and drained by a small daemon
  worker pool into the shared :class:`BankingPlanner` -- one code path
  for sync and async planning; ``BankingPlanner.plan`` is itself
  ``service.submit_prepared(...).result()``.
* ``ticket.fallback()`` returns an *immediately usable* compiled artifact
  (the trivial single-bank scheme, or a stored same-family near-match)
  so a caller can pack tables and serve traffic NOW and atomically
  hot-swap to ``ticket.artifact()`` when the solve lands -- the pattern
  ``runtime/server.py`` uses between decode ticks.
* :class:`StaleWhileRevalidate`: when a submit's canonical signature
  misses but the store holds a plan of the same problem *family* (same
  memory + access polytopes, drifted solver options), the ticket serves
  that near-match as its provisional artifact while the exact solve runs
  speculatively in the background.
* Cold solves are **sharded**: the claiming worker enumerates the
  problem's :class:`~repro.core.candidates.CandidateSpace`, splits it
  into up to ``shard_budget`` :class:`SolveShard` s, and fans them back
  across this same worker pool.  A
  :class:`~repro.core.candidates.SolutionReducer` merges the shard
  streams; ``ticket.best_so_far()`` exposes its ranked best
  incrementally, so consumers (the serving runtime's hot swap) can
  promote to the current best scheme *before* the full search drains --
  and ``ticket.result()`` still returns exactly the scheme the
  monolithic search would have chosen.  With no explicit
  ``shard_budget`` the fan-out is sized **adaptively** from the
  enumerated space, so small problems skip fan-out overhead.
* The shard executor is **selectable** (``executor="pool" | "fabric"``,
  per-service or per-ticket): ``"fabric"`` drives the same work
  units over a :class:`~repro.core.fabric.SolveFabric` of remote
  worker processes -- one reducer, many hosts -- with the reducer's cut
  bounds broadcast live so remote shards prune like local ones.  A
  fabric with no attached workers falls back to the pool.

Tickets deduplicate in-flight work: two submits of the same
(signature, scorer) share one solve.

The front door is **multi-tenant** (:mod:`repro.runtime.tenancy`):
``PlanService(tenants=TenantRegistry(...))`` + ``submit(...,
tenant="name")`` gives each consumer a QoS class (priority band,
fair-share weight, in-flight/deferral quotas, shard and fabric-lease
caps), an :class:`~repro.runtime.tenancy.AdmissionController` that
defers -- honestly, fallback still served -- or sheds over-quota cold
solves, weighted fair-share queue draining so a noisy tenant cannot
starve the rest, and an exact per-tenant stats slice
(``stats.for_tenant(name)``).
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from ..runtime.tenancy import (
    DEFAULT_TENANT,
    AdmissionController,
    AdmissionError,
    FairShareQueue,
    TenantRegistry,
)
from .artifact import CompiledBankingPlan, compile_solution, compile_trivial
from .candidates import SolutionReducer, SolveShard, evaluate
from .planner import (
    BankingPlan,
    BankingPlanner,
    PlanRequest,
    PreparedRequest,
    ScorerLike,
    default_planner,
    resolve_scorer,
)
from .jointplan import (
    FrontierPoint,
    JointMember,
    JointPlan,
    JointRequest,
    JointSelection,
    ResourceBudget,
    co_select,
    joint_signature,
    pareto_frontier,
    trivial_solution,
)
from .polytope import MemorySpec
from .solver import BankingSolution, SolverOptions
from .store import PlanStore, as_store
from .tracing import NULL_SPAN, new_trace_id


@dataclass
class StaleWhileRevalidate:
    """Policy for answering submits from a stored near-match.

    ``enabled``: serve a same-family plan (same memory + access structure,
    drifted solver options/scorer) as the ticket's provisional artifact
    while the exact solve runs in the background.
    ``max_age``: ignore near-matches older than this many seconds
    (``None`` = any age).
    """

    enabled: bool = True
    max_age: Optional[float] = None

    def pick(self, planner: BankingPlanner,
             prep: PreparedRequest) -> Optional[BankingPlan]:
        if not self.enabled:
            return None
        plan = planner.find_family(prep.family,
                                   exclude_signature=prep.signature)
        if plan is None:
            return None
        if (self.max_age is not None
                and time.time() - plan.created_at > self.max_age):
            return None
        return plan


class PlanTicket:
    """Future-like handle for one submitted banking problem.

    States: ``queued`` -> ``solving`` -> ``done`` | ``error``; a ticket
    answered synchronously (cache/store hit) is born ``done``; one with a
    stale near-match attached is ``revalidating`` until its exact solve
    lands.  ``fallback()`` always returns immediately with an executable
    artifact -- the stored near-match when one exists, else the trivial
    single-bank scheme -- so callers can execute *now* and hot-swap when
    ``done()`` flips.
    """

    def __init__(self, *, service: "PlanService", prep: PreparedRequest,
                 priority: int = 0, shard_budget: Optional[int] = None,
                 executor: Optional[str] = None, verify: str = "off",
                 tenant: str = DEFAULT_TENANT):
        self._service = service
        self._prep = prep
        self.memory = prep.memory
        self.signature = prep.signature
        self.family = prep.family
        self.scorer_name = prep.scorer_name
        self.priority = priority
        self.shard_budget = shard_budget
        self.executor = executor     # None = the service default
        self.verify = verify         # resolved verification mode
        self.tenant = tenant         # resolved tenant name
        self.deferred = False        # parked by admission control
        self.submitted_at = time.time()
        self.resolved_at: Optional[float] = None
        self.status = "queued"
        # observability: the per-ticket trace (None when tracing is
        # off) and the honest latency attribution satellites --
        # queue_ms / deferred_ms accumulate wall time the ticket spent
        # waiting for a worker / parked by admission, measured from
        # monotonic timestamps whether or not spans record them
        self.trace_id: Optional[str] = None
        self._root_span = None
        self.queue_ms = 0.0
        self.deferred_ms = 0.0
        self._queued_at: Optional[float] = None
        self._deferred_at: Optional[float] = None
        self._admitted = False       # holds one admission in-flight slot
        self._event = threading.Event()
        self._plan: Optional[BankingPlan] = None
        self._error: Optional[BaseException] = None
        self._stale: Optional[BankingPlan] = None
        self._fallbacks: Dict[str, CompiledBankingPlan] = {}
        self._reducer: Optional[SolutionReducer] = None
        self._best_arts: Dict[Tuple[int, str], CompiledBankingPlan] = {}
        self._final_version = 0
        self._claimed = False
        self._callbacks: List[Callable[["PlanTicket"], None]] = []
        self._lock = threading.Lock()

    # -- completion ------------------------------------------------------------
    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)

    def result(self, timeout: Optional[float] = None) -> BankingPlan:
        """The solved plan; blocks up to ``timeout`` seconds.  Raises
        ``TimeoutError`` on expiry and re-raises solver exceptions."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"plan {self.signature} not solved within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._plan

    def artifact(self, timeout: Optional[float] = None,
                 backend: str = "jax") -> CompiledBankingPlan:
        """The *solved* compiled artifact (blocks like ``result``)."""
        return self._service.planner.compile(self.result(timeout),
                                             backend=backend)

    # -- progressive results -------------------------------------------------------
    def best_so_far(self) -> Optional[BankingSolution]:
        """The best-ranked scheme the sharded search has admitted so far.

        ``None`` until the first valid candidate lands; never regresses
        in score as shards stream in; equal to ``result().best`` once
        the ticket resolves.  A ticket whose search *failed* keeps
        serving the partial best the dead search had found.  Consumers
        that can re-layout cheaply (the serving runtime's page pool)
        promote to it between ticks instead of waiting for the full
        search to drain.
        """
        if self._event.is_set() and self._error is None \
                and self._plan is not None:
            return self._plan.best
        red = self._reducer
        return red.best() if red is not None else None

    def best_version(self) -> int:
        """Monotone counter: bumps each time ``best_so_far`` improves.
        Poll it to promote only when the best actually changed."""
        red = self._reducer
        return red.version if red is not None else self._final_version

    def _release_reducer(self) -> None:
        """Drop the search machinery once the plan holds the answer --
        the reducer pins the whole candidate space, conflict caches, and
        every admitted solution, which a resolved ticket no longer
        needs."""
        red = self._reducer
        if red is not None:
            self._final_version = red.version
            self._reducer = None
        with self._lock:
            self._best_arts.clear()

    def best_so_far_artifact(self, backend: str = "jax"
                             ) -> Optional[CompiledBankingPlan]:
        """Compiled artifact of the current best-so-far scheme (the
        solved artifact once done; a failed search's partial best, like
        ``best_so_far``).  Lowering is cached per best-version, so
        polling between ticks re-lowers only on improvement."""
        if self.done() and self._error is None:
            if self._plan is None or self._plan.best is None:
                return None
            return self._service.planner.compile(self._plan,
                                                 backend=backend)
        red = self._reducer
        if red is None:
            return None
        sol, version = red.best_with_version()
        if sol is None:
            return None
        key = (version, backend)
        with self._lock:
            art = self._best_arts.get(key)
        if art is not None:
            return art
        art = compile_solution(sol, signature=self.signature,
                               backend=backend,
                               scorer_name=self.scorer_name)
        hub = self._service.telemetry
        if hub is not None:
            hub.instrument(art)
        with self._lock:
            # keep only the newest version per backend: stale lowers
            # are dead weight once the best has moved on
            for k in [k for k in self._best_arts if k[1] == backend]:
                del self._best_arts[k]
            self._best_arts[key] = art
        return art

    # -- immediate execution -----------------------------------------------------
    @property
    def stale_plan(self) -> Optional[BankingPlan]:
        """The same-family near-match serving as provisional answer."""
        return self._stale

    def fallback(self, backend: str = "jax") -> CompiledBankingPlan:
        """An executable artifact available *now*, without the solver.

        Prefers the already-solved plan (free once ``done()``), then the
        stale same-family near-match, then the trivial single-bank
        scheme.  Use it to serve immediately; hot-swap to ``artifact()``
        when the ticket resolves.
        """
        if self.done() and self._error is None \
                and self._plan is not None and self._plan.best is not None:
            return self._service.planner.compile(self._plan, backend=backend)
        if self._stale is not None:
            return self._service.planner.compile(self._stale, backend=backend)
        with self._lock:
            art = self._fallbacks.get(backend)
            if art is None:
                art = self._service.trivial_artifact(self._prep.mem,
                                                     backend=backend)
                self._fallbacks[backend] = art
        return art

    # -- resolution (service-internal) -------------------------------------------
    def _claim(self) -> bool:
        """Exactly one queue entry may solve this ticket (a priority
        upgrade re-enqueues the same ticket; later pops are no-ops)."""
        with self._lock:
            if self._claimed or self._event.is_set():
                return False
            self._claimed = True
            self.status = "solving"
            return True

    def _resolve(self, plan: BankingPlan) -> None:
        self._plan = plan
        self.status = "done"
        self.resolved_at = time.time()
        self._event.set()
        self._fire_callbacks()

    def _fail(self, exc: BaseException) -> None:
        self._error = exc
        self.status = "error"
        self.resolved_at = time.time()
        self._event.set()
        self._fire_callbacks()

    # -- completion callbacks ------------------------------------------------------
    def add_done_callback(self, fn: Callable[["PlanTicket"], None]) -> None:
        """Call ``fn(ticket)`` when this ticket resolves or fails.

        Fires on the resolving thread; a ticket that is already done
        fires immediately on the caller's.  This is how a joint ticket
        graph re-co-selects as member solves land -- callbacks must not
        block (or re-enter the service's submit path)."""
        with self._lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    def _fire_callbacks(self) -> None:
        with self._lock:
            cbs, self._callbacks = self._callbacks, []
        for fn in cbs:
            try:
                fn(self)
            except Exception:   # a consumer's bug must not kill the solve
                pass

    def as_dict(self) -> Dict[str, object]:
        """JSON-serializable ticket summary with honest latency
        attribution: ``queue_ms`` is time spent waiting for a worker,
        ``deferred_ms`` time parked by admission control -- both
        sourced from the same monotonic timestamps the trace spans
        record, so admission latency is attributable instead of folded
        into solve time."""
        now = time.time()
        resolved = self.resolved_at
        return {
            "memory": self.memory,
            "signature": self.signature,
            "scorer": self.scorer_name,
            "status": self.status,
            "tenant": self.tenant,
            "priority": self.priority,
            "deferred": self.deferred,
            "trace_id": self.trace_id,
            "submitted_at": self.submitted_at,
            "resolved_at": resolved,
            "latency_ms": round(((resolved if resolved is not None
                                  else now) - self.submitted_at) * 1e3, 3),
            "queue_ms": round(self.queue_ms, 3),
            "deferred_ms": round(self.deferred_ms, 3),
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"<PlanTicket {self.memory} {self.signature[:16]}... "
                f"{self.status}>")


class JointTicket:
    """Future-like handle for one whole-model joint planning problem.

    A ticket *graph*: one member :class:`PlanTicket` per memory, fanned
    out through the service's normal executors (pool or fabric, one
    tenant unit), plus a co-selection layer on top.  ``selection()``
    re-co-selects progressively as member solves land and best-so-far
    schemes improve -- ``best_so_far`` semantics lifted to the group --
    and ``best_version()`` bumps only when the *joint* selection
    actually changes, so pollers (the serving runtime's coherent
    multi-pool swap) re-lower only on improvement.  Once every member is
    terminal the final co-selection certifies each selected non-trivial
    scheme, persists as a :class:`~repro.core.jointplan.JointPlan`, and
    ``result()`` returns it.

    One member's failure (solver error, certifier refusal, admission
    shed) never poisons the group: that memory degrades to the trivial
    single-bank scheme and co-selection continues over the rest.
    """

    def __init__(self, *, service: "PlanService", request: JointRequest,
                 preps: Dict[str, PreparedRequest], signature: str,
                 scorer_name: str, verify: str = "off",
                 tenant: str = DEFAULT_TENANT):
        self._service = service
        self.request = request
        self.signature = signature
        self.scorer_name = scorer_name
        self.verify = verify
        self.tenant = tenant
        self.budget = request.budget
        self.frontier_cap = max(2, int(request.frontier_cap))
        self.submitted_at = time.time()
        self.resolved_at: Optional[float] = None
        self.status = "queued"
        self.trace_id: Optional[str] = None
        self.members: Dict[str, PlanTicket] = {}
        self._preps = preps
        self._event = threading.Event()
        self._plan: Optional[JointPlan] = None
        self._error: Optional[BaseException] = None
        self._pending = 0
        self._finalized = False
        self._version = 0
        self._stamp: Optional[tuple] = None
        self._selection: Optional[JointSelection] = None
        self._sel_key: Optional[tuple] = None
        self._trivials: Dict[str, BankingSolution] = {}
        self._arts: Dict[Tuple[int, str], Dict[str, CompiledBankingPlan]] = {}
        self._certified: Dict[Tuple[str, tuple], Optional[dict]] = {}
        self._lock = threading.Lock()

    # -- completion ------------------------------------------------------------
    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)

    def result(self, timeout: Optional[float] = None) -> JointPlan:
        """The final joint plan; blocks up to ``timeout`` seconds."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"joint plan {self.signature} not solved within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._plan

    # -- wiring (service-internal) ----------------------------------------------
    def _register(self, name: str, ticket: PlanTicket) -> None:
        self.members[name] = ticket
        self._pending += 1

    def _arm(self) -> None:
        """Subscribe to every member's completion.  Called once, after
        all members are registered; a member that is already done (sync
        cache hit, shed) fires its callback immediately on this
        thread."""
        self.status = "solving"
        for name, t in self.members.items():
            t.add_done_callback(lambda _t, n=name: self._member_done(n))

    def _resolve_cached(self, plan: JointPlan) -> None:
        """Born-done path: the store already held this joint plan."""
        self._plan = plan
        self._finalized = True
        self.status = "done"
        self.resolved_at = time.time()
        self._event.set()

    def _member_done(self, name: str) -> None:
        with self._lock:
            self._pending -= 1
            last = self._pending == 0 and not self._finalized
            if last:
                self._finalized = True
        if last:
            try:
                self._finalize()
            except BaseException as e:
                self._error = e
                self.status = "error"
                self.resolved_at = time.time()
                self._event.set()
                tr = self._service.tracer
                if tr is not None and self.trace_id is not None:
                    tr.finish(self.trace_id, status="error",
                              anomaly="error")

    # -- frontiers -------------------------------------------------------------
    def _trivial_for(self, name: str) -> BankingSolution:
        with self._lock:
            sol = self._trivials.get(name)
        if sol is None:
            prep = self._preps[name]
            sol = trivial_solution(prep.mem, prep.groups, prep.iterators,
                                   prep.opts)
            with self._lock:
                self._trivials[name] = sol
        return sol

    def _frontier_for(self, name: str) -> "List[FrontierPoint]":
        """The member's current frontier: its full solved frontier once
        done, its best-so-far singleton while solving, trivial-only
        after a failure -- always non-empty."""
        t = self.members[name]
        sols: List[BankingSolution] = []
        if t.done():
            if t._error is None and t._plan is not None:
                # a disk-hydrated plan carries only its best scheme;
                # fresh and memory-cached plans keep the whole ranking
                sols = list(t._plan.solutions) or (
                    [t._plan.best] if t._plan.best is not None else [])
        else:
            best = t.best_so_far()
            if best is not None:
                sols = [best]
        return pareto_frontier(sols, trivial=self._trivial_for(name),
                               cap=self.frontier_cap)

    # -- progressive co-selection ------------------------------------------------
    def selection(self) -> JointSelection:
        """The current joint co-selection over whatever each member has
        produced so far (recomputed only when some member's state
        changed).  Pure function of member frontiers + budget, so the
        answer is invariant to the order solves happen to land in."""
        if self._event.is_set() and self._plan is not None:
            return self._final_selection()
        stamp = tuple((n, t.status, t.done(), t.best_version())
                      for n, t in sorted(self.members.items()))
        with self._lock:
            if stamp == self._stamp and self._selection is not None:
                return self._selection
        tr = self._service.tracer
        cs_stats = {} if tr is not None else None
        t_sel = time.perf_counter()
        frontiers = {n: self._frontier_for(n) for n in self.members}
        sel = co_select(frontiers, self.budget, stats_out=cs_stats)
        if tr is not None and self.trace_id is not None:
            tr.record(self.trace_id, "co-select", t_sel,
                      time.perf_counter(), progressive=True,
                      **(cs_stats or {}))
        with self._lock:
            if sel.key() != self._sel_key:
                self._version += 1
                self._sel_key = sel.key()
                self._service.stats.bump("joint_reselects",
                                         tenant=self.tenant)
            self._stamp = stamp
            self._selection = sel
        return sel

    def _final_selection(self) -> JointSelection:
        picks = {}
        for name, m in self._plan.members.items():
            sol = m.chosen if m.chosen is not None \
                else self._trivial_for(name)
            picks[name] = FrontierPoint(
                solution=sol, use=m.use, score=m.score, trivial=m.trivial)
        return JointSelection(picks=picks, total_use=self._plan.total_use,
                              total_score=self._plan.total_score,
                              feasible=self._plan.feasible)

    def best_version(self) -> int:
        """Monotone counter: bumps each time the joint selection
        changes.  Poll it to re-lower/promote only on improvement."""
        if not self._event.is_set():
            self.selection()
        with self._lock:
            return self._version

    # -- artifacts ---------------------------------------------------------------
    def artifacts(self, backend: str = "jax"
                  ) -> Dict[str, CompiledBankingPlan]:
        """Compiled artifacts of the current joint selection, one per
        memory -- lowered and cached per selection version, so polling
        between decode ticks re-lowers only when the selection moved."""
        sel = self.selection()
        with self._lock:
            version = self._version
            cached = self._arts.get((version, backend))
        if cached is not None:
            return dict(cached)
        arts: Dict[str, CompiledBankingPlan] = {}
        for name, pick in sel.picks.items():
            prep = self._preps[name]
            if pick.trivial:
                arts[name] = self._service.trivial_artifact(prep.mem,
                                                            backend=backend)
            else:
                art = compile_solution(pick.solution,
                                       signature=prep.signature,
                                       backend=backend,
                                       scorer_name=self.scorer_name)
                hub = self._service.telemetry
                if hub is not None:
                    hub.instrument(art)
                arts[name] = art
        with self._lock:
            # keep only the newest version per backend
            for k in [k for k in self._arts if k[1] == backend]:
                del self._arts[k]
            self._arts[(version, backend)] = arts
        return dict(arts)

    def fallback(self, backend: str = "jax"
                 ) -> Dict[str, CompiledBankingPlan]:
        """Immediately executable artifacts for every member (each
        member ticket's own fallback discipline) -- serve now, swap to
        ``artifacts()`` as the joint selection lands."""
        return {name: t.fallback(backend)
                for name, t in self.members.items()}

    # -- finalization ------------------------------------------------------------
    def _certify_pick(self, name: str, pick: "FrontierPoint"
                      ) -> Tuple[bool, Optional[dict]]:
        """Certify one selected scheme (cached per scheme); returns
        (ok, certificate-JSON)."""
        key = (name, pick.key())
        with self._lock:
            if key in self._certified:
                cert = self._certified[key]
                return cert is not None, cert
        from ..analysis.certify import certify_solution
        prep = self._preps[name]
        res = certify_solution(pick.solution, prep.groups, prep.iterators,
                               signature=prep.signature,
                               scorer=self.scorer_name)
        cert = (res.certificate.to_json()
                if res.ok and res.certificate is not None else None)
        with self._lock:
            self._certified[key] = cert
        return res.ok, cert

    def _finalize(self) -> None:
        """Every member is terminal: run the final co-selection, certify
        each selected scheme, persist, resolve.

        A certifier refusal evicts just that scheme from its member's
        frontier and re-co-selects -- the group never fails for one bad
        member, it degrades that member (ultimately to trivial, which
        needs no certificate because it serializes instead of banking).
        """
        service = self._service
        tr = service.tracer
        tid = self.trace_id if tr is not None else None
        frontiers = {n: self._frontier_for(n) for n in self.members}
        certs: Dict[str, Optional[dict]] = {}
        while True:
            cs_stats = {} if tr is not None else None
            t_sel = time.perf_counter()
            sel = co_select(frontiers, self.budget, stats_out=cs_stats)
            if tid is not None:
                tr.record(tid, "co-select", t_sel, time.perf_counter(),
                          final=True, **(cs_stats or {}))
            if self.verify == "off":
                break
            evicted = False
            for name, pick in sorted(sel.picks.items()):
                if pick.trivial:
                    continue
                ok, cert = self._certify_pick(name, pick)
                if ok:
                    certs[name] = cert
                else:
                    frontiers[name] = [p for p in frontiers[name]
                                       if p.key() != pick.key()]
                    service.stats.bump("joint_cert_evictions",
                                       tenant=self.tenant)
                    evicted = True
            if not evicted:
                break
        members: Dict[str, JointMember] = {}
        for name, pick in sel.picks.items():
            t = self.members[name]
            if t.done() and t._error is None and t._plan is not None:
                status, error = t._plan.status, t._plan.error
            else:
                status = "error"
                error = repr(t._error) if t._error is not None else ""
            cert = None if pick.trivial else certs.get(name)
            members[name] = JointMember(
                memory=name, signature=t.signature, status=status,
                chosen=pick.solution, trivial=pick.trivial,
                certified=cert is not None, certificate=cert,
                score=float(pick.solution.score), use=pick.use,
                error=error)
        plan = JointPlan(
            signature=self.signature, members=members, budget=self.budget,
            feasible=sel.feasible, scorer_name=self.scorer_name,
            status="solved", solve_seconds=time.time() - self.submitted_at,
            created_at=time.time(),
            opts=next(iter(self._preps.values())).opts)
        store = service.planner.store
        if store is not None and self.request.use_cache:
            store.put_joint(plan)
        service.stats.bump("joint_solved", tenant=self.tenant)
        if not sel.feasible:
            service.stats.bump("joint_infeasible", tenant=self.tenant)
        with self._lock:
            if sel.key() != self._sel_key:
                self._version += 1
                self._sel_key = sel.key()
            self._selection = sel
        self._plan = plan
        self.status = "done"
        self.resolved_at = time.time()
        self._event.set()
        if tid is not None:
            tr.finish(tid, status="ok",
                      anomaly=None if sel.feasible else "infeasible")

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"<JointTicket {self.signature[:16]}... "
                f"{len(self.members)} members {self.status}>")


@dataclass
class ServiceStats:
    submits: int = 0
    sync_hits: int = 0       # tickets born done (cache/store answered)
    deduped: int = 0         # submits merged onto an in-flight ticket
    queued: int = 0
    solved: int = 0
    errors: int = 0
    deferred: int = 0        # over-quota submits parked by admission
    shed: int = 0            # submits refused outright (backlog full)
    revalidations: int = 0   # tickets served a stale near-match
    shards_spawned: int = 0  # SolveShards fanned across the worker pool
    shards_completed: int = 0
    best_promotions: int = 0  # times a ticket's best-so-far improved
    dedup_hits: int = 0      # duplicate schemes dropped by the reducers
    adaptive_budgets: int = 0  # cold solves whose fan-out was auto-sized
    fabric_solves: int = 0   # cold solves run on the remote fabric
    fabric_fallbacks: int = 0  # fabric requested but no workers: pool ran
    fabric_leases: int = 0   # work units leased to remote workers
    fabric_requeues: int = 0  # leases requeued after worker death/timeout
    fabric_cut_broadcasts: int = 0  # cut snapshots pushed mid-flight
    fabric_workers_lost: int = 0
    fabric_heartbeats: int = 0  # liveness frames from remote workers
    observations: int = 0    # measured gather/scatter/tick timings logged
    refreshes: int = 0       # ml_scorer.json refits from measured pairs
    demotions: int = 0       # stored plans evicted for measured slowness
    certified: int = 0       # schemes independently certified before caching
    cert_failures: int = 0   # solver outputs refused by the certifier
    cert_rejected: int = 0   # fabric result batches rejected + requeued
    lint_errors: int = 0     # submits refused by the pre-solve lint pass
    joint_submits: int = 0   # whole-model submit_joint calls
    joint_sync_hits: int = 0  # joint tickets answered from the store
    joint_solved: int = 0    # joint tickets resolved with a selection
    joint_reselects: int = 0  # progressive co-selections as members landed
    joint_infeasible: int = 0  # budgets under even the all-trivial floor
    joint_cert_evictions: int = 0  # selected schemes refused + re-selected
    # per-tenant slices (global counters include every slice; a slice
    # never has its own sub-slices)
    tenants: Dict[str, "ServiceStats"] = field(default_factory=dict,
                                               repr=False, compare=False)
    # the MetricsRegistry mirror (enable_tracing wires it): every bump
    # ALSO lands as plan_<name>{tenant=...} through the same single
    # write path, so the registry subsumes this arithmetic without
    # breaking the exact per-tenant reconciliation
    metrics: Optional[object] = field(default=None, repr=False,
                                      compare=False)

    def bump(self, name: str, n: int = 1,
             tenant: Optional[str] = None) -> None:
        """Add ``n`` to counter ``name`` here AND on the tenant's slice.

        The single write path is what makes ``for_tenant`` slices
        reconcile *exactly* with the global counters: every global
        increment lands on exactly one slice (``tenant=None`` =
        the default tenant).  With a :class:`MetricsRegistry` attached
        the same increment mirrors there as ``plan_<name>`` with a
        ``tenant`` label -- one write, three consistent views.
        """
        setattr(self, name, getattr(self, name) + n)
        if self.tenants is not None:   # a slice doesn't slice further
            slice_ = self.for_tenant(tenant or DEFAULT_TENANT)
            setattr(slice_, name, getattr(slice_, name) + n)
        if self.metrics is not None:
            self.metrics.inc("plan_" + name, n,
                             tenant=tenant or DEFAULT_TENANT)

    def for_tenant(self, name: str) -> "ServiceStats":
        """The tenant's counter slice (created on first touch)."""
        stats = self.tenants.get(name)
        if stats is None:
            stats = ServiceStats(tenants=None)
            self.tenants[name] = stats
        return stats

    def as_dict(self, include_tenants: bool = True) -> Dict[str, object]:
        """Counters as a JSON-serializable dict; per-tenant slices nest
        under ``"tenants"`` (omitted when empty)."""
        out: Dict[str, object] = {
            k: v for k, v in vars(self).items() if isinstance(v, int)}
        if include_tenants and self.tenants:
            out["tenants"] = {
                name: s.as_dict(include_tenants=False)
                for name, s in sorted(self.tenants.items())}
        return out


@dataclass
class _SolveState:
    """Book-keeping for one in-flight sharded solve: the reducer shared
    by its shard jobs, plus completion/error accounting.  The worker
    that finishes the last shard finalizes the plan and resolves the
    ticket."""

    prep: PreparedRequest
    ticket: "PlanTicket"
    reducer: SolutionReducer
    scorer_fn: object
    started: float
    remaining: int
    failed: bool = False
    lock: threading.Lock = field(default_factory=threading.Lock)

    def shard_finished(self) -> bool:
        """True for exactly the caller that completed the last shard."""
        with self.lock:
            self.remaining -= 1
            return self.remaining == 0 and not self.failed

    def fail(self, exc: BaseException) -> bool:
        """Record the first failure; returns True for that first caller."""
        with self.lock:
            first = not self.failed
            self.failed = True
        if first:
            self.reducer.cancel()   # stop sibling shards early
        return first


@dataclass
class _ShardJob:
    state: _SolveState
    shard: SolveShard


_SENTINEL = None

EXECUTORS = ("pool", "fabric")

# Static-verification modes (repro.analysis):
#   "off"   -- trust the solver (the historical behavior);
#   "store" -- lint programs before queueing, certify solver output
#              before it is cached/persisted, persist the certificate
#              beside the plan, re-verify store entries on hydrate;
#   "all"   -- "store" plus certification of every solution batch a
#              fabric worker streams back (bad batches are rejected and
#              their units requeued away from the sender).
VERIFY_MODES = ("off", "store", "all")


class PlanService:
    """submit/await planning: a priority queue of banking problems drained
    by daemon workers into one shared :class:`BankingPlanner`.

    Parameters
    ----------
    planner : the planner to answer through (default: a fresh one)
    store : plan store for a fresh planner (``PlanStore`` or directory
        path); ignored when ``planner`` is given
    workers : worker-pool width (threads spawn lazily on first miss)
    revalidate : the :class:`StaleWhileRevalidate` policy (pass
        ``StaleWhileRevalidate(enabled=False)`` to disable)
    shard_budget : shards per cold solve (per-submit override via
        ``submit(..., shard_budget=...)``); 1 disables sharding and the
        default ``None`` sizes the fan-out *adaptively* from each
        problem's enumerated candidate space
        (:meth:`CandidateSpace.suggested_shards`), so small spaces skip
        fan-out overhead entirely
    executor : where cold solves run -- ``"pool"`` (this process's
        worker threads) or ``"fabric"`` (remote shard workers attached
        to ``fabric``); per-submit override via
        ``submit(..., executor=...)``
    fabric : the :class:`~repro.core.fabric.SolveFabric` backing the
        ``"fabric"`` executor (attach one later via
        :meth:`attach_fabric`); a fabric with no live workers falls
        back to the pool
    tenants : the :class:`~repro.runtime.tenancy.TenantRegistry` naming
        this service's consumers and their QoS classes.  Submits tag
        themselves with ``submit(..., tenant="name")``: the tenant's
        QoS band offsets the ticket priority, its quotas gate admission
        (over-quota cold solves defer -- fallback still served -- and a
        full deferral backlog sheds with an honest
        :class:`~repro.runtime.tenancy.AdmissionError`), its weight
        drives fair-share queue draining, and its shard/lease caps
        bound solver fan-out.  ``stats.for_tenant(name)`` is the
        tenant's exact counter slice.  Default: a fresh permissive
        registry (untagged submits behave exactly as before tenancy).
    """

    def __init__(self, planner: Optional[BankingPlanner] = None, *,
                 store: Optional[Union[PlanStore, str]] = None,
                 workers: int = 2,
                 revalidate: Optional[StaleWhileRevalidate] = None,
                 shard_budget: Optional[int] = None,
                 executor: str = "pool",
                 fabric=None,
                 verify: str = "off",
                 tenants: Optional[TenantRegistry] = None):
        if executor not in EXECUTORS:
            raise ValueError(
                f"unknown executor {executor!r}; one of {EXECUTORS}")
        if verify not in VERIFY_MODES:
            raise ValueError(
                f"unknown verify mode {verify!r}; one of {VERIFY_MODES}")
        if planner is None:
            planner = BankingPlanner(store=as_store(store))
        self.planner = planner
        self.verify = verify
        if verify != "off" and planner.store is not None \
                and hasattr(planner.store, "verify_hydrated"):
            # an armed service refuses to serve uncertified disk entries
            planner.store.verify_hydrated = True
        # claim the planner's inline-service slot when it's free, so
        # planner.plan() (= submit().result()) shares this queue/workers
        with planner._lock:
            if planner._service is None:
                planner._service = self
        self.revalidate = (revalidate if revalidate is not None
                           else StaleWhileRevalidate())
        self.stats = ServiceStats()
        self.tenants = tenants if tenants is not None else TenantRegistry()
        self._admission = AdmissionController(self.tenants)
        # always the fair-share queue, even single-tenant: equal-band
        # entries drain in submit order (seq tie-break), and tenant
        # weights only matter once a registry defines contending ones
        self._queue = FairShareQueue(self.tenants)
        self._seq = itertools.count()
        self._inflight: Dict[Tuple[str, str], PlanTicket] = {}
        self._trivial: Dict[Tuple, CompiledBankingPlan] = {}
        self._threads = []
        # queued + claimed-but-unfinished items; counted at enqueue time
        # (not from qsize()) so worker sizing can't race a fast pop
        self._outstanding = 0
        self._demand = threading.Lock()
        self._max_workers = max(1, int(workers))
        # None = adaptive: sized per problem from its candidate space
        self.shard_budget = (max(1, int(shard_budget))
                             if shard_budget is not None else None)
        self.executor = executor
        self._fabric = fabric
        self._shutdown = False
        self._lock = threading.Lock()
        self.telemetry = None   # ServiceTelemetry hub (enable_telemetry)
        # observability plane (enable_tracing): all hooks are guarded by
        # `tracer is None`, so an un-traced service pays one attr load
        self.tracer = None
        self.metrics = None
        self.recorder = None

    def attach_fabric(self, fabric) -> None:
        """Attach (or replace) the remote solve fabric backing the
        ``"fabric"`` executor."""
        self._fabric = fabric

    def enable_telemetry(self, config=None, log=None):
        """Turn on the measured-cost feedback loop.

        Builds a :class:`~repro.core.telemetry.ServiceTelemetry` hub wired
        to this service and its planner: artifacts the planner compiles
        get timing hooks, answered plans are registered for demotion
        watch, observations flush into the store's ``telemetry/`` sidecar,
        and ``scorer="measured"`` submits rank on this service's own log.
        Returns the hub (idempotent: repeated calls return the same one).
        """
        if self.telemetry is None:
            from .telemetry import ServiceTelemetry
            hub = ServiceTelemetry(service=self, planner=self.planner,
                                   config=config, log=log)
            self.telemetry = hub
            self.planner.telemetry = hub
        return self.telemetry

    def enable_tracing(self, *, capacity: int = 64,
                       slo_ms: Optional[float] = None,
                       trace_dir: Optional[str] = None):
        """Turn on the observability plane (idempotent).

        Builds one :class:`~repro.core.tracing.MetricsRegistry` (every
        ``stats.bump`` mirrors into it as ``plan_<counter>`` with a
        ``tenant`` label), one :class:`~repro.core.tracing.Tracer`
        (each submit gets a ``trace_id`` whose spans cover
        prepare -> lookup -> admission -> queue-wait -> solve -> certify,
        stitched with remote fabric worker spans over the wire), and
        one :class:`~repro.core.tracing.FlightRecorder` keeping the
        last ``capacity`` completed ticket traces -- dumped as Chrome
        ``trace_event`` JSON on demand or on anomaly (latency over
        ``slo_ms``, a certificate rejection, a telemetry demotion;
        anomaly dumps land in ``trace_dir`` when given).  Returns the
        tracer.
        """
        if self.tracer is None:
            from .tracing import FlightRecorder, MetricsRegistry, Tracer
            self.metrics = MetricsRegistry()
            self.recorder = FlightRecorder(capacity=capacity,
                                           slo_ms=slo_ms,
                                           trace_dir=trace_dir,
                                           metrics=self.metrics)
            self.tracer = Tracer(recorder=self.recorder,
                                 metrics=self.metrics)
            self.stats.metrics = self.metrics
            # queue depth / pops and admission backlog gauges
            self._queue.metrics = self.metrics
            self._admission.metrics = self.metrics
        return self.tracer

    # -- the front door ----------------------------------------------------------
    def submit(self, program, memory: Optional[str] = None, *,
               opts: Optional[SolverOptions] = None,
               scorer: ScorerLike = None,
               use_cache: bool = True,
               priority: int = 0,
               shard_budget: Optional[int] = None,
               executor: Optional[str] = None,
               verify: Optional[str] = None,
               tenant: Optional[str] = None) -> PlanTicket:
        """Pose one banking problem; returns a :class:`PlanTicket`.

        Runs unroll + grouping + signature + cache probe inline (bad
        memories / unknown scorers raise here, warm caches return a
        ticket that is already ``done()``); cold problems are queued for
        the worker pool, which fans each solve across up to
        ``shard_budget`` candidate-space shards (default: the service's,
        itself defaulting to an adaptive per-problem fan-out) -- or, with
        ``executor="fabric"``, across the attached remote solve workers.
        Lower ``priority`` solves first.

        ``verify`` ("off" | "store" | "all", default: the service's
        mode) arms the static verification layer for this submit: the
        program is linted before queueing (lint errors raise
        ``repro.analysis.LintError`` here), solver output is
        independently certified before it is cached or persisted, and
        with "all" every fabric result batch is certified on intake.

        ``tenant`` names the submitting consumer (see the ``tenants``
        registry): its QoS class offsets the priority band, its quotas
        may defer or shed this submit's cold solve (deferral is honest
        -- ``ticket.deferred`` -- and the fallback artifact still serves
        immediately), and its stats slice records the submit.
        """
        tr = self.tracer
        trace_id = new_trace_id() if tr is not None else None
        t_prep = time.perf_counter()
        prep = self.planner.prepare(program, memory, opts=opts,
                                    scorer=scorer, use_cache=use_cache)
        if tr is not None:
            # the ticket doesn't exist yet: the trace does, and the
            # prepare stage is its first span
            tr.record(trace_id, "prepare", t_prep, time.perf_counter(),
                      memory=prep.memory)
        return self.submit_prepared(prep, priority=priority,
                                    shard_budget=shard_budget,
                                    executor=executor, verify=verify,
                                    tenant=tenant, _trace_id=trace_id)

    def submit_request(self, request: PlanRequest, *,
                       priority: int = 0) -> PlanTicket:
        return self.submit_prepared(self.planner.prepare(request),
                                    priority=priority)

    def submit_prepared(self, prep: PreparedRequest, *,
                        priority: int = 0,
                        shard_budget: Optional[int] = None,
                        executor: Optional[str] = None,
                        verify: Optional[str] = None,
                        tenant: Optional[str] = None,
                        _trace_id: Optional[str] = None) -> PlanTicket:
        if executor is not None and executor not in EXECUTORS:
            raise ValueError(
                f"unknown executor {executor!r}; one of {EXECUTORS}")
        if verify is not None and verify not in VERIFY_MODES:
            raise ValueError(
                f"unknown verify mode {verify!r}; one of {VERIFY_MODES}")
        verify = verify if verify is not None else self.verify
        ten = self.tenants.resolve(tenant)
        # the QoS band offsets the caller's priority: an interactive
        # tenant's priority-0 submit still outranks a batch tenant's
        priority = priority + ten.qos.priority
        tr = self.tracer
        trace_id = (_trace_id if _trace_id is not None
                    else (new_trace_id() if tr is not None else None))
        self.stats.bump("submits", tenant=ten.name)
        if verify != "off":
            # lint before anything queues: problems no banking can fix
            # (OOB accesses, colliding Syms, oversubscribed ports) must
            # fail the submit, not burn a solve
            with (tr.span(trace_id, "lint") if tr is not None
                  else NULL_SPAN):
                self._lint_gate(prep, ten.name)
        key = (prep.signature, prep.scorer_name)
        if prep.request.use_cache:
            t_look = time.perf_counter()
            hit = self.planner.lookup(prep)
            if tr is not None:
                tr.record(trace_id, "lookup", t_look, time.perf_counter(),
                          hit=hit is not None)
            if hit is not None:
                self.stats.bump("sync_hits", tenant=ten.name)
                ticket = PlanTicket(service=self, prep=prep,
                                    priority=priority, verify=verify,
                                    tenant=ten.name)
                ticket.trace_id = trace_id
                ticket._resolve(hit)
                if tr is not None:
                    tr.finish(trace_id, status="sync-hit",
                              label=f"ticket {prep.memory}")
                if self.telemetry is not None:
                    self.telemetry.register(prep, hit)
                return ticket
        ticket = PlanTicket(service=self, prep=prep, priority=priority,
                            shard_budget=shard_budget, executor=executor,
                            verify=verify, tenant=ten.name)
        ticket.trace_id = trace_id
        if tr is not None:
            tr.label(trace_id, f"ticket {prep.memory}")
            ticket._root_span = tr.begin(trace_id, "ticket",
                                         memory=prep.memory,
                                         tenant=ten.name,
                                         signature=prep.signature[:16])
        if prep.request.use_cache:
            # atomic check-and-register: concurrent submits of the same
            # (signature, scorer) must share ONE solve
            with self._lock:
                inflight = self._inflight.get(key)
                if inflight is None:
                    self._inflight[key] = ticket
            if inflight is not None:
                self.stats.bump("deduped", tenant=ten.name)
                if tr is not None:
                    # this submit rides the in-flight ticket's solve;
                    # close the newborn trace rather than leak it live
                    tr.end(ticket._root_span,
                           deduped_onto=inflight.trace_id or "")
                    tr.finish(trace_id, status="deduped")
                if priority < inflight.priority:
                    # urgency upgrade; a still-deferred ticket isn't in
                    # the queue yet -- it just keeps the better priority
                    # for when admission releases it
                    inflight.priority = priority
                    if not inflight.deferred:
                        # re-enqueue the same ticket at the new
                        # priority; _claim() makes later pops no-ops
                        self._enqueue((priority, next(self._seq),
                                       inflight._prep, inflight))
                return inflight
            stale = self.revalidate.pick(self.planner, prep)
            if stale is not None:
                ticket._stale = stale
                ticket.status = "revalidating"
                self.stats.bump("revalidations", tenant=ten.name)
        # admission: the cold solve claims one of the tenant's in-flight
        # slots, or parks in its deferral backlog, or -- backlog full --
        # sheds with an honest error (the fallback artifact still works)
        if self._admission.try_acquire(ten.name):
            ticket._admitted = True
        elif self._admission.defer(ten.name, (prep, ticket)):
            ticket.deferred = True
            ticket._deferred_at = time.perf_counter()
            if ticket.status == "queued":
                ticket.status = "deferred"
            self.stats.bump("deferred", tenant=ten.name)
            if tr is not None:
                tr.instant(trace_id, "admission-deferred",
                           tenant=ten.name)
            return ticket
        else:
            self.stats.bump("shed", tenant=ten.name)
            with self._lock:
                if self._inflight.get(key) is ticket:
                    del self._inflight[key]
            ticket._fail(AdmissionError(
                f"tenant {ten.name!r} over quota "
                f"(max_inflight={ten.qos.max_inflight}, "
                f"max_deferred={ten.qos.max_deferred}): submit shed; "
                f"the ticket's fallback artifact is still servable"))
            ticket.status = "shed"
            if tr is not None:
                if ticket._root_span is not None:
                    tr.end(ticket._root_span)
                    ticket._root_span = None
                tr.finish(trace_id, status="shed", anomaly="shed")
            return ticket
        self.stats.bump("queued", tenant=ten.name)
        ticket._queued_at = time.perf_counter()
        self._enqueue((priority, next(self._seq), prep, ticket))
        self._ensure_workers()
        return ticket

    # -- whole-model joint planning ----------------------------------------------
    def submit_joint(self, request, *,
                     memories: Optional[Sequence[str]] = None,
                     budget: Optional[ResourceBudget] = None,
                     opts: Optional[SolverOptions] = None,
                     scorer: ScorerLike = None,
                     use_cache: bool = True,
                     frontier_cap: int = 8,
                     priority: int = 0,
                     shard_budget: Optional[int] = None,
                     executor: Optional[str] = None,
                     verify: Optional[str] = None,
                     tenant: Optional[str] = None) -> JointTicket:
        """Pose one whole-model planning problem; returns a
        :class:`JointTicket`.

        ``request`` is a :class:`~repro.core.jointplan.JointRequest` or
        a bare ``Program`` (then ``memories``/``budget``/``opts``/
        ``scorer`` apply).  Each memory's solve fans out through the
        normal executors exactly like a ``submit`` -- same sharding,
        fabric, stale-while-revalidate, and verification -- but all
        members submit as **one tenant unit** (same tenant, admission
        quotas serialize them honestly; a shed member degrades to its
        trivial scheme instead of failing the group) and the ticket
        co-selects one scheme per memory under the shared ``budget``
        instead of taking each argmin.  A warm ``joint/`` store entry
        answers before any member submits (ticket born ``done``).
        """
        if isinstance(request, JointRequest):
            req = request
        else:
            req = JointRequest(program=request, memories=memories,
                               budget=budget, opts=opts, scorer=scorer,
                               use_cache=use_cache,
                               frontier_cap=frontier_cap)
        names = req.memory_names()
        if not names:
            raise ValueError("joint request names no memories")
        verify = verify if verify is not None else self.verify
        if verify not in VERIFY_MODES:
            raise ValueError(
                f"unknown verify mode {verify!r}; one of {VERIFY_MODES}")
        ten = self.tenants.resolve(tenant)
        tr = self.tracer
        trace_id = new_trace_id() if tr is not None else None
        # member prep is the same cheap inline half as submit(): bad
        # memories and unknown scorers raise here, on the caller
        t_prep = time.perf_counter()
        preps = {name: self.planner.prepare(req.program, name,
                                            opts=req.opts, scorer=req.scorer,
                                            use_cache=req.use_cache)
                 for name in names}
        scorer_name = next(iter(preps.values())).scorer_name
        signature = joint_signature(
            {n: p.signature for n, p in preps.items()}, scorer_name,
            req.budget)
        if tr is not None:
            tr.label(trace_id, f"joint {len(names)} memories")
            tr.record(trace_id, "joint-prepare", t_prep,
                      time.perf_counter(), members=len(names))
        self.stats.bump("joint_submits", tenant=ten.name)
        ticket = JointTicket(service=self, request=req, preps=preps,
                             signature=signature, scorer_name=scorer_name,
                             verify=verify, tenant=ten.name)
        ticket.trace_id = trace_id
        if req.use_cache and self.planner.store is not None:
            cached = self.planner.store.get_joint(signature)
            if cached is not None:
                self.stats.bump("joint_sync_hits", tenant=ten.name)
                ticket._resolve_cached(cached)
                if tr is not None:
                    tr.finish(trace_id, status="sync-hit")
                return ticket
        # fan out the member solves -- one tenant unit; registration
        # completes before arming so a flurry of sync hits cannot
        # finalize a half-registered graph
        for name, prep in preps.items():
            member = self.submit_prepared(
                prep, priority=priority, shard_budget=shard_budget,
                executor=executor, verify=verify, tenant=tenant)
            ticket._register(name, member)
        ticket._arm()
        return ticket

    # -- immediate artifacts -------------------------------------------------------
    def trivial_artifact(self, mem: MemorySpec, *,
                         backend: str = "jax") -> CompiledBankingPlan:
        """Process-cached trivial single-bank artifact for ``mem``."""
        key = (tuple(mem.dims), mem.word_bits, backend)
        with self._lock:
            art = self._trivial.get(key)
        if art is not None:
            return art
        art = compile_trivial(mem, backend=backend)
        with self._lock:
            self._trivial[key] = art
        return art

    # -- static verification (repro.analysis) ------------------------------------
    def _lint_gate(self, prep: PreparedRequest,
                   tenant: str = DEFAULT_TENANT) -> None:
        """Refuse submits whose Program fails the lint pass (raises
        :class:`repro.analysis.LintError` on error-severity findings)."""
        from ..analysis.lint import LintError, lint_program
        report = lint_program(prep.request.program, prep.memory)
        if not report.ok:
            with self._lock:
                self.stats.bump("lint_errors", tenant=tenant)
            raise LintError(report)

    def _make_verifier(self, mode: str, tenant: str = DEFAULT_TENANT,
                       trace_id: Optional[str] = None):
        """The certify-before-cache callback handed to
        ``BankingPlanner.complete_solve`` (``None`` when verification is
        off).  Failed certification bumps ``cert_failures`` and raises
        :class:`repro.analysis.CertificationError` -- the plan is never
        cached or persisted, and the ticket surfaces the counterexample
        through ``result()``.  Success bumps ``certified`` and persists
        the certificate beside the plan when the store keeps them."""
        if mode == "off":
            return None

        def verify(plan: BankingPlan, prep: PreparedRequest) -> None:
            from ..analysis.certify import CertificationError, certify_plan
            tr = self.tracer
            t_cert = time.perf_counter()
            res = certify_plan(plan, prep.iterators,
                               scorer=prep.scorer_name)
            if tr is not None and trace_id is not None:
                tr.record(trace_id, "certify", t_cert,
                          time.perf_counter(), ok=res.ok)
            if not res.ok:
                with self._lock:
                    self.stats.bump("cert_failures", tenant=tenant)
                if tr is not None:
                    tr.note_anomaly("cert-rejection",
                                    detail=plan.signature[:16])
                why = (res.counterexample.describe()
                       if res.counterexample is not None else res.reason)
                raise CertificationError(
                    f"solver output failed independent certification: "
                    f"{why}", res.counterexample)
            with self._lock:
                self.stats.bump("certified", tenant=tenant)
            if res.certificate is not None \
                    and self.planner.store is not None:
                self.planner.store.put_certificate(
                    plan.signature, plan.scorer_name,
                    res.certificate.to_json())

        return verify

    # -- worker pool ----------------------------------------------------------------
    def _enqueue(self, item) -> None:
        """All work lands through here so ``_outstanding`` counts queued
        AND claimed-but-unfinished items -- a worker that already popped
        a long (or gated) solve must not hide demand, or one slow joint
        member would serialize the rest of its graph."""
        with self._demand:
            self._outstanding += 1
        self._queue.put(item)

    def _ensure_workers(self) -> None:
        with self._lock:
            if self._shutdown:
                raise RuntimeError("PlanService is shut down")
            want = min(self._max_workers, max(1, self._outstanding))
            while len(self._threads) < want:
                t = threading.Thread(
                    target=self._worker, daemon=True,
                    name=f"plan-service-{len(self._threads)}")
                self._threads.append(t)
                t.start()

    def _worker(self) -> None:
        while True:
            item = self._queue.get()
            try:
                if item[2] is _SENTINEL:
                    return
                _, _, payload, ticket = item
                if isinstance(payload, _ShardJob):
                    self._run_shard(payload, ticket)
                    continue
                if not ticket._claim():
                    continue   # duplicate entry (priority upgrade) or done
                queued_at = ticket._queued_at
                if queued_at is not None:
                    now = time.perf_counter()
                    ticket.queue_ms += (now - queued_at) * 1e3
                    ticket._queued_at = None
                    tr = self.tracer
                    if tr is not None and ticket.trace_id is not None:
                        tr.record(ticket.trace_id, "queue-wait",
                                  queued_at, now, tenant=ticket.tenant)
                try:
                    plan = (self.planner.lookup(payload)
                            if payload.request.use_cache else None)
                    if plan is None:
                        # cold: fan the candidate space across the pool;
                        # the last shard's worker resolves the ticket
                        self._launch_shards(payload, ticket)
                        continue
                except BaseException as e:  # surface through result()
                    self._finish(ticket, payload, error=e)
                else:
                    self._finish(ticket, payload, plan=plan)
            finally:
                if item[2] is not _SENTINEL:
                    with self._demand:
                        self._outstanding -= 1
                self._queue.task_done()

    def _launch_shards(self, prep: PreparedRequest,
                       ticket: PlanTicket) -> None:
        """Enumerate the candidate space and run the solve on the chosen
        executor: enqueue one pool job per shard at the ticket's
        priority, or drive the remote fabric from this worker thread.
        Runs on the claiming worker so scorer resolution (lazy "ml"
        training) stays off the submitter's thread, exactly like the
        old monolithic solve."""
        self.planner.stats.misses += 1
        tr = self.tracer
        tid = ticket.trace_id if tr is not None else None
        t_enum = time.perf_counter()
        space = self.planner.build_space(prep)
        if tid is not None:
            tr.record(tid, "enumerate", t_enum, time.perf_counter(),
                      candidates=len(space))
        _, scorer_fn = resolve_scorer(prep.scorer_spec)
        if self.telemetry is not None:
            # a "measured" scorer ranks on THIS service's observation log
            scorer_fn = self.telemetry.adapt_scorer(prep.scorer_name,
                                                    scorer_fn)
        reducer = SolutionReducer(space, scorer=scorer_fn)
        ticket._reducer = reducer
        executor = (ticket.executor if ticket.executor is not None
                    else self.executor)
        if executor == "fabric":
            fabric = self._fabric
            if fabric is not None and fabric.workers_alive > 0:
                self._run_fabric_solve(prep, ticket, space, reducer,
                                       scorer_fn, fabric)
                return
            with self._lock:     # no fabric / no workers: the pool runs
                self.stats.bump("fabric_fallbacks", tenant=ticket.tenant)
        if ticket.shard_budget is not None:
            budget = ticket.shard_budget
        elif self.shard_budget is not None:
            budget = self.shard_budget
        else:                    # adaptive: sized from the enumeration
            budget = space.suggested_shards(self._max_workers)
            with self._lock:
                self.stats.bump("adaptive_budgets", tenant=ticket.tenant)
        qos_cap = self.tenants.resolve(ticket.tenant).qos.shard_budget
        if qos_cap is not None:
            # a low-QoS tenant's solve may not fan across the whole pool
            budget = min(budget, qos_cap)
        shards = space.shards(max(1, budget))
        state = _SolveState(prep=prep, ticket=ticket, reducer=reducer,
                            scorer_fn=scorer_fn,
                            started=time.perf_counter(),
                            remaining=len(shards))
        if not shards:   # empty candidate space: resolve immediately
            self._finish(ticket, prep, plan=self.planner.complete_solve(
                prep, [], 0.0, scorer_fn,
                verify=self._make_verifier(ticket.verify, ticket.tenant,
                                           trace_id=tid)))
            return
        with self._lock:
            self.stats.bump("shards_spawned", len(shards),
                            tenant=ticket.tenant)
        for shard in shards:
            self._enqueue((ticket.priority, next(self._seq),
                           _ShardJob(state=state, shard=shard), ticket))
        self._ensure_workers()

    def _run_fabric_solve(self, prep: PreparedRequest, ticket: PlanTicket,
                          space, reducer: SolutionReducer, scorer_fn,
                          fabric) -> None:
        """Drive one cold solve over the remote fabric, blocking this
        worker thread until the merged search drains.  Best-so-far
        promotions, server hot-swaps, and the final plan are identical
        to the pool path -- the same reducer merges either way."""
        started = time.perf_counter()
        with self._lock:
            self.stats.bump("fabric_solves", tenant=ticket.tenant)
        verifier = None
        if ticket.verify == "all":
            # certify every solution batch the untrusted workers stream
            # back; bad batches are rejected + requeued by the fabric
            from ..analysis.certify import make_batch_verifier
            verifier = make_batch_verifier(space)
        lease_cap = self.tenants.resolve(ticket.tenant).qos.fabric_lease_cap
        tr = self.tracer
        tid = ticket.trace_id if tr is not None else None
        try:
            t_fab = time.perf_counter()
            report = fabric.solve(space, reducer=reducer,
                                  verifier=verifier, lease_cap=lease_cap,
                                  trace=((tr, tid) if tid is not None
                                         else None))
            t_red = time.perf_counter()
            if tid is not None:
                tr.record(tid, "fabric-solve", t_fab, t_red,
                          leases=report.leases,
                          requeues=report.requeues,
                          workers_lost=report.workers_lost)
            plan = self.planner.complete_solve(
                prep, reducer.finalize(),
                time.perf_counter() - started, scorer_fn,
                verify=self._make_verifier(ticket.verify, ticket.tenant,
                                           trace_id=tid))
            if tid is not None:
                tr.record(tid, "reduce", t_red, time.perf_counter(),
                          promotions=reducer.promotions,
                          dedup_hits=reducer.dedup_hits)
            with self._lock:
                t = ticket.tenant
                self.stats.bump("fabric_leases", report.leases, tenant=t)
                self.stats.bump("fabric_requeues", report.requeues,
                                tenant=t)
                self.stats.bump("fabric_cut_broadcasts",
                                report.cut_broadcasts, tenant=t)
                self.stats.bump("fabric_workers_lost",
                                report.workers_lost, tenant=t)
                self.stats.bump("fabric_heartbeats",
                                getattr(report, "heartbeats", 0), tenant=t)
                self.stats.bump("cert_rejected", report.cert_rejected,
                                tenant=t)
                self.stats.bump("best_promotions", reducer.promotions,
                                tenant=t)
                self.stats.bump("dedup_hits", reducer.dedup_hits, tenant=t)
        except BaseException as e:
            self._finish(ticket, prep, error=e)
        else:
            self._finish(ticket, prep, plan=plan)

    def _run_shard(self, job: _ShardJob, ticket: PlanTicket) -> None:
        state = job.state
        tr = self.tracer
        tid = ticket.trace_id if tr is not None else None
        t_eval = time.perf_counter()
        try:
            for ev in evaluate(job.shard, gate=state.reducer):
                state.reducer.add(ev)
        except BaseException as e:
            if state.fail(e):
                self._finish(ticket, state.prep, error=e)
            return
        finally:
            if tid is not None:
                tr.record(tid, "shard-eval", t_eval, time.perf_counter(),
                          units=len(job.shard))
            with self._lock:
                self.stats.bump("shards_completed", tenant=ticket.tenant)
        if state.shard_finished():
            try:
                red = state.reducer
                t_red = time.perf_counter()
                plan = self.planner.complete_solve(
                    state.prep, red.finalize(),
                    time.perf_counter() - state.started, state.scorer_fn,
                    verify=self._make_verifier(state.ticket.verify,
                                               state.ticket.tenant,
                                               trace_id=tid))
                if tid is not None:
                    tr.record(tid, "reduce", t_red, time.perf_counter(),
                              promotions=red.promotions,
                              dedup_hits=red.dedup_hits)
                with self._lock:
                    self.stats.bump("best_promotions", red.promotions,
                                    tenant=ticket.tenant)
                    self.stats.bump("dedup_hits", red.dedup_hits,
                                    tenant=ticket.tenant)
            except BaseException as e:
                self._finish(ticket, state.prep, error=e)
            else:
                self._finish(ticket, state.prep, plan=plan)

    def _finish(self, ticket: PlanTicket, prep: PreparedRequest, *,
                plan: Optional[BankingPlan] = None,
                error: Optional[BaseException] = None) -> None:
        tr = self.tracer
        if tr is not None and ticket.trace_id is not None:
            if ticket._root_span is not None:
                tr.end(ticket._root_span,
                       status="error" if error is not None else "done")
                ticket._root_span = None
            tr.finish(ticket.trace_id,
                      status="error" if error is not None else "ok",
                      anomaly="error" if error is not None else None)
        if error is not None:
            with self._lock:
                self.stats.bump("errors", tenant=ticket.tenant)
            ticket._fail(error)
            # the reducer stays attached: a failed search's partial best
            # remains servable through best_so_far()
        else:
            with self._lock:
                self.stats.bump("solved", tenant=ticket.tenant)
            ticket._resolve(plan)   # done flips first: best_so_far now
            ticket._release_reducer()  # reads the plan, so drop the search
            if self.telemetry is not None:
                self.telemetry.register(prep, plan)
        with self._lock:
            key = (prep.signature, prep.scorer_name)
            if self._inflight.get(key) is ticket:
                del self._inflight[key]
        if ticket._admitted:
            self._release_admission(ticket.tenant)

    def _release_admission(self, tenant: str) -> None:
        """Free the finished solve's in-flight slot and queue whatever
        the tenant's deferral backlog can now admit (oldest first, at
        each deferred ticket's kept priority)."""
        tr = self.tracer
        for prep2, t2 in self._admission.release(tenant):
            t2.deferred = False
            t2._admitted = True
            if t2.status == "deferred":
                t2.status = "queued"
            deferred_at = t2._deferred_at
            now = time.perf_counter()
            if deferred_at is not None:
                t2.deferred_ms += (now - deferred_at) * 1e3
                t2._deferred_at = None
                if tr is not None and t2.trace_id is not None:
                    tr.record(t2.trace_id, "deferred-wait", deferred_at,
                              now, tenant=t2.tenant)
            t2._queued_at = now
            self.stats.bump("queued", tenant=t2.tenant)
            self._enqueue((t2.priority, next(self._seq), prep2, t2))
            try:
                self._ensure_workers()
            except RuntimeError:
                # shut down mid-release: the entry stays queued; the
                # drained workers' sentinels already passed it by, and
                # callers of a shut-down service hold their own tickets
                pass

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every queued problem has been solved -- deferred
        admissions included -- (or fail the wait after ``timeout``
        seconds).  Returns True when drained."""
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while self._queue.unfinished_tasks or self._admission.pending():
            if deadline is not None and time.monotonic() > deadline:
                return False
            time.sleep(0.002)
        return True

    def shutdown(self, wait: bool = True) -> None:
        with self._lock:
            if self._shutdown:
                return
            self._shutdown = True
            threads = list(self._threads)
        for _ in threads:
            self._queue.put((float("inf"), next(self._seq), _SENTINEL,
                             _SENTINEL))
        if wait:
            for t in threads:
                t.join(timeout=5.0)

    def __enter__(self) -> "PlanService":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()


# ---------------------------------------------------------------------------
# Process-wide default service (serving hot path, sharding bridge)
# ---------------------------------------------------------------------------

_DEFAULT_SERVICE: Optional[PlanService] = None
_DEFAULT_LOCK = threading.Lock()


def default_service() -> PlanService:
    """The shared service over :func:`default_planner` -- what the serving
    runtime and the sharding bridge submit through."""
    global _DEFAULT_SERVICE
    with _DEFAULT_LOCK:
        if _DEFAULT_SERVICE is None:
            _DEFAULT_SERVICE = default_planner().service
        return _DEFAULT_SERVICE


__all__ = [
    "JointTicket",
    "PlanService",
    "PlanTicket",
    "ServiceStats",
    "StaleWhileRevalidate",
    "default_service",
]
