"""Fault-tolerant checkpointing.

Design (multi-thousand-node posture):

* **Logical state**: checkpoints store a name->array dict (params flattened
  by pytree path) + metadata (step, data-iterator state, config hash).
  Restore re-shards onto WHATEVER mesh the restoring job has -- elastic
  scaling is a restore with a different device set, nothing more.
* **Atomicity**: write to ``<dir>/tmp.<step>/``, fsync, then ``os.rename``
  to ``step_<n>`` -- a crash mid-save never corrupts the latest checkpoint.
* **Async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes in a background thread so the step loop is never blocked.
* **GC**: keep the newest ``keep`` checkpoints.

Serialization is npz-per-shard-group (numpy, no external deps).  On a real
cluster each host writes only the shards it owns (``process_index`` naming
is already in place); in this single-process container that is one file.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/{i}"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k),
                                f"{prefix}/{k}" if prefix else k))
    else:
        out[prefix] = tree
    return out


def _unflatten_into(template: Any, flat: Dict[str, Any], prefix: str = ""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}/{k}" if prefix else str(k))
                for k, v in template.items()}
    if hasattr(template, "_fields"):
        vals = [_unflatten_into(getattr(template, k), flat,
                                f"{prefix}/{k}" if prefix else k)
                for k in template._fields]
        return type(template)(*vals)
    if isinstance(template, (list, tuple)):
        return type(template)(
            _unflatten_into(v, flat, f"{prefix}/{i}")
            for i, v in enumerate(template))
    return flat[prefix]


@dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                if os.path.exists(os.path.join(self.directory, name, "META")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ----------------------------------------------------------------
    def _write(self, step: int, host_state: Dict[str, np.ndarray],
               meta: Dict[str, Any]):
        tmp = os.path.join(self.directory, f"tmp.{step}.{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        shard = os.path.join(tmp, f"shard_{jax.process_index():05d}.npz")
        np.savez(shard, **host_state)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        # fsync the directory contents before the atomic publish
        for name in os.listdir(tmp):
            fd = os.open(os.path.join(tmp, name), os.O_RDONLY)
            os.fsync(fd)
            os.close(fd)
        open(os.path.join(tmp, "META"), "w").write("ok")
        final = self._step_dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # clean stale tmp dirs from crashed saves
        for name in os.listdir(self.directory):
            if name.startswith("tmp."):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)

    def save(self, step: int, state: Any, extra_meta: Optional[Dict] = None,
             block: bool = True):
        """Snapshot to host then write (async unless block=True)."""
        flat = _flatten(state)
        host = {}
        for k, v in flat.items():
            a = np.asarray(v)
            if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
                # numpy npz cannot store bfloat16: stash as uint16 + tag
                host[k + "::bf16"] = a.view(np.uint16)
            else:
                host[k] = a
        meta = {"step": step, "time": time.time(),
                "keys": sorted(host.keys()), **(extra_meta or {})}
        self.wait()
        if block:
            self._write(step, host, meta)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore ---------------------------------------------------------------
    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, Dict[str, Any]]:
        """Restore into ``template``'s structure; if ``shardings`` (a pytree
        of NamedSharding matching template) is given, place shards onto the
        *current* mesh -- this is the elastic-rescale path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint found")
        d = self._step_dir(step)
        flat: Dict[str, np.ndarray] = {}
        import ml_dtypes
        for name in sorted(os.listdir(d)):
            if name.startswith("shard_"):
                with np.load(os.path.join(d, name)) as z:
                    for k in z.files:
                        if k.endswith("::bf16"):
                            flat[k[:-6]] = z[k].view(ml_dtypes.bfloat16)
                        else:
                            flat[k] = z[k]
        meta = json.load(open(os.path.join(d, "meta.json")))
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        else:
            state = jax.tree.map(jax.numpy.asarray, state)
        return state, meta
