"""MoE dispatch Pallas kernel: the token->expert crossbar.

Expert routing is the paper's banking problem with experts as banks and
capacity as ports (DESIGN.md).  After the router + sort (ops.py computes
``slot_token``: for every (expert, capacity) slot, which token fills it, or
T for empty), this kernel materializes the (E*C, D) expert input buffer --
the physical crossbar datapath whose fan-out the paper's FO metric sizes.

Grid: one step per slot row; a scalar-prefetch index_map selects the source
token tile, so the gather is pure data movement (like banked_gather, the
'resolution arithmetic' runs on the scalar core).  Empty slots read a
zeros row appended to the token array (index T) -- branchless padding.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dispatch_kernel(slot_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]


def moe_dispatch(x_padded: jax.Array, slot_token: jax.Array, *,
                 interpret=False) -> jax.Array:
    """x_padded: (T+1, D) tokens with a zeros row at index T.
    slot_token: (E*C,) int32 source token per slot (T = empty).
    Returns (E*C, D) expert input buffer.
    """
    S, D = slot_token.shape[0], x_padded.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, D), lambda s, slot_ref: (slot_ref[s], 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda s, slot_ref: (s, 0)),
    )
    return pl.pallas_call(
        _dispatch_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, D), x_padded.dtype),
        interpret=interpret,
    )(slot_token, x_padded)


def moe_combine(y_buf: jax.Array, slot_token: jax.Array, weights: jax.Array,
                T: int) -> jax.Array:
    """Weighted scatter-add back to tokens (pure jnp: segment-sum is already
    optimal on TPU; the crossbar direction that needs a kernel is dispatch).

    y_buf: (E*C, D); slot_token: (E*C,) in [0, T]; weights: (E*C,).
    """
    contrib = y_buf.astype(jnp.float32) * weights[:, None]
    out = jnp.zeros((T + 1, y_buf.shape[1]), jnp.float32)
    out = out.at[slot_token].add(contrib)
    return out[:T]
