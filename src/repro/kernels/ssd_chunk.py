"""SSD chunk kernel (Mamba2 state-space duality) in Pallas.

Computes one chunk of the SSD recurrence for a block of heads:

    y_intra = ((C B^T) .* L) (dt .* X)        -- Q x Q matmul form (MXU)
    y_inter = diag(exp(cum)) C S_prev^T
    S_new   = exp(cum_Q) S_prev + B^T diag(exp(cum_Q - cum) dt) X

Grid: (batch, heads) -- each instance owns one (Q, P) x (Q, N) working set.
VMEM: Q=256, N=128, P=64 fp32 => CB^T (256x256) 256 KB + operands ~0.5 MB,
well inside VMEM.  The inter-chunk scan (carrying S) stays in JAX
(models/ssm.py); the kernel is the per-chunk compute hot spot.

Oracle: ref.ssd_chunk_reference == one scan step of models.ssm.ssd_chunked.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._compat import tpu_compiler_params


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, cum_ref, s_ref,
                y_ref, snew_ref):
    # blocks: x (1,1,Q,P), dt/cum (1,1,Q), b/c (1,Q,N), s (1,1,P,N)
    x = x_ref[0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (Q,)
    bm = b_ref[0].astype(jnp.float32)          # (Q, N)
    cm = c_ref[0].astype(jnp.float32)          # (Q, N)
    cum = cum_ref[0, 0].astype(jnp.float32)    # (Q,)
    s_prev = s_ref[0, 0].astype(jnp.float32)   # (P, N)

    Q = x.shape[0]
    rel = cum[:, None] - cum[None, :]
    causal = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    rel = jnp.where(causal, rel, -jnp.inf)  # mask before exp
    Lmat = jnp.exp(rel)
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    W = scores * Lmat                          # (Q, Q)
    xdt = x * dt[:, None]                      # (Q, P)
    y_intra = jax.lax.dot(W, xdt, preferred_element_type=jnp.float32)
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, s_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)    # (Q, P)
    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    decay_to_end = jnp.exp(cum[-1] - cum)      # (Q,)
    # S_new = exp(cum_Q) * S_prev + (xdt * decay)^T B   -> (P, N)
    s_add = jax.lax.dot_general(
        xdt * decay_to_end[:, None], bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    snew_ref[0, 0] = jnp.exp(cum[-1]) * s_prev + s_add


def ssd_chunk(x, dt, bm, cm, cum, s_prev, *, interpret=False):
    """One SSD chunk for all (batch, head) pairs.

    x: (B, H, Q, P); dt/cum: (B, H, Q); bm/cm: (B, Q, N);
    s_prev: (B, H, P, N).  Returns (y (B, H, Q, P), s_new (B, H, P, N)).
    """
    B, H, Q, P = x.shape
    N = bm.shape[-1]
    y, s_new = pl.pallas_call(
        _ssd_kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, dt, bm, cm, cum, s_prev)
    return y, s_new
