"""Banked gather: the paper's bank-resolution circuit as a Pallas kernel.

The memory is stored *bank-major* -- physical layout (N_banks, bank_volume,
row_width) produced by a BankingSolution -- and the kernel gathers logical
rows by evaluating the bank-address / bank-offset equations (Eq. 1-2) with
the Sec-3.4 strength-reduced arithmetic.

TPU adaptation of the circuit: the BA/BO arithmetic runs inside the
*index_map* of a scalar-prefetch BlockSpec -- the same place an FPGA would
put the resolution logic, i.e. in front of the memory. Each grid step
copies one logical row's (1, row) tile from HBM to VMEM based on the
prefetched index; Crandall/NAF rewrites shorten the scalar index path
exactly as they eliminate DSPs on the FPGA (the TPU scalar core has no
integer divide either -- XLA emits long multiply sequences for /C and %C).

Used by the paged-KV cache (pages = banks) and as the embedding-row gather.
"""

from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.solver import BankingSolution
from ..core.transforms import Node, lower_jnp


def resolution_fns(sol: BankingSolution) -> Tuple[Callable, Callable]:
    """(ba_fn, bo_fn) over a flat logical address, from the solution graphs.

    For 1-D memories the graphs take x0 = flat address directly; for n-D the
    caller decomposes the address (row-major) before calling.
    """
    ba_graph = sol.resolution_ba
    if isinstance(ba_graph, tuple):  # multidim: fold per-dim BAs row-major
        bas = [lower_jnp(g) for g in ba_graph]
        Ns = sol.geometry.Ns

        def ba_fn(*xs):
            out = None
            for f, n in zip(bas, Ns):
                b = f(**{f"x{i}": x for i, x in enumerate(xs)})
                out = b if out is None else out * n + b
            return out
    else:
        f = lower_jnp(ba_graph)

        def ba_fn(*xs):
            return f(**{f"x{i}": x for i, x in enumerate(xs)})

    g = lower_jnp(sol.resolution_bo)

    def bo_fn(*xs):
        return g(**{f"x{i}": x for i, x in enumerate(xs)})

    return ba_fn, bo_fn


def _gather_kernel(idx_ref, table_ref, o_ref):
    # the entire gather is index-map driven; the body is a VMEM copy
    o_ref[...] = table_ref[0]


def banked_gather(table: jax.Array, indices: jax.Array,
                  ba_fn: Callable, bo_fn: Callable, *,
                  interpret=False) -> jax.Array:
    """table: (N_banks, bank_volume, D) bank-major storage.
    indices: (T,) int32 flat logical addresses (1-D memory view).
    Returns (T, D) gathered rows.

    The bank-resolution arithmetic (ba_fn/bo_fn, built from the transformed
    op graphs) executes in the BlockSpec index_map on the prefetched index
    scalars -- one (1, D) row tile is streamed per grid step.
    """
    T = indices.shape[0]
    N, V, D = table.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, 1, D),
                         lambda t, idx_ref: (ba_fn(idx_ref[t]),
                                             bo_fn(idx_ref[t]), 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda t, idx_ref: (t, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, D), table.dtype),
        interpret=interpret,
    )(indices, table)


def pack_banked(flat: jax.Array, sol: BankingSolution) -> jax.Array:
    """Layout conversion: logical (A, D) rows -> bank-major (N, V, D).

    Pure-jnp scatter using the *reference* (untransformed) BA/BO equations
    from the geometry object -- tests assert the kernel's transformed
    arithmetic agrees with this layout.
    """
    A, D = flat.shape
    geo = sol.geometry
    dims = sol.memory.dims
    addrs = jnp.arange(A)
    if sol.kind == "flat":
        import numpy as np
        ba = np.array([geo.bank_address((int(a),)) for a in range(A)])
        bo = np.array([geo.bank_offset((int(a),), dims) for a in range(A)])
        nb = geo.N
    else:
        import numpy as np
        Ns = geo.Ns
        ba_t = [geo.bank_address((int(a),)) for a in range(A)]
        ba = np.array([b[0] for b in ba_t])
        bo = np.array([geo.bank_offset((int(a),), dims) for a in range(A)])
        nb = geo.num_banks
    V = int(sol.bank_volume)
    table = jnp.zeros((nb, V, D), flat.dtype)
    return table.at[ba, bo].set(flat)
