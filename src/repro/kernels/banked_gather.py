"""Banked gather/scatter: the paper's bank-resolution circuit as Pallas
kernels.

The memory is stored *bank-major* -- physical layout (N_banks, bank_volume,
row_width) owned by a ``CompiledBankingPlan`` -- and the kernel gathers
logical rows by evaluating the bank-address / bank-offset equations
(Eq. 1-2) with the Sec-3.4 strength-reduced arithmetic.

TPU adaptation of the circuit: the BA/BO arithmetic runs inside the
*index_map* of a scalar-prefetch BlockSpec -- the same place an FPGA would
put the resolution logic, i.e. in front of the memory. Each grid step
copies one logical row's (1, row) tile from HBM to VMEM based on the
prefetched index; Crandall/NAF rewrites shorten the scalar index path
exactly as they eliminate DSPs on the FPGA (the TPU scalar core has no
integer divide either -- XLA emits long multiply sequences for /C and %C).

This module is the *raw kernel only*: it takes the already-compiled
``ba_fn`` / ``bo_fn`` resolution callables.  Lowering a banking scheme to
those callables (and to the pack/unpack layout converters) is the job of
``repro.core.artifact.CompiledBankingPlan`` -- use ``plan.compile()`` and
call ``artifact.gather(table, rows)`` instead of binding this directly.

Used by the paged-KV cache (pages = banks) and as the embedding-row gather.
"""

from __future__ import annotations

from typing import Callable

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, table_ref, o_ref):
    # the entire gather is index-map driven; the body is a VMEM copy
    o_ref[...] = table_ref[0]


def banked_gather(table: jax.Array, indices: jax.Array,
                  ba_fn: Callable, bo_fn: Callable, *,
                  interpret=False) -> jax.Array:
    """table: (N_banks, bank_volume, D) bank-major storage.
    indices: (T,) int32 flat logical addresses.
    Returns (T, D) gathered rows.

    The bank-resolution arithmetic (ba_fn/bo_fn, the compiled artifact's
    transformed op graphs) executes in the BlockSpec index_map on the
    prefetched index scalars -- one (1, D) row tile is streamed per grid
    step.
    """
    T = indices.shape[0]
    N, V, D = table.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, 1, D),
                         lambda t, idx_ref: (ba_fn(idx_ref[t]),
                                             bo_fn(idx_ref[t]), 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda t, idx_ref: (t, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, D), table.dtype),
        interpret=interpret,
    )(indices, table)


def _scatter_kernel(idx_ref, v_ref, t_ref, o_ref):
    # like the gather, the scatter is index-map driven: each grid step
    # copies one value row into the resolved (bank, offset) slot
    o_ref[0, 0, :] = v_ref[0]


def banked_scatter(table: jax.Array, indices: jax.Array, values: jax.Array,
                   ba_fn: Callable, bo_fn: Callable, *,
                   interpret=False) -> jax.Array:
    """Write logical rows into bank-major storage -- the write-path
    analogue of :func:`banked_gather`.

    table: (N_banks, bank_volume, D); indices: (T,) flat logical
    addresses; values: (T, D) replacement rows.  Returns the updated
    table; the input buffer is donated (``input_output_aliases``), so
    untouched slots carry over and duplicate indices resolve
    last-write-wins (sequential grid order).  The BA/BO resolution
    arithmetic runs in the out-spec index map -- in front of the memory,
    exactly like the gather.
    """
    T = indices.shape[0]
    N, V, D = table.shape
    out_spec = pl.BlockSpec((1, 1, D),
                            lambda t, idx_ref: (ba_fn(idx_ref[t]),
                                                bo_fn(idx_ref[t]), 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, D), lambda t, idx_ref: (t, 0)),
            out_spec,            # aliased table input mirrors the output
        ],
        out_specs=out_spec,
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
        input_output_aliases={2: 0},     # operand order: idx, values, table
        interpret=interpret,
    )(indices, values, table)


def _scatter_elem_kernel(idx_ref, col_ref, v_ref, t_ref, o_ref):
    o_ref[0, 0, 0] = v_ref[0]


def banked_scatter_elems(table: jax.Array, indices: jax.Array,
                         cols: jax.Array, values: jax.Array,
                         ba_fn: Callable, bo_fn: Callable, *,
                         interpret=False) -> jax.Array:
    """Scatter single elements: ``table[ba(i), bo(i), cols[t]] = values[t]``.

    The column index is prefetched alongside the logical address, so a
    batch of per-slot token-record writes (the serving runtime's decode
    tick) lands in ONE kernel launch without read-modify-writing whole
    rows.  Same donation / last-write-wins semantics as
    :func:`banked_scatter`.
    """
    T = indices.shape[0]
    out_spec = pl.BlockSpec((1, 1, 1),
                            lambda t, idx_ref, col_ref: (
                                ba_fn(idx_ref[t]), bo_fn(idx_ref[t]),
                                col_ref[t]))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1,), lambda t, idx_ref, col_ref: (t,)),
            out_spec,
        ],
        out_specs=out_spec,
    )
    return pl.pallas_call(
        _scatter_elem_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(table.shape, table.dtype),
        input_output_aliases={3: 0},     # idx, cols, values, table
        interpret=interpret,
    )(indices, cols, values, table)
