"""Banked gather: the paper's bank-resolution circuit as a Pallas kernel.

The memory is stored *bank-major* -- physical layout (N_banks, bank_volume,
row_width) owned by a ``CompiledBankingPlan`` -- and the kernel gathers
logical rows by evaluating the bank-address / bank-offset equations
(Eq. 1-2) with the Sec-3.4 strength-reduced arithmetic.

TPU adaptation of the circuit: the BA/BO arithmetic runs inside the
*index_map* of a scalar-prefetch BlockSpec -- the same place an FPGA would
put the resolution logic, i.e. in front of the memory. Each grid step
copies one logical row's (1, row) tile from HBM to VMEM based on the
prefetched index; Crandall/NAF rewrites shorten the scalar index path
exactly as they eliminate DSPs on the FPGA (the TPU scalar core has no
integer divide either -- XLA emits long multiply sequences for /C and %C).

This module is the *raw kernel only*: it takes the already-compiled
``ba_fn`` / ``bo_fn`` resolution callables.  Lowering a banking scheme to
those callables (and to the pack/unpack layout converters) is the job of
``repro.core.artifact.CompiledBankingPlan`` -- use ``plan.compile()`` and
call ``artifact.gather(table, rows)`` instead of binding this directly.

Used by the paged-KV cache (pages = banks) and as the embedding-row gather.
"""

from __future__ import annotations

from typing import Callable

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, table_ref, o_ref):
    # the entire gather is index-map driven; the body is a VMEM copy
    o_ref[...] = table_ref[0]


def banked_gather(table: jax.Array, indices: jax.Array,
                  ba_fn: Callable, bo_fn: Callable, *,
                  interpret=False) -> jax.Array:
    """table: (N_banks, bank_volume, D) bank-major storage.
    indices: (T,) int32 flat logical addresses.
    Returns (T, D) gathered rows.

    The bank-resolution arithmetic (ba_fn/bo_fn, the compiled artifact's
    transformed op graphs) executes in the BlockSpec index_map on the
    prefetched index scalars -- one (1, D) row tile is streamed per grid
    step.
    """
    T = indices.shape[0]
    N, V, D = table.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, 1, D),
                         lambda t, idx_ref: (ba_fn(idx_ref[t]),
                                             bo_fn(idx_ref[t]), 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda t, idx_ref: (t, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, D), table.dtype),
        interpret=interpret,
    )(indices, table)
