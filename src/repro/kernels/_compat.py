"""Pallas API compatibility shims.

``jax.experimental.pallas.tpu`` renamed ``TPUCompilerParams`` to
``CompilerParams`` across JAX releases; resolve whichever this JAX ships.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

TPU_COMPILER_PARAMS = getattr(
    pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def tpu_compiler_params(**kwargs):
    """Build the TPU compiler-params struct under either JAX spelling."""
    return TPU_COMPILER_PARAMS(**kwargs)
