"""Pure-jnp oracles for every Pallas kernel (shape-swept in tests)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mha_reference(q, k, v, *, causal=True, window=0, kv_len=None, scale=None):
    """q: (BH, Sq, D), k/v: (BH, Sk, D)."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    if kv_len is not None:
        mask &= k_pos < kv_len
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def banked_gather_reference(flat_rows, indices):
    """Gather straight from the logical (A, D) array."""
    return flat_rows[indices]


def moe_dispatch_reference(x_padded, slot_token):
    return x_padded[slot_token]


def ssd_chunk_reference(x, dt, bm, cm, cum, s_prev):
    """One SSD chunk, direct form.  Shapes as kernels.ssd_chunk."""
    B, H, Q, P = x.shape
    rel = cum[..., :, None] - cum[..., None, :]          # (B, H, Q, Q)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    rel = jnp.where(causal, rel, -jnp.inf)  # mask before exp (grad safety)
    Lmat = jnp.exp(rel)
    scores = jnp.einsum("bin,bjn->bij", cm.astype(jnp.float32),
                        bm.astype(jnp.float32))
    W = scores[:, None] * Lmat                           # (B, H, Q, Q)
    xdt = x.astype(jnp.float32) * dt[..., None]
    y_intra = jnp.einsum("bhij,bhjp->bhip", W, xdt)
    y_inter = jnp.exp(cum)[..., None] * jnp.einsum(
        "bin,bhpn->bhip", cm.astype(jnp.float32), s_prev)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)          # (B, H, Q)
    s_add = jnp.einsum("bhqp,bqn,bhq->bhpn", xdt, bm.astype(jnp.float32),
                       decay_to_end)
    s_new = jnp.exp(cum[..., -1])[..., None, None] * s_prev + s_add
    return y_intra + y_inter, s_new
