"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU so the kernel bodies execute (and are
tested) on CPU; on TPU the same calls compile through Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.artifact import as_compiled
from .flash_attention import flash_attention
from .moe_dispatch import moe_combine, moe_dispatch
from .ssd_chunk import ssd_chunk


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def mha(q, k, v, *, causal=True, window=0, kv_len=None,
        block_q=128, block_k=128, interpret=None):
    """Multi-head attention via the flash kernel.

    q: (B, Sq, H, Dh); k/v: (B, Sk, Hkv, Dh).  GQA is folded: each kv head
    serves H//Hkv query heads through the leading grid axis.
    """
    interpret = _default_interpret() if interpret is None else interpret
    B, Sq, H, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, Dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, Sk, Dh)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, Sk, Dh)
    out = flash_attention(qf, kf, vf, causal=causal, window=window,
                          kv_len=kv_len, block_q=block_q, block_k=block_k,
                          interpret=interpret)
    return out.reshape(B, H, Sq, Dh).transpose(0, 2, 1, 3)


def gather_banked(table, indices, compiled, *, interpret=None):
    """Gather logical rows from a bank-major table through a compiled
    banking artifact (``plan.compile()``); its strength-reduced resolution
    arithmetic runs in the Pallas index map (see kernels/banked_gather.py).

    ``indices`` may be a flat ``(T,)`` vector or a stacked ``(T, R)``
    matrix of row-sets (one decode tick's reads for every active
    sequence): the batched form issues ONE ``pallas_call`` for the whole
    tick and returns ``(T, R, D)``.

    Accepts a ``CompiledBankingPlan`` or a ``BankingPlan``; passing a raw
    ``BankingSolution`` still works but is deprecated."""
    interpret = _default_interpret() if interpret is None else interpret
    return as_compiled(compiled).gather(table, indices, interpret=interpret)


def scatter_banked(table, indices, values, compiled, *, col=None,
                   interpret=None):
    """Write logical rows into a bank-major table through a compiled
    banking artifact -- the scatter analogue of :func:`gather_banked`.

    ``indices`` is a flat ``(T,)`` vector of logical addresses.  With
    ``col=None``, ``values`` is ``(T, D)`` replacement rows; with
    ``col`` a ``(T,)`` vector of column indices, ``values`` is ``(T,)``
    scalars -- the serving runtime's batched per-slot token-record
    write.  Returns the updated table; the resolution arithmetic runs in
    the Pallas out-spec index map (see kernels/banked_gather.py)."""
    interpret = _default_interpret() if interpret is None else interpret
    return as_compiled(compiled).scatter(table, indices, values, col=col,
                                         interpret=interpret)


def pack_banked(flat, compiled):
    """Layout conversion: logical (A, D) rows -> bank-major (N, V, D) per
    the compiled artifact's physical layout (reference Eq. 1-2 placement --
    tests assert the kernel's transformed arithmetic agrees with it)."""
    return as_compiled(compiled).pack(flat)


def dispatch(x, slot_token, *, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    x_padded = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)])
    return moe_dispatch(x_padded, slot_token, interpret=interpret)


def ssd(x, dt, bm, cm, cum, s_prev, *, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return ssd_chunk(x, dt, bm, cm, cum, s_prev, interpret=interpret)


__all__ = ["dispatch", "gather_banked", "mha", "moe_combine", "pack_banked",
           "scatter_banked", "ssd"]
