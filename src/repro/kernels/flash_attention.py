"""Flash attention Pallas kernel (TPU target, BlockSpec VMEM tiling).

Grid: (batch*heads, q_blocks, k_blocks) with the k dimension innermost and
marked 'arbitrary' so the VMEM scratch accumulators (m, l, acc) carry
across k steps -- the online-softmax recurrence.  Causal and sliding-window
masks are applied from absolute positions derived from program ids; GQA is
handled in ops.py by folding the q-head group into the leading axis so each
kernel instance reads one kv head.

Block shapes default to (128, 128): MXU-aligned (128x128 systolic array),
and the VMEM working set per step is
q(128xD) + k/v(128xD) + acc(128xD) + scores(128x128) floats -- ~0.5 MB for
D=256, comfortably inside the ~16 MB/core VMEM budget.

Validated in interpret mode against ``ref.mha_reference`` (pure jnp) over a
shape/dtype sweep in tests/test_kernels.py.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, kv_len: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)
    nkb = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)                  # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    q_pos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kb == nkb - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, kv_len=None,
                    scale=None, block_q=128, block_k=128,
                    interpret=False):
    """q: (BH, Sq, D), k/v: (BH, Sk, D) -- heads pre-folded into batch.

    Sliding-window masking uses absolute positions (q row i attends to
    [i-window+1, i]).  ``kv_len`` masks a padded KV buffer (decode).
    """
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq = -(-Sq // block_q)
    nk = -(-Sk // block_k)
    kv_len = Sk if kv_len is None else kv_len

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_len=kv_len)

    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out
