"""Distributed-optimization collectives.

``compressed_psum``: int8-quantized gradient all-reduce with error-feedback
residuals.  At 1000+ nodes the pod-crossing gradient all-reduce is the
slowest collective in the step; quantizing to int8 cuts the inter-pod bytes
4x (bf16) / 8x (f32).  Error feedback keeps the *accumulated* quantization
error bounded: the residual of each step is added back before the next
quantization, so the compressed SGD trajectory tracks the exact one (Seide
et al.; Karimireddy et al.).

Implemented with per-tensor max-abs scaling inside ``shard_map`` so the
all-reduce really moves int8 on the wire (XLA would otherwise upcast).
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_leaf(x: jax.Array, residual: jax.Array, axis: str
                         ) -> Tuple[jax.Array, jax.Array]:
    """One error-feedback compressed all-reduce step for a single tensor.
    Must run inside shard_map with `axis` unmapped on x."""
    x = x + residual
    q, scale = quantize_int8(x)
    # int32 sum of int8 payloads (the wire format is the int8 tensor +
    # one f32 scale; psum of the scaled ints preserves exactness per shard)
    summed = jax.lax.psum(q.astype(jnp.int32).astype(jnp.float32) * scale,
                          axis_name=axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name=axis)
    mean = summed / n
    new_residual = x - dequantize_int8(q, scale)
    return mean, new_residual


def compressed_grad_mean(grads: Any, residuals: Any, mesh: Mesh,
                         axis: str = "data") -> Tuple[Any, Any]:
    """Error-feedback int8 mean of gradients over a mesh axis.

    grads/residuals: pytrees replicated over `axis` (i.e. per-shard partial
    gradients).  Returns (mean_grads, new_residuals).
    """
    from jax.experimental.shard_map import shard_map

    def one(g, r):
        fn = shard_map(
            functools.partial(compressed_psum_leaf, axis=axis),
            mesh=mesh,
            in_specs=(P(*([None] * g.ndim)), P(*([None] * g.ndim))),
            out_specs=(P(*([None] * g.ndim)), P(*([None] * g.ndim))),
        )
        return fn(g, r)

    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out_g, out_r = [], []
    for g, r in zip(flat_g, flat_r):
        mg, nr = one(g, r)
        out_g.append(mg)
        out_r.append(nr)
    return jax.tree.unflatten(tree, out_g), jax.tree.unflatten(tree, out_r)
