"""Pipeline parallelism (GPipe schedule) via shard_map + collective_permute.

An optional distribution mode: layers are split into S contiguous stages
along a 'stage' mesh axis; microbatches stream through with the classic
(M + S - 1)-tick schedule.  Activations hop stages with
``jax.lax.ppermute`` -- the TPU-native point-to-point.

This is deliberately generic: ``stage_fn(stage_params, x)`` applies one
stage's layer stack; the host model provides stacked per-stage params
(reshape of the scan-stacked (L, ...) arrays into (S, L/S, ...)).

Used by examples/pipeline_train.py and tests/test_pipeline.py; the main
dry-run meshes use DP x TP (the pod axis is pure DP), PP is the documented
alternative for slower inter-pod links (DESIGN.md Sec 5).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn: Callable[[Any, jax.Array], jax.Array],
                   stage_params: Any,       # leaves (S, ...) -- stage-major
                   x: jax.Array,            # (M, mb, ...) microbatched input
                   mesh: Mesh, axis: str = "stage") -> jax.Array:
    """Run a GPipe pipeline over mesh axis `axis`.  Returns (M, mb, ...)."""
    S = mesh.shape[axis]
    M = x.shape[0]
    n_ticks = M + S - 1

    def per_stage(params, xs):
        # params: (1, ...) this stage's slice; xs: (M, mb, ...) only stage 0
        # consumes real inputs, everything else starts from zeros.
        params = jax.tree.map(lambda a: a[0], params)
        sid = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        buf = jnp.zeros(mb_shape, xs.dtype)           # activation in flight
        outs = jnp.zeros((M,) + mb_shape, xs.dtype)   # stage S-1 collects

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if t < M), others use incoming
            feed = jnp.where(t < M, xs[jnp.minimum(t, M - 1)], 0.0)
            h_in = jnp.where(sid == 0, feed, buf)
            h_out = stage_fn(params, h_in)
            # pass to next stage
            perm = [(i, i + 1) for i in range(S - 1)]
            buf_next = jax.lax.ppermute(h_out, axis, perm)
            # last stage emits microbatch t - (S - 1)
            emit_idx = t - (S - 1)
            emit = jnp.logical_and(sid == S - 1, emit_idx >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, h_out.astype(o.dtype), jnp.maximum(emit_idx, 0), 0),
                lambda o: o,
                outs)
            return (buf_next, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        # only the last stage ever writes outs; psum == broadcast to all
        outs = jax.lax.psum(outs, axis)
        return outs

    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()),     # params stage-sharded, x replicated
        out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, x)
