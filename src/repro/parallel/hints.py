"""Named activation-sharding hints.

Models call ``hint(x, "residual")`` at layout-critical points; the trainer /
dry-run installs a policy mapping hint names to PartitionSpecs for the
current (arch x shape x mesh) cell.  Without a policy the calls are no-ops,
so smoke tests and single-device runs are untouched.

This is the activation-side twin of the parameter banking bridge: the
policy for each cell is part of the solution the Perf loop iterates on
(EXPERIMENTS.md records before/after per hint change).
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec

_LOCAL = threading.local()


def _policy() -> Optional[Dict[str, PartitionSpec]]:
    return getattr(_LOCAL, "policy", None)


@contextmanager
def sharding_policy(policy: Dict[str, PartitionSpec]):
    old = _policy()
    _LOCAL.policy = policy
    try:
        yield
    finally:
        _LOCAL.policy = old


def hint(x, name: str):
    pol = _policy()
    if pol is None:
        return x
    spec = pol.get(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def policy_value(name: str, default=None):
    """Non-spec policy entries (e.g. '__mesh__' for shard_map impls)."""
    pol = _policy()
    if pol is None:
        return default
    return pol.get(name, default)
