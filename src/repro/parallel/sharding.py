"""Banking-solver -> PartitionSpec bridge.

Device-level banking (DESIGN.md Sec 2): a tensor accessed by the unrolled
lanes of data/tensor/expert-parallel execution is an array accessed by a
concurrent access group; mesh axes are banks.  For each tensor *role* we
pose the corresponding 1-D banking problem to the solver -- lanes = mesh
axis size, array dim = the candidate partition dim -- and accept the
partition dimension whose hyperplane (N = axis size, B = 1, alpha = unit)
is conflict-free with fan-out 1 (each lane owns one shard: no crossbar =
no collective on the access path).  Dims that cannot bank conflict-free
(e.g. 8 kv heads across a 16-way axis) fall back to the next candidate dim
-- precisely the paper's 'many valid geometries, pick the cheap one'.

The result is memoized per (role, dims, axis size) and the underlying
banking problems are **submitted through the shared PlanService** (the
same submit -> ticket front door the serving runtime uses, so lane
problems share its plan store and in-flight dedup); the qualifying scheme
comes back as a **compiled artifact** (``core.artifact.lane_compile``)
whose ``to_partition_spec`` supplies the mesh-axis placement -- no
geometry reverse-engineering here.  The same compiled artifacts drive the
Pallas banked-gather kernel, so device-level and kernel-level banking
share one solver *and* one lowering.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeConfig
from ..core.artifact import CompiledBankingPlan, lane_compile
from ..core.controller import AccessDecl, Counter, Ctrl, Program, Sched
from ..core.polytope import Affine, MemorySpec
from ..core.service import default_service
from ..core.solver import SolverOptions


@functools.lru_cache(maxsize=None)
def lane_artifact(dim_size: int, lanes: int) -> Optional[CompiledBankingPlan]:
    """Compiled conflict-free FO=1 lane banking of `dim_size`, or None.

    Poses the canonical strided access problem to the banking planner:
    lanes read disjoint contiguous blocks.  Equivalent to lanes | dim
    (block scheme), but answered by the solver so the decision is the
    paper's -- and returned as the compiled artifact whose
    ``to_partition_spec`` places the banked dim on a mesh axis.
    """
    if lanes <= 1 or dim_size < lanes or dim_size % lanes:
        return None
    blk = dim_size // lanes
    mem = MemorySpec("t", dims=(dim_size,), ports=1)
    # lane l owns the contiguous block [l*blk, (l+1)*blk): outer counter
    # supplies the lane, the inner synchronized counter the offset.
    prog = Program(
        root=Ctrl("rd", Sched.INNER,
                  counters=[Counter("o", 0, 1, lanes, par=lanes),
                            Counter("j", 0, 1, blk)],
                  accesses=[AccessDecl("t", (Affine.of(o=blk, j=1),))]),
        memories={"t": mem},
    )
    opts = SolverOptions(max_solutions=4, n_budget=8,
                         b_candidates=(blk, 1) if blk > 1 else (1,),
                         allow_multidim=False, allow_duplication=False)
    # submit -> await through the shared service: lane problems share the
    # serving runtime's plan store, cache, and in-flight dedup
    plan = default_service().submit(prog, "t", opts=opts).result()
    return lane_compile(plan, lanes)


def bankable(dim_size: int, lanes: int) -> bool:
    """Can `dim_size` be banked conflict-free FO=1 across `lanes` lanes?"""
    return lanes <= 1 or lane_artifact(dim_size, lanes) is not None


def first_bankable(dims: Sequence[int], candidates: Sequence[int],
                   lanes: int) -> Optional[int]:
    for d in candidates:
        if d < len(dims) and bankable(dims[d], lanes):
            return d
    return None


# ---------------------------------------------------------------------------
# Mesh-axis vocabulary
# ---------------------------------------------------------------------------


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)

def tp_axis(mesh: Mesh) -> str:
    return "model"


# ---------------------------------------------------------------------------
# Parameter sharding rules (driven by `bankable`)
# ---------------------------------------------------------------------------


def _param_spec(path: str, shape: Tuple[int, ...], tp_size: int,
                fsdp_size: int, fsdp: bool,
                fsdp_axes: Tuple[str, ...] = ("data",)) -> P:
    """Choose (tp_dim, fsdp_dim) for one parameter by role."""
    nd = len(shape)
    name = path.split("/")[-1]

    # role table: candidate tp dims (relative to trailing dims), then fsdp
    reversed_candidates = {
        # attention
        "wq": (0,), "wk": (0,), "wv": (0,), "wo": (1,),
        "bq": (0,), "bk": (0,), "bv": (0,),
        # mlps (gate/up shard F=last, down shards F=first-of-trailing-2)
        "w_gate": (0,), "w_up": (0,), "w_down": (1,),
        "b_up": (0,), "b_down": (),
        # embeddings / heads: shard vocab
        "embed": (1,), "lm_head": (1,),
        # moe: shard experts (dim -3 of (E, D, F))
        "we_gate": (2,), "we_up": (2,), "we_down": (2,),
        "router": (0,),
        # ssm
        "in_proj": (0,), "out_proj": (1,), "conv_w": (0,), "conv_b": (0,),
        "A_log": (0,), "D_skip": (0,), "dt_bias": (0,), "gate_ln": (0,),
    }
    cands_rev = reversed_candidates.get(name, ())
    spec = [None] * nd
    for c in cands_rev:
        d = nd - 1 - c
        if d < 0:
            continue
        if tp_size <= 1:
            spec[d] = "model"   # single-lane axis: placement is free
            break
        art = lane_artifact(shape[d], tp_size)
        if art is not None:
            # the artifact's own PartitionSpec bridge places the banked
            # (single) dim of its 1-D problem on the mesh axis
            spec[d] = art.to_partition_spec("model")[0]
            break
    if fsdp:
        # ZeRO-3 style: also cut the largest remaining dim across data
        # (and pod, for optimizer state -- fsdp_axes=("data","pod"))
        fsdp_entry = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
        order = sorted(range(nd), key=lambda d: -shape[d])
        for d in order:
            if spec[d] is not None or shape[d] < 2 * fsdp_size:
                continue
            if fsdp_size <= 1:
                spec[d] = fsdp_entry
                break
            art = lane_artifact(shape[d], fsdp_size)
            if art is not None:
                spec[d] = art.to_partition_spec(fsdp_entry)[0]
                break
    return P(*spec)


def _path_join(prefix, key) -> str:
    k = getattr(key, "key", getattr(key, "name", str(key)))
    return f"{prefix}/{k}" if prefix else str(k)


def param_specs(params_shape: Any, mesh: Mesh, fsdp: bool = False,
                fsdp_axes: Tuple[str, ...] = ("data",)) -> Any:
    """PartitionSpec pytree matching a params shape-pytree."""
    tp_size = mesh.shape["model"]
    fsdp_axes = tuple(a for a in fsdp_axes if a in mesh.axis_names)
    fsdp_size = int(np.prod([mesh.shape[a] for a in fsdp_axes])) \
        if fsdp_axes else 1

    def walk(tree, prefix):
        if isinstance(tree, dict):
            return {k: walk(v, _path_join(prefix, k)) for k, v in tree.items()}
        shape = tuple(tree.shape)
        return _param_spec(prefix, shape, tp_size, fsdp_size, fsdp,
                           fsdp_axes or ("data",))

    return walk(params_shape, "")


# ---------------------------------------------------------------------------
# Batch / cache / activation sharding per shape-kind
# ---------------------------------------------------------------------------


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> Dict[str, P]:
    dp = dp_axes(mesh)
    bdim = dp if shape.global_batch >= int(np.prod([mesh.shape[a] for a in dp])) \
        else dp[:1] if shape.global_batch > 1 else ()
    b = bdim if bdim else None
    specs = {"tokens": P(b, None), "labels": P(b, None)}
    if cfg.family == "encdec":
        specs["frames"] = P(b, None, None)
    return specs


def _seq_or_heads(mesh: Mesh, heads: int, long: bool) -> Tuple[Any, Any]:
    """(head_axis_spec, seq_axis_spec) for KV caches."""
    tp = "model"
    if not long and bankable(heads, mesh.shape[tp]):
        return tp, None
    return None, tp


def cache_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> Any:
    """PartitionSpec pytree for the family's decode cache."""
    dp = dp_axes(mesh)
    long = shape.kind == "long_decode"
    nb = None if shape.global_batch == 1 else dp
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        h_ax, s_ax = _seq_or_heads(mesh, cfg.n_kv_heads, long)
        if long and shape.global_batch == 1:
            # B=1: spread the huge cache over every axis we have
            kv = P(None, None, tuple(a for a in (*dp, "model")), None, None)
        else:
            kv = P(None, nb, s_ax, h_ax, None)
        from ..models.transformer import KVCache
        return KVCache(k=kv, v=kv, pos=P())
    if fam == "ssm":
        from ..models.ssm import SSMCache
        return SSMCache(conv=P(None, nb, None, "model"),
                        state=P(None, nb, "model", None, None),
                        pos=P())
    if fam == "hybrid":
        from ..models.hybrid import HybridCache
        h_ax, s_ax = _seq_or_heads(mesh, cfg.n_kv_heads, long)
        if long and shape.global_batch == 1:
            kv = P(None, None, tuple(a for a in (*dp, "model")), None, None)
        else:
            kv = P(None, nb, s_ax, h_ax, None)
        return HybridCache(conv=P(None, None, nb, None, "model"),
                           state=P(None, None, nb, "model", None, None),
                           k=kv, v=kv, pos=P())
    if fam == "encdec":
        from ..models.encdec import EncDecCache
        h_ax, s_ax = _seq_or_heads(mesh, cfg.n_kv_heads, long)
        kv = P(None, nb, s_ax, h_ax, None)
        return EncDecCache(k_self=kv, v_self=kv, k_cross=kv, v_cross=kv,
                           pos=P())
    raise ValueError(fam)


def logits_spec(mesh: Mesh, batch_sharded: bool = True) -> P:
    dp = dp_axes(mesh)
    return P(dp if batch_sharded else None, "model")
