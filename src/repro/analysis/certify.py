"""Independent conflict-freedom certifier: the solver's second opinion.

The whole solving spine -- solver, reducer, fabric, store -- trusts ONE
decision procedure, the indicator-vector sumset DP behind
:func:`repro.core.polytope.delta_can_hit_window`.  This module re-decides
every access pair of a finished :class:`~repro.core.solver.BankingSolution`
through a deliberately different path:

* **bounded lattice enumeration** -- iterators with small static trip
  counts are walked point by point over their actual window, so a
  conflict arrives with the concrete lattice assignment that collides;
* **residue-witness sets** -- unbounded iterators, data-dependent
  counters and ``Sym`` terms contribute the cyclic subgroup of Z_M they
  generate (plain gcd arithmetic plus explicit per-residue witness
  pointers, never the numpy roll-convolution sumset).

Agreement yields a machine-checkable :class:`ConflictCertificate`: a
JSON document carrying, for every distinct pair delta, the residue
classes mod the free-term subgroup reachable by the bounded part and
the conflict-window classes they must avoid.  :func:`check_certificate`
re-derives every proof offline -- a plan store can be audited without
the solver.  Disagreement yields a concrete :class:`Counterexample` --
two iterator points, same cycle, same bank -- that renders directly as
a pytest regression case (``Counterexample.to_pytest``).
"""

from __future__ import annotations

import itertools
import json
import math
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.polytope import Access, AccessGroup, Affine, Iterator
from ..core.solver import BankingSolution

__all__ = [
    "CERTIFICATE_FORMAT",
    "CertificationError",
    "ConflictCertificate",
    "Counterexample",
    "PairDecision",
    "certify_plan",
    "certify_solution",
    "check_certificate",
    "certificate_matches_plan",
    "decide_delta",
    "make_batch_verifier",
]

CERTIFICATE_FORMAT = "conflict-certificate/v1"
_ENUM_CAP = 1 << 14       # max lattice points enumerated outright
_SCAN_CAP = 1 << 12       # max env grid scanned for a literal collision


class CertificationError(RuntimeError):
    """A scheme failed independent certification.

    Carries the :class:`Counterexample` (when one was constructed) so
    callers can persist it or render it as a regression test.
    """

    def __init__(self, message: str, counterexample=None):
        super().__init__(message)
        self.counterexample = counterexample


# ---------------------------------------------------------------------------
# The independent pair decision
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PairDecision:
    """Outcome of independently re-deciding one pair delta mod N*B.

    The reachable residue set of the delta factors as ``partials + <d>``
    where ``d`` generates the subgroup contributed by the free terms
    (unbounded iterators / Syms) and ``partials`` is the finite set the
    bounded lattice reaches.  Conflict iff some conflict-window residue
    is congruent mod ``d`` to some partial -- so the proof of conflict-
    freedom is just two disjoint residue-class sets.
    """

    conflict: bool
    M: int
    subgroup: int                       # d: free-term subgroup generator
    partials: Tuple[int, ...]           # bounded-part residues mod d
    window: Tuple[int, ...]             # window residues mod d
    method: str                         # trivial | lattice | witness-set
    witness: Optional[Dict[str, int]] = None   # env hitting the window


def _extgcd(a: int, b: int) -> Tuple[int, int, int]:
    """(g, x, y) with x*a + y*b == g == gcd(a, b)."""
    old_r, r = a, b
    old_x, x = 1, 0
    old_y, y = 0, 1
    while r:
        q = old_r // r
        old_r, r = r, old_r - q * r
        old_x, x = x, old_x - q * x
        old_y, y = y, old_y - q * y
    return old_r, old_x, old_y


def _solve_free(gens: Sequence[int], target: int, M: int) -> List[int]:
    """Integer multipliers x with sum(x[i]*gens[i]) === target (mod M).

    Requires ``target`` to be a multiple of gcd(M, *gens); folds the
    generators through the extended euclidean algorithm, tracking how
    each partial gcd is expressed over the generators (the M component
    of the combination vanishes mod M).
    """
    d = M
    combo: List[int] = []
    for g in gens:
        g2, a, b = _extgcd(d, g % M)
        combo = [a * c for c in combo] + [b]
        d = g2
    if d == 0:
        d = M
    k = (target % M) // d
    return [c * k for c in combo]


def _bounded_partials(const, bounded, M, enum_cap):
    """Residues mod M reachable by ``const + sum(c*t)`` with one witness
    lattice assignment each -> ({residue: (t, ...)}, method)."""
    total = 1
    for _name, _c, trips in bounded:
        total *= trips
    if total <= enum_cap:
        # bounded lattice enumeration: walk the actual iteration lattice
        out: Dict[int, Tuple[int, ...]] = {}
        for ts in itertools.product(*(range(tr) for _, _, tr in bounded)):
            r = const
            for (_name, c, _tr), t in zip(bounded, ts):
                r += c * t
            out.setdefault(r % M, ts)
            if len(out) == M:
                break
        return out, "lattice"
    # residue-witness sets: fold one term at a time, keeping for every
    # new residue a witness pointer back into the previous layer
    layers: List[Dict[int, Optional[Tuple[int, int]]]] = [{const % M: None}]
    for _name, c, trips in bounded:
        prev, nxt = layers[-1], {}
        for r in prev:
            for t in range(trips):
                nr = (r + c * t) % M
                if nr not in nxt:
                    nxt[nr] = (r, t)
            if len(nxt) == M:
                break
        layers.append(nxt)
    out = {}
    for r in layers[-1]:
        ts: List[int] = []
        rr = r
        for li in range(len(bounded), 0, -1):
            back = layers[li][rr]
            assert back is not None
            rr, t = back
            ts.append(t)
        out[r] = tuple(reversed(ts))
    return out, "witness-set"


def decide_delta(delta: Affine, iters: Dict[str, Iterator], N: int, B: int,
                 *, enum_cap: int = _ENUM_CAP) -> PairDecision:
    """Independently decide conflict-window reachability for one delta.

    Same predicate as :func:`~repro.core.polytope.delta_can_hit_window`
    (Def 2.8/2.9: delta === r (mod N*B) with |r| < B), decided by
    lattice enumeration + subgroup witness arithmetic instead of the
    sumset DP.  Conflicting decisions come with a concrete witness
    environment assigning every variable of ``delta``.
    """
    M = int(N) * int(B)
    names = [k for k, _ in delta.terms] + [k for k, _ in delta.syms]
    if M <= 1:
        env = {}
        for n in names:
            it = iters.get(n)
            env[n] = it.start if it is not None else 0
        return PairDecision(True, M, 1, (0,), (0,), "trivial", env)
    window = tuple(sorted({w % M for w in range(-(B - 1), B)}))
    const = delta.const % M
    fixed: Dict[str, int] = {}
    bounded: List[Tuple[str, int, int, Iterator]] = []   # name, c, trips, it
    free: List[Tuple[str, str, int, Optional[Iterator]]] = []
    for name, coeff in delta.terms:
        it = iters.get(name)
        if it is None:
            # unknown trip space: conservative unbounded integer
            if coeff % M == 0:
                fixed[name] = 0
            else:
                free.append(("raw", name, coeff % M, None))
            continue
        const = (const + coeff * it.start) % M
        c = (coeff * it.step) % M
        if c == 0 or (it.count is not None and it.count <= 1):
            fixed[name] = it.start
            continue
        period = M // math.gcd(c, M)
        if it.count is None or it.count >= period:
            # the window already wraps the whole subgroup <gcd(c, M)>
            free.append(("iter", name, c, it))
        else:
            bounded.append((name, c, min(it.count, period), it))
    for key, coeff in delta.syms:
        if coeff % M == 0:
            fixed.setdefault(key, 0)
        else:
            free.append(("sym", key, coeff % M, None))
    d = M
    for _kind, _name, g, _it in free:
        d = math.gcd(d, g)
    partials, method = _bounded_partials(
        const, [(n, c, tr) for n, c, tr, _ in bounded], M, enum_cap)
    hit = None
    for p in partials:
        for w in window:
            if (w - p) % d == 0:
                hit = (p, w)
                break
        if hit:
            break
    p_mod = tuple(sorted({p % d for p in partials}))
    w_mod = tuple(sorted({w % d for w in window}))
    if hit is None:
        return PairDecision(False, M, d, p_mod, w_mod, method, None)
    p, w = hit
    env = dict(fixed)
    for (name, _c, _tr, it), t in zip(bounded, partials[p]):
        env[name] = it.start + it.step * t
    xs = _solve_free([g for _k, _n, g, _i in free], (w - p) % M, M)
    for (kind, name, g, it), x in zip(free, xs):
        if kind == "iter":
            period = M // math.gcd(g, M)
            t = x % period
            env[name] = it.start + it.step * t
        else:
            env[name] = x % M
    r = delta.evaluate(env) % M
    assert r in set(window), (delta, env, r)     # internal soundness check
    return PairDecision(True, M, d, p_mod, w_mod, method, env)


# ---------------------------------------------------------------------------
# Counterexamples
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Counterexample:
    """Two concurrent accesses, one iterator point, one shared bank.

    ``env`` assigns every iterator/Sym the pair depends on; ``x1``/``x2``
    are the resulting array points and ``bank1``/``bank2`` their bank
    ids under the refuted scheme.  ``same_bank`` is True when the two
    points literally land on one bank; when only the relaxed window
    criterion (Def 2.8) is violated the residue evidence is kept
    instead and ``same_bank`` is False.
    """

    memory: str
    scheme: str
    group: str
    a_label: str
    b_label: str
    env: Dict[str, int]
    x1: Tuple[int, ...]
    x2: Tuple[int, ...]
    bank1: object
    bank2: object
    same_bank: bool
    residue: int
    window: Tuple[int, ...]
    note: str = ""

    def describe(self) -> str:
        head = (f"{self.memory}: accesses {self.a_label!r}/{self.b_label!r}"
                f" at env={self.env} -> points {self.x1} / {self.x2}")
        if self.same_bank:
            return f"{head} share bank {self.bank1} under {self.scheme}"
        return (f"{head} hit window residue {self.residue} (window "
                f"{self.window}) under {self.scheme}")

    def to_json(self) -> dict:
        return {
            "format": "conflict-counterexample/v1",
            "memory": self.memory, "scheme": self.scheme,
            "group": self.group,
            "a_label": self.a_label, "b_label": self.b_label,
            "env": dict(self.env),
            "x1": list(self.x1), "x2": list(self.x2),
            "bank1": _bank_json(self.bank1), "bank2": _bank_json(self.bank2),
            "same_bank": self.same_bank,
            "residue": self.residue, "window": list(self.window),
            "note": self.note,
        }

    def to_pytest(self, name: str = "test_certifier_counterexample") -> str:
        """Render as a self-contained pytest regression case.

        The generated test re-evaluates the two array points at the
        recorded environment and asserts the collision is real -- it
        fails only if someone edits it out of agreement with the
        recorded evidence, so a future solver/certifier disagreement
        lands in the suite as a reproducible case, not a log line.
        """
        cex = json.dumps(self.to_json(), indent=1, sort_keys=True)
        body = [
            "import json",
            "",
            "",
            f"def {name}():",
            '    """Auto-rendered by repro.analysis.certify; see',
            "    Counterexample.to_pytest.  Evidence that the scheme",
            f"    {self.scheme!r}",
            f"    conflicts on memory {self.memory!r}.",
            '    """',
            # JSON, not a Python literal: true/false/null must parse
            f"    cex = json.loads(r'''{cex}''')",
            "    x1, x2 = tuple(cex['x1']), tuple(cex['x2'])",
        ]
        if self.same_bank:
            body += [
                "    assert cex['same_bank']",
                "    assert cex['bank1'] == cex['bank2'], (",
                "        'recorded points must collide on one bank')",
            ]
        else:
            body += [
                "    assert cex['residue'] in cex['window'], (",
                "        'recorded delta residue must sit in the window')",
            ]
        return "\n".join(body) + "\n"


def _bank_json(bank):
    if isinstance(bank, tuple):
        return list(int(b) for b in bank)
    return int(bank) if bank is not None else None


def _point(access: Access, env: Dict[str, int]) -> Tuple[int, ...]:
    return tuple(int(e.evaluate(env)) for e in access.exprs)


def _pair_names(a: Access, b: Access) -> List[str]:
    names: List[str] = []
    for acc in (a, b):
        for e in acc.exprs:
            for k, _ in e.terms:
                if k not in names:
                    names.append(k)
            for k, _ in e.syms:
                if k not in names:
                    names.append(k)
    return names


def _literal_collision(a, b, geometry, iters, env0, *, cap=_SCAN_CAP):
    """Scan a small env grid near the witness for a literal shared bank."""
    names = _pair_names(a, b)
    axes: List[List[int]] = []
    for n in names:
        it = iters.get(n)
        if it is not None:
            trips = it.count if it.count is not None else 16
            vals = [it.start + it.step * t for t in range(min(trips, 16))]
        else:
            base = env0.get(n, 0)
            vals = [base + k for k in range(-4, 12)]
        if env0.get(n) is not None and env0[n] not in vals:
            vals.insert(0, env0[n])
        axes.append(vals)
    total = 1
    for vals in axes:
        total *= len(vals)
    while total > cap:
        big = max(range(len(axes)), key=lambda i: len(axes[i]))
        total //= len(axes[big])
        axes[big] = axes[big][:max(1, len(axes[big]) // 2)]
        total *= len(axes[big])
    for combo in itertools.product(*axes):
        env = dict(zip(names, combo))
        b1 = geometry.bank_address(_point(a, env))
        b2 = geometry.bank_address(_point(b, env))
        if b1 == b2:
            return env, b1
    return None, None


def _counterexample(sol, group_label, a, b, iters, env, residue, window,
                    note=""):
    geo = sol.geometry
    lit_env, bank = _literal_collision(a, b, geo, iters, env)
    if lit_env is not None:
        env, same = lit_env, True
        bank1 = bank2 = bank
    else:
        same = False
        bank1 = geo.bank_address(_point(a, env))
        bank2 = geo.bank_address(_point(b, env))
    return Counterexample(
        memory=sol.memory.name, scheme=sol.describe(), group=group_label,
        a_label=a.label or f"access{a.uid}",
        b_label=b.label or f"access{b.uid}",
        env=dict(env), x1=_point(a, env), x2=_point(b, env),
        bank1=bank1, bank2=bank2, same_bank=same,
        residue=residue, window=tuple(window), note=note)


# ---------------------------------------------------------------------------
# Whole-solution certification
# ---------------------------------------------------------------------------

def _clique_lower_bound(n: int, edges: set) -> int:
    """Greedy clique bound, reimplemented here so the certifier's verdict
    never borrows code from the path under audit (same semantics as the
    solver's: the certificate records the full edge set, so a stronger
    offline checker can always re-derive an exact clique)."""
    if not edges:
        return 1
    adj: Dict[int, set] = {i: set() for i in range(n)}
    for u, v in edges:
        adj[u].add(v)
        adj[v].add(u)
    best = 2
    for u in sorted(adj, key=lambda q: -len(adj[q]))[:16]:
        clique = {u}
        for v in sorted(adj[u], key=lambda w: -len(adj[w])):
            if all(v in adj[c] for c in clique):
                clique.add(v)
        best = max(best, len(clique))
    return best


def _geometry_json(sol: BankingSolution) -> dict:
    g = sol.geometry
    if sol.kind == "flat":
        return {"kind": "flat", "N": int(g.N), "B": int(g.B),
                "alpha": [int(x) for x in g.alpha],
                "P": [int(x) for x in g.P]}
    return {"kind": "multidim", "Ns": [int(x) for x in g.Ns],
            "Bs": [int(x) for x in g.Bs],
            "alphas": [int(x) for x in g.alphas]}


def _affine_json(e: Affine) -> dict:
    return {"terms": [[k, int(c)] for k, c in e.terms],
            "syms": [[k, int(c)] for k, c in e.syms],
            "const": int(e.const)}


def _affine_from_json(d: dict) -> Affine:
    return Affine(terms=tuple((k, int(c)) for k, c in d["terms"]),
                  syms=tuple((k, int(c)) for k, c in d["syms"]),
                  const=int(d["const"]))


def _delta_key(delta: Affine, N: int, B: int) -> str:
    return json.dumps([_affine_json(delta), int(N), int(B)],
                      sort_keys=True)


def _certify_groups(sol: BankingSolution, groups, duplicates: int):
    """The groups a solution must keep conflict-free, with labels.

    Mirrors the candidate space: a duplicated scheme serves each read
    subset from its own copy, so each subset (plus every write-bearing
    group) must be independently conflict-free.
    """
    if duplicates <= 1:
        return [(f"group{i}", g) for i, g in enumerate(groups)]
    read_groups = [g for g in groups if not any(a.is_write for a in g)]
    big = max(read_groups, key=len) if read_groups else None
    if big is None or len(big) < 2 * duplicates:
        raise CertificationError(
            f"scheme claims x{duplicates} duplication but no read group "
            f"is splittable {duplicates} ways")
    labeled = [(f"group{i}", g) for i, g in enumerate(groups) if g is not big]
    labeled += [(f"dup-subset{i}",
                 AccessGroup(list(big)[i::duplicates]))
                for i in range(duplicates)]
    return labeled


class ConflictCertificate:
    """Wrapper over the JSON certificate document."""

    def __init__(self, doc: dict):
        self.doc = doc

    @property
    def verdict(self) -> str:
        return self.doc.get("verdict", "")

    @property
    def signature(self) -> str:
        return self.doc.get("signature", "")

    def to_json(self) -> dict:
        return self.doc

    @classmethod
    def from_json(cls, doc: dict) -> "ConflictCertificate":
        return cls(doc)


@dataclass
class CertifyResult:
    ok: bool
    certificate: Optional[ConflictCertificate]
    counterexample: Optional[Counterexample]
    pairs_checked: int
    seconds: float
    reason: str = ""


def certify_solution(sol: BankingSolution, groups, iters,
                     *, signature: str = "", scorer: str = "",
                     enum_cap: int = _ENUM_CAP) -> CertifyResult:
    """Independently certify one scheme over its access groups.

    Returns a :class:`CertifyResult`: either ``ok`` with a
    machine-checkable certificate, or a counterexample -- the concrete
    env where more than ``ports`` accesses of one group collide (or, at
    minimum, a pair the scheme's edge set missed).
    """
    t0 = time.perf_counter()
    mem = sol.memory
    try:
        labeled = _certify_groups(sol, groups, sol.duplicates)
    except CertificationError as e:
        return CertifyResult(False, None, None, 0,
                             time.perf_counter() - t0, reason=str(e))
    proofs: Dict[str, dict] = {}
    group_docs = []
    pairs_checked = 0

    def decide(delta, N, B):
        key = _delta_key(delta, N, B)
        cached = proofs.get(key)
        if cached is not None:
            return cached["_decision"], key
        dec = decide_delta(delta, iters, N, B, enum_cap=enum_cap)
        proofs[key] = {
            "delta": _affine_json(delta), "N": int(N), "B": int(B),
            "conflict": dec.conflict, "M": dec.M,
            "subgroup": dec.subgroup,
            "partials_mod_d": list(dec.partials),
            "window_mod_d": list(dec.window),
            "method": dec.method, "_decision": dec,
        }
        return dec, key

    for label, group in labeled:
        accesses = list(group)
        edges = set()
        pair_docs = []
        for i, j in itertools.combinations(range(len(accesses)), 2):
            a, b = accesses[i], accesses[j]
            pairs_checked += 1
            if sol.kind == "flat":
                geo = sol.geometry
                delta = a.dot(geo.alpha) - b.dot(geo.alpha)
                dec, key = decide(delta, geo.N, geo.B)
                keys = [key]
                conflict = dec.conflict
            else:
                geo = sol.geometry
                conflict, keys = True, []
                for dim in range(len(geo.Ns)):
                    dd = (a.exprs[dim].scale(geo.alphas[dim])
                          - b.exprs[dim].scale(geo.alphas[dim]))
                    dec, key = decide(dd, geo.Ns[dim], geo.Bs[dim])
                    keys.append(key)
                    if not dec.conflict:
                        conflict = False
                        break
            if conflict:
                edges.add((i, j))
            pair_docs.append([i, j, keys, bool(conflict)])
        clique = _clique_lower_bound(len(accesses), edges)
        group_docs.append({
            "label": label, "n": len(accesses),
            "labels": [a.label or f"access{a.uid}" for a in accesses],
            "edges": sorted([list(e) for e in edges]),
            "pairs": pair_docs, "clique": clique,
        })
        if clique > mem.ports:
            # the scheme admits a conflict clique beyond the ports: dig
            # out one offending edge and build the concrete evidence
            u, v = min(edges)
            a, b = accesses[u], accesses[v]
            if sol.kind == "flat":
                delta = (a.dot(sol.geometry.alpha)
                         - b.dot(sol.geometry.alpha))
                dec, _ = decide(delta, sol.geometry.N, sol.geometry.B)
                env = dec.witness or {}
                residue = delta.evaluate(env) % max(dec.M, 1) \
                    if env else 0
                window = tuple(sorted({w % max(dec.M, 1)
                                       for w in range(-(sol.geometry.B - 1),
                                                      sol.geometry.B)}))
            else:
                env = {}
                for i2, j2, keys2, c2 in pair_docs:
                    if (i2, j2) == (u, v):
                        for key in keys2:
                            w_env = proofs[key]["_decision"].witness
                            if w_env:
                                env.update(w_env)
                residue, window = 0, (0,)
            cex = _counterexample(
                sol, label, a, b, iters, env, residue, window,
                note=(f"clique {clique} > ports {mem.ports} "
                      f"in {label}"))
            return CertifyResult(
                False, None, cex, pairs_checked,
                time.perf_counter() - t0,
                reason=f"conflict clique {clique} > {mem.ports} ports")

    for doc in proofs.values():
        doc.pop("_decision", None)
    cert = ConflictCertificate({
        "format": CERTIFICATE_FORMAT,
        "signature": signature, "scorer": scorer,
        "memory": mem.name, "ports": int(mem.ports),
        "dims": [int(d) for d in mem.dims],
        "kind": sol.kind, "duplicates": int(sol.duplicates),
        "geometry": _geometry_json(sol),
        "iterators": {name: {"start": it.start, "step": it.step,
                             "count": it.count}
                      for name, it in iters.items()},
        "groups": group_docs,
        "proofs": proofs,
        "pairs_checked": pairs_checked,
        "verdict": "certified",
        "created_at": time.time(),
    })
    return CertifyResult(True, cert, None, pairs_checked,
                         time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# Offline certificate checking (what `python -m repro.analysis` runs)
# ---------------------------------------------------------------------------

def check_certificate(cert) -> Tuple[bool, str]:
    """Re-derive every residue proof of a certificate from scratch.

    Needs nothing but the certificate itself: rebuilds each pair delta,
    re-decides it, and re-checks the clique arithmetic against the
    recorded ports.  Returns (ok, reason).
    """
    doc = cert.doc if isinstance(cert, ConflictCertificate) else cert
    if doc.get("format") != CERTIFICATE_FORMAT:
        return False, f"unknown certificate format {doc.get('format')!r}"
    if doc.get("verdict") != "certified":
        return False, f"verdict is {doc.get('verdict')!r}"
    iters = {name: Iterator(name, start=d["start"], step=d["step"],
                            count=d["count"])
             for name, d in doc.get("iterators", {}).items()}
    for key, proof in doc.get("proofs", {}).items():
        delta = _affine_from_json(proof["delta"])
        dec = decide_delta(delta, iters, proof["N"], proof["B"])
        if dec.conflict != proof["conflict"]:
            return False, f"proof {key}: recorded conflict bit disagrees"
        if (dec.subgroup != proof["subgroup"]
                or list(dec.partials) != list(proof["partials_mod_d"])
                or list(dec.window) != list(proof["window_mod_d"])):
            return False, f"proof {key}: residue sets disagree"
        if not proof["conflict"]:
            touch = {p % proof["subgroup"]
                     for p in proof["partials_mod_d"]}
            if touch & set(proof["window_mod_d"]):
                return False, f"proof {key}: classes not disjoint"
    ports = int(doc.get("ports", 1))
    for g in doc.get("groups", []):
        edges = {tuple(e) for e in g["edges"]}
        for i, j, _keys, conflict in g["pairs"]:
            if conflict != ((i, j) in edges):
                return False, f"{g['label']}: edge list disagrees with pairs"
        clique = _clique_lower_bound(g["n"], edges)
        if clique != g["clique"]:
            return False, (f"{g['label']}: recorded clique {g['clique']} "
                           f"!= recomputed {clique}")
        if clique > ports:
            return False, (f"{g['label']}: clique {clique} exceeds "
                           f"{ports} ports")
    return True, "ok"


def certify_plan(plan, iters, *, scorer: str = "") -> CertifyResult:
    """Certify a plan's chosen scheme against its own access groups."""
    if plan.best is None:
        return CertifyResult(True, None, None, 0, 0.0,
                             reason="plan has no solution to certify")
    return certify_solution(plan.best, plan.groups, iters,
                            signature=plan.signature,
                            scorer=scorer or plan.scorer_name)


def make_batch_verifier(space):
    """Build the untrusted-result gate a :class:`SolveFabric` applies to
    every solution batch a remote worker streams back.

    Returns ``None`` to accept the batch, or the failing
    :class:`CertifyResult` (reason + counterexample) to reject it -- the
    fabric then drops the batch, requeues the unit away from that
    worker, and counts a ``cert_rejected``.
    """
    def verify(events):
        for ev in events:
            for sol in getattr(ev, "solutions", ()) or ():
                res = certify_solution(sol, space.groups, space.iters)
                if not res.ok:
                    return res
        return None
    return verify


def certificate_matches_plan(cert, plan) -> bool:
    """Does this certificate certify this plan's chosen scheme?"""
    doc = cert.doc if isinstance(cert, ConflictCertificate) else cert
    best = plan.best
    if best is None:
        return False
    if doc.get("signature") and plan.signature \
            and doc["signature"] != plan.signature:
        return False
    return doc.get("geometry") == _geometry_json(best)
