"""Program lint: catch banking problems no solver can fix, before solving.

Four families of diagnostics, severity-graded:

* ``degenerate-counter`` -- zero/negative trip counts, zero steps,
  nonsensical ``par``: the unroller would silently produce an empty or
  repeated lane space.
* ``oob-access`` / ``unbounded-access`` -- interval arithmetic over each
  affine access against the ``MemorySpec`` dims; a provable
  out-of-bounds index is an error, an unprovable one (data-dependent
  counter, ``Sym`` offset) is informational.
* ``sym-collision`` -- the same raw ``Sym`` key used from *distinct*
  call sites: under lockstep lanes the unroller keeps raw keys as-is,
  so two semantically different runtime values cancel in access deltas
  and the conflict analysis is unsound.
* ``port-oversubscription`` -- more than ``ports`` concurrent accesses
  with literally identical address expressions land on one bank under
  EVERY geometry; an error when writes are involved (duplication can
  only serve reads).

``lint_program`` is what ``PlanService.submit(..., verify=...)`` runs
before a solve is even queued.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from ..core.controller import Counter, Ctrl, Program, unroll
from ..core.grouping import build_groups
from ..core.polytope import Affine

__all__ = ["Diagnostic", "LintError", "LintReport", "lint_program"]

SEVERITIES = ("error", "warning", "info")


class LintError(ValueError):
    """A Program failed the pre-solve lint gate (error-severity findings).

    Raised by ``PlanService.submit(..., verify=...)`` before the solve
    queues; ``.report`` carries the full :class:`LintReport`.
    """

    def __init__(self, report: "LintReport"):
        super().__init__("program fails lint:\n" + report.describe())
        self.report = report


@dataclass(frozen=True)
class Diagnostic:
    severity: str
    code: str
    message: str
    where: str = ""

    def describe(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        return f"{self.severity}: {self.code}{loc}: {self.message}"


@dataclass
class LintReport:
    diagnostics: List[Diagnostic]

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def describe(self) -> str:
        if not self.diagnostics:
            return "lint: clean"
        return "\n".join(d.describe() for d in self.diagnostics)


def _counter_range(c: Counter) -> Optional[Tuple[int, int]]:
    """Inclusive value range of a static counter, None when unknowable."""
    if not c.static or c.count is None or c.count <= 0:
        return None
    last = c.start + c.step * (c.count - 1)
    return (min(c.start, last), max(c.start, last))


def _expr_bounds(expr: Affine, env: Dict[str, Counter]):
    """Interval of an affine access expression, None when unbounded."""
    if expr.syms:
        return None
    lo = hi = expr.const
    for name, coeff in expr.terms:
        c = env.get(name)
        rng = _counter_range(c) if c is not None else None
        if rng is None:
            return None
        vmin, vmax = rng
        if coeff >= 0:
            lo += coeff * vmin
            hi += coeff * vmax
        else:
            lo += coeff * vmax
            hi += coeff * vmin
    return lo, hi


def _lint_counters(ctrl: Ctrl, out: List[Diagnostic]) -> None:
    for c in ctrl.counters:
        where = f"{ctrl.name}.{c.name}"
        if c.count is not None and c.count <= 0:
            out.append(Diagnostic(
                "error", "degenerate-counter",
                f"trip count {c.count} produces no iterations", where))
        if c.step == 0 and (c.count is None or c.count > 1):
            out.append(Diagnostic(
                "error", "degenerate-counter",
                "step 0 repeats one value every iteration", where))
        if c.par < 1:
            out.append(Diagnostic(
                "error", "degenerate-counter",
                f"par {c.par} is not a positive lane count", where))
        elif c.count is not None and 0 < c.count < c.par:
            out.append(Diagnostic(
                "warning", "degenerate-counter",
                f"par {c.par} exceeds trip count {c.count}: "
                f"some lanes never run", where))


def _lint_bounds(ctrl: Ctrl, env: Dict[str, Counter], program: Program,
                 memory: Optional[str], out: List[Diagnostic]) -> None:
    env = dict(env)
    for c in ctrl.counters:
        env[c.name] = c
    for decl in ctrl.accesses:
        if memory is not None and decl.memory != memory:
            continue
        mem = program.memories.get(decl.memory)
        if mem is None:
            out.append(Diagnostic(
                "error", "oob-access",
                f"access targets undeclared memory {decl.memory!r}",
                f"{ctrl.name}.{decl.label or decl.memory}"))
            continue
        if len(decl.exprs) != len(mem.dims):
            out.append(Diagnostic(
                "error", "oob-access",
                f"{len(decl.exprs)} index exprs for "
                f"{len(mem.dims)}-d memory {mem.name!r}",
                f"{ctrl.name}.{decl.label or mem.name}"))
            continue
        for d, (expr, dim) in enumerate(zip(decl.exprs, mem.dims)):
            where = f"{ctrl.name}.{decl.label or mem.name}[dim{d}]"
            bounds = _expr_bounds(expr, env)
            if bounds is None:
                out.append(Diagnostic(
                    "info", "unbounded-access",
                    f"index range not statically bounded vs dim {dim} "
                    f"(data-dependent counter or Sym offset)", where))
                continue
            lo, hi = bounds
            if lo < 0 or hi >= dim:
                out.append(Diagnostic(
                    "error", "oob-access",
                    f"index range [{lo}, {hi}] escapes [0, {dim})",
                    where))
    for child in ctrl.children:
        _lint_bounds(child, env, program, memory, out)


def _lint_syms(program: Program, out: List[Diagnostic]) -> None:
    sites: Dict[str, List[str]] = {}
    for ctrl in program.root.subtree():
        keys = set()
        for decl in ctrl.accesses:
            for expr in decl.exprs:
                for key, _ in expr.syms:
                    if "@" not in key:       # qualified keys are per-site
                        keys.add(key)
        for key in keys:
            sites.setdefault(key, []).append(ctrl.name)
    for key, ctrls in sorted(sites.items()):
        if len(ctrls) > 1:
            out.append(Diagnostic(
                "error", "sym-collision",
                f"Sym {key!r} appears in distinct call sites "
                f"{sorted(set(ctrls))}: under lockstep unrolling the "
                f"instances cancel in deltas as if equal -- qualify the "
                f"keys per site", key))


def _lint_ports(program: Program, memory: Optional[str],
                out: List[Diagnostic]) -> None:
    try:
        up = unroll(program)
    except Exception as e:                    # surfaced, not raised
        out.append(Diagnostic("error", "unroll-failure",
                              f"program does not unroll: {e!r}"))
        return
    names = [memory] if memory is not None else sorted(program.memories)
    for name in names:
        mem = program.memories.get(name)
        if mem is None:
            continue
        for gi, group in enumerate(build_groups(up, name)):
            buckets: Dict[Tuple, List] = {}
            for a in group:
                buckets.setdefault(tuple(a.exprs), []).append(a)
            for exprs, accs in buckets.items():
                if len(accs) <= mem.ports:
                    continue
                labels = sorted(a.label or f"access{a.uid}" for a in accs)
                writes = any(a.is_write for a in accs)
                sev = "error" if writes else "warning"
                fix = ("no banking or duplication separates them"
                       if writes else
                       "only array duplication can serve them")
                out.append(Diagnostic(
                    sev, "port-oversubscription",
                    f"{len(accs)} concurrent accesses {labels} on "
                    f"{name!r} share one address expression "
                    f"(> {mem.ports} ports): {fix}",
                    f"group{gi}"))


def lint_program(program: Program,
                 memory: Optional[str] = None) -> LintReport:
    """Lint a :class:`Program` (optionally scoped to one memory)."""
    out: List[Diagnostic] = []
    for ctrl in program.root.subtree():
        _lint_counters(ctrl, out)
    _lint_bounds(program.root, {}, program, memory, out)
    _lint_syms(program, out)
    _lint_ports(program, memory, out)
    order = {s: i for i, s in enumerate(SEVERITIES)}
    out.sort(key=lambda d: (order.get(d.severity, 9), d.code, d.where))
    return LintReport(out)
