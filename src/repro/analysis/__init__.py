"""Static verification layer: lint before solving, certify after.

Two passes over the banking spine, both independent of the solver's own
decision procedure:

* :mod:`repro.analysis.lint` -- :func:`lint_program` flags problems no
  banking can fix (out-of-bounds accesses, colliding ``Sym`` keys,
  degenerate counters, port over-subscription) before a solve queues.
* :mod:`repro.analysis.certify` -- :func:`certify_solution` re-decides
  every access pair of a finished scheme via bounded lattice
  enumeration + residue-witness sets, emitting a machine-checkable
  :class:`ConflictCertificate` or a concrete :class:`Counterexample`.

``PlanService.submit(..., verify="store"|"all")`` arms both in the
serving path; ``python -m repro.analysis`` audits an existing plan
store offline.
"""

from .certify import (CERTIFICATE_FORMAT, CertificationError,
                      CertifyResult, ConflictCertificate, Counterexample,
                      PairDecision, certificate_matches_plan, certify_plan,
                      certify_solution, check_certificate, decide_delta,
                      make_batch_verifier)
from .lint import Diagnostic, LintError, LintReport, lint_program

__all__ = [
    "CERTIFICATE_FORMAT",
    "CertificationError",
    "CertifyResult",
    "ConflictCertificate",
    "Counterexample",
    "Diagnostic",
    "LintError",
    "LintReport",
    "PairDecision",
    "certificate_matches_plan",
    "certify_plan",
    "certify_solution",
    "check_certificate",
    "decide_delta",
    "lint_program",
    "make_batch_verifier",
]
