"""Audit banking artifacts from the command line.

Sweep an existing plan store, re-checking every persisted certificate
against its plan (missing certificates are reported, not failed --
stores written before verification was armed have none):

    PYTHONPATH=src python -m repro.analysis PATH/TO/STORE

Certify every baseline system's chosen scheme over the Sec-4 problems
(the CI fast step):

    PYTHONPATH=src python -m repro.analysis --baselines [--fast]

Exit status is non-zero iff any check FAILED.
"""

from __future__ import annotations

import argparse
import sys


def _sweep_store(path: str) -> int:
    from ..core.store import DirectoryStore
    from .certify import certificate_matches_plan, check_certificate

    store = DirectoryStore(path)
    verified = missing = failed = 0
    for plan in store.plans():
        cert = store.get_certificate(plan.signature, plan.scorer_name)
        tag = f"{plan.signature} scorer={plan.scorer_name}"
        if cert is None:
            missing += 1
            print(f"missing  {tag}")
            continue
        ok, reason = check_certificate(cert)
        if ok and not certificate_matches_plan(cert, plan):
            ok, reason = False, "certificate does not match plan scheme"
        if ok:
            verified += 1
            print(f"verified {tag}")
        else:
            failed += 1
            print(f"FAILED   {tag}: {reason}")
    print(f"swept: {verified} verified, {missing} missing, "
          f"{failed} failed")
    return 1 if failed else 0


def _certify_baselines(fast: bool) -> int:
    from ..core import baselines, problems
    from ..core.controller import unroll
    from .certify import certify_plan
    from .lint import lint_program

    apps = ["denoise", "sobel"] if fast \
        else list(problems.STENCILS) + list(problems.APPS)
    failures = 0
    for app in apps:
        prog = problems.build(app)
        memname = list(prog.memories)[0]
        report = lint_program(prog, memname)
        if not report.ok:
            failures += 1
            print(f"FAILED   {app}: lint errors\n{report.describe()}")
            continue
        iters = unroll(prog).iterators
        for name, fn in sorted(baselines.SYSTEMS.items()):
            plan = fn(prog, memname)
            res = certify_plan(plan, iters, scorer=name)
            if res.ok:
                print(f"verified {app}/{name}: "
                      f"{res.pairs_checked} pairs in "
                      f"{res.seconds * 1e3:.1f} ms")
            else:
                failures += 1
                why = (res.counterexample.describe()
                       if res.counterexample else res.reason)
                print(f"FAILED   {app}/{name}: {why}")
    print(f"baselines: {failures} failures")
    return 1 if failures else 0


def main() -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="independently verify banking plans and certificates")
    ap.add_argument("store", nargs="?", default=None,
                    help="plan store directory to sweep (re-checks every "
                         "persisted certificate against its plan)")
    ap.add_argument("--baselines", action="store_true",
                    help="lint + certify every core/baselines.py system's "
                         "chosen scheme over the Sec-4 problems")
    ap.add_argument("--fast", action="store_true",
                    help="with --baselines: two representative problems "
                         "instead of the full suite")
    args = ap.parse_args()
    if args.baselines:
        sys.exit(_certify_baselines(args.fast))
    if args.store is None:
        ap.error("give a plan store path or --baselines")
    sys.exit(_sweep_store(args.store))


if __name__ == "__main__":
    main()
