"""chameleon-34b [vlm]: 48L d=8192 64H (GQA kv=8) ff=22016 vocab=65536.

Early-fusion VLM: VQ image tokens share the 65536 vocabulary
[arXiv:2405.09818; unverified].  Frontend = STUB (input_specs provides
token ids; the VQ-GAN tokenizer is out of scope).  long_500k SKIPPED.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=65_536, head_dim=128, tie_embeddings=False,
    frontend="vq_tokens",
    notes="banking applies to the shared VQ codebook embedding",
)
