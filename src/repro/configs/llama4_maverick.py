"""llama4-maverick-400b-a17b [moe]: 48L d=5120 40H (GQA kv=8) ff=8192
vocab=202048, MoE 128 experts top-1 + always-on shared expert
[hf:meta-llama/Llama-4 family; unverified].

Expert dispatch IS the paper's banking problem (DESIGN.md Sec 2): 128
experts = banks, router = access pattern, capacity = ports.
long_500k SKIPPED (chunked-attention variant not modelled).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202_048, head_dim=128, tie_embeddings=False,
    n_experts=128, top_k=1, moe_d_ff=8192, shared_expert=True,
)
