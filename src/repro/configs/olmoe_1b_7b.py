"""olmoe-1b-7b [moe]: 16L d=2048 16H (GQA kv=16) ff=1024/expert
vocab=50304, 64 experts top-8 [arXiv:2409.02060; hf].

FO=8 dispatch crossbar: the paper's fan-out metric literally sizes the
expert all-to-all.  long_500k SKIPPED.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024,
    vocab=50_304, head_dim=128, tie_embeddings=False,
    n_experts=64, top_k=8, moe_d_ff=1024, shared_expert=False,
)
