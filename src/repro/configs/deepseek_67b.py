"""deepseek-67b [dense]: 95L d=8192 64H (GQA kv=8) ff=22016 vocab=102400.

Llama-architecture [arXiv:2401.02954; hf].  long_500k SKIPPED (pure full
attention; noted in DESIGN.md).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=102_400, head_dim=128, tie_embeddings=False,
    notes="GQA kv=8 < model-axis 16 => solver picks bank-by-duplication "
          "for the KV cache (paper Sec 3.3)",
)
