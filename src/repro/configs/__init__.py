"""Assigned architecture registry: ``--arch <id>`` resolves here."""

from __future__ import annotations

from importlib import import_module
from typing import Dict

from .base import SHAPES, ArchConfig, ShapeConfig

ARCH_IDS = [
    "gemma3_12b", "deepseek_67b", "qwen2_7b", "internlm2_20b",
    "chameleon_34b", "llama4_maverick", "olmoe_1b_7b", "mamba2_370m",
    "zamba2_2_7b", "whisper_base",
]

_ALIASES = {
    "gemma3-12b": "gemma3_12b", "deepseek-67b": "deepseek_67b",
    "qwen2-7b": "qwen2_7b", "internlm2-20b": "internlm2_20b",
    "chameleon-34b": "chameleon_34b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "olmoe-1b-7b": "olmoe_1b_7b", "mamba2-370m": "mamba2_370m",
    "zamba2-2.7b": "zamba2_2_7b", "whisper-base": "whisper_base",
}


def get_arch(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    mod = import_module(f".{mod_name}", __package__)
    return mod.CONFIG


def all_archs() -> Dict[str, ArchConfig]:
    return {a: get_arch(a) for a in ARCH_IDS}


__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "ShapeConfig", "all_archs",
           "get_arch"]
