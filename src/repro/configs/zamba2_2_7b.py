"""zamba2-2.7b [hybrid]: 54L mamba2 d=2560 + shared attention blocks
(32H, kv=32, ff=10240), ssm_state=64 [arXiv:2411.15242; hf].

Shared transformer block re-applied every 6 SSM layers (9 sites), single
parameter set, per-site KV cache.  long_500k RUNS.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32_000, head_dim=80, ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    hybrid_period=6,
)
