"""Architecture configuration schema.

One dataclass covers all ten assigned families (dense / moe / ssm / hybrid /
encdec / vlm); family-specific fields are zero/None when unused.  Full-size
configs are exercised only through the dry-run (ShapeDtypeStruct, no
allocation); ``reduced()`` derives the smoke-test configuration.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    # attention flavour
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # >0: local-attention window size
    local_global_ratio: int = 0      # gemma3: N local layers per global layer
    mlp_act: str = "swiglu"          # swiglu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim (olmoe: 1024)
    shared_expert: bool = False      # llama4: always-on shared FFN
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2): one shared attention block applied every k SSM blocks
    hybrid_period: int = 0
    # encoder-decoder (whisper)
    n_encoder_layers: int = 0
    # modality frontend stub: None | "vq_tokens" | "audio_frames"
    frontend: Optional[str] = None
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # notes for DESIGN/EXPERIMENTS (e.g. applicability of paper technique)
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (see DESIGN.md Arch-applicability)."""
        return (self.family in ("ssm", "hybrid")
                or (self.sliding_window > 0 and self.local_global_ratio > 0))

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2 if self.family != "hybrid" else 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=256,
            vocab=512,
            head_dim=32,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=64 if self.moe_d_ff else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=16 if self.ssm_state else 256,
            hybrid_period=2 if self.hybrid_period else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
        )


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per architecture)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "long_decode"),
}
