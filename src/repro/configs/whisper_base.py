"""whisper-base [audio]: 6L enc + 6L dec, d=512 8H ff=2048 vocab=51865
[arXiv:2212.04356; unverified].

Enc-dec; conv/mel frontend is a STUB (input_specs provides precomputed
frame embeddings).  Decode shapes exercise the decoder with self + cross
KV caches.  long_500k SKIPPED (full attention; 1500-frame native context).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="encdec",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab=51_865, head_dim=64, mlp_act="gelu", n_encoder_layers=6,
    frontend="audio_frames",
)
