"""mamba2-370m [ssm]: 48L d=1024, attention-free, ssm_state=128 (SSD)
[arXiv:2405.21060; unverified].

Attention-free: attention-sharding aspects of the paper are inapplicable
(DESIGN.md Arch-applicability); the solver instead banks the (H, P, N)
SSD state across the model axis.  long_500k RUNS (O(1) decode state).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50_280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    tie_embeddings=True,
)
