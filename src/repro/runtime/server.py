"""Batched serving loop: continuous-batching decode with a paged KV cache.

Serving structure (vLLM-style, TPU-native):

* requests queue in; the scheduler packs up to ``max_batch`` active
  sequences into the fixed decode batch (slots);
* prefill runs per request (chunked attention), its KV written into the
  slot's region of the cache;
* one fused ``serve_step`` decodes a token for every active slot per tick;
* finished sequences (EOS or max_len) free their slot for the next queued
  request -- continuous batching.

The cache pages are banks from the banking solver (pages = banks, page
size = blocking factor B); `page_solution()` exposes the scheme used so the
Pallas banked-gather kernel and this scheduler agree on the layout.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.controller import AccessDecl, Counter, Ctrl, Program, Sched
from ..core.planner import default_planner
from ..core.polytope import Affine, MemorySpec
from ..models import Model
from ..launch import steps as steps_mod


@dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


def page_solution(cfg: ArchConfig, max_len: int, page: int = 128,
                  readers: int = 8):
    """Banking scheme for the KV pool: pages = banks, page size = B.

    ``readers`` concurrent decode lanes must never contend on a page.

    Every decode tick poses the structurally identical KV-pool problem, so
    this goes through the shared planner: the first call solves, every
    later call is a signature-keyed cache hit (zero solver work on the
    serving hot path)."""
    npages = max_len // page
    mem = MemorySpec("kv_pool", dims=(max_len,), word_bits=16, ports=1)
    prog = Program(
        root=Ctrl("decode", Sched.INNER,
                  counters=[Counter("r", 0, 1, readers, par=readers),
                            Counter("j", 0, 1, page)],
                  accesses=[AccessDecl("kv_pool", (Affine.of(r=page, j=1),))]),
        memories={"kv_pool": mem},
    )
    from ..core.solver import SolverOptions
    plan = default_planner().plan(
        prog, "kv_pool",
        opts=SolverOptions(b_candidates=(page, 1), allow_multidim=False))
    return plan.best


class Server:
    def __init__(self, model: Model, max_batch: int = 4, max_len: int = 128):
        self.model = model
        self.cfg = model.cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}   # slot -> request
        self._decode = jax.jit(steps_mod.make_serve_step(model))
        self.cache = model.init_cache(max_batch, max_len)
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self.positions = np.zeros(max_batch, np.int64)
        self.ticks = 0

    # -- admission -------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.max_batch):
            if slot in self.active or not self.queue:
                continue
            req = self.queue.pop(0)
            # per-request prefill: run the prompt through decode one token at
            # a time into this slot (batch=1 prefill folded into the shared
            # cache; a production server runs a separate prefill graph)
            toks = req.prompt
            for t in toks:
                self.tokens = self.tokens.at[slot, 0].set(int(t))
                nxt, _, self.cache = self._decode(
                    _slot_params(self), self.cache, self.tokens)
            req._next = int(np.asarray(nxt)[slot, 0])
            self.active[slot] = req

    # -- decode tick -------------------------------------------------------------
    def tick(self):
        self._admit()
        if not self.active:
            return
        for slot, req in self.active.items():
            self.tokens = self.tokens.at[slot, 0].set(
                getattr(req, "_next", 1))
        nxt, _, self.cache = self._decode(_slot_params(self), self.cache,
                                          self.tokens)
        nxt = np.asarray(nxt)
        finished = []
        for slot, req in self.active.items():
            tok = int(nxt[slot, 0])
            req.out.append(tok)
            req._next = tok
            if len(req.out) >= req.max_new:
                req.done = True
                finished.append(slot)
        for slot in finished:
            del self.active[slot]
        self.ticks += 1

    def run(self, max_ticks: int = 1000):
        while (self.queue or self.active) and self.ticks < max_ticks:
            self.tick()


def _slot_params(server: Server):
    if not hasattr(server, "_params"):
        server._params = server.model.init(jax.random.PRNGKey(0))
    return server._params
