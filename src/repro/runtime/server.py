"""Batched serving loop: continuous-batching decode with a paged KV cache.

Serving structure (vLLM-style, TPU-native):

* requests queue in; the scheduler packs up to ``max_batch`` active
  sequences into the fixed decode batch (slots);
* prefill runs per request (chunked attention), its KV written into the
  slot's region of the cache;
* one fused ``serve_step`` decodes a token for every active slot per tick;
* finished sequences (EOS or max_len) free their slot for the next queued
  request -- continuous batching.

The cache pages are banks from the banking planner (pages = banks, page
size = bank volume).  Since the service redesign the server never blocks
on the solver: ``page_ticket()`` submits the KV-pool problem to the
:class:`~repro.core.service.PlanService` and the :class:`Server` starts
serving immediately from the ticket's **fallback artifact** (trivial
single-bank scheme), then atomically hot-swaps the page pool -- and the
bank-major token-record table -- to the solved artifact between decode
ticks once the background solve lands.

Each decode tick reads its per-slot token records through **one batched
banked gather** (a single ``pallas_call`` over a stacked ``(slots, W)``
index matrix) instead of one kernel launch per row-set -- the compiled
resolution arithmetic runs in the kernel's scalar-prefetch index map
either way, so the scheduler and the gather agree on the layout by
construction.  Writes go the same way: token records queue per tick and
flush through **one batched banked scatter** (``artifact.scatter`` with
per-slot column indices), so the resolution circuit -- not host-side
index math -- places the rows on both paths.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.artifact import CompiledBankingPlan
from ..core.controller import AccessDecl, Counter, Ctrl, Program, Sched
from ..core.jointplan import ResourceBudget
from ..core.service import (JointTicket, PlanService, PlanTicket,
                            default_service)
from ..core.polytope import Affine, MemorySpec
from ..models import Model
from ..launch import steps as steps_mod


@dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


def _page_program(max_len: int, page: int, readers: int) -> Program:
    mem = MemorySpec("kv_pool", dims=(max_len,), word_bits=16, ports=1)
    return Program(
        root=Ctrl("decode", Sched.INNER,
                  counters=[Counter("r", 0, 1, readers, par=readers),
                            Counter("j", 0, 1, page)],
                  accesses=[AccessDecl("kv_pool", (Affine.of(r=page, j=1),))]),
        memories={"kv_pool": mem},
    )


def model_memory_program(cfg: ArchConfig, max_len: int, page: int = 128,
                         readers: int = 8) -> Program:
    """One whole-model ``Program``: every banked memory the serving loop
    touches for this architecture, as children of one root controller.

    * ``kv_pool`` -- the paged KV cache every family reads per decode
      tick (``readers`` parallel lanes, ``page``-token pages);
    * ``moe_dispatch`` (MoE families) -- the per-expert token staging
      buffer the router scatters into, ``top_k`` experts in parallel;
    * ``ssm_state`` (SSM families) -- the chunked state the scan
      updates, four head lanes in parallel.

    This is what turns each config in ``configs/`` into a distinct
    joint-planning workload: one ``submit_joint`` co-selects schemes
    for all of a model's pools under a shared budget.
    """
    mems: Dict[str, MemorySpec] = {
        "kv_pool": MemorySpec("kv_pool", dims=(max_len,), word_bits=16,
                              ports=1)}
    kids = [Ctrl("decode", Sched.INNER,
                 counters=[Counter("r", 0, 1, readers, par=readers),
                           Counter("j", 0, 1, page)],
                 accesses=[AccessDecl("kv_pool",
                                      (Affine.of(r=page, j=1),))])]
    if cfg.n_experts > 0:
        slot = max(4, page // 4)
        mems["moe_dispatch"] = MemorySpec(
            "moe_dispatch", dims=(cfg.n_experts * slot,), word_bits=16,
            ports=1)
        par = max(1, cfg.top_k)
        kids.append(Ctrl(
            "route", Sched.INNER,
            counters=[Counter("e", 0, 1, par, par=par),
                      Counter("j", 0, 1, slot)],
            accesses=[AccessDecl("moe_dispatch",
                                 (Affine.of(e=slot, j=1),))]))
    if cfg.ssm_state > 0:
        lanes = 4
        mems["ssm_state"] = MemorySpec(
            "ssm_state", dims=(lanes * cfg.ssm_state,), word_bits=16,
            ports=1)
        kids.append(Ctrl(
            "scan", Sched.INNER,
            counters=[Counter("h", 0, 1, lanes, par=lanes),
                      Counter("j", 0, 1, cfg.ssm_state)],
            accesses=[AccessDecl("ssm_state",
                                 (Affine.of(h=cfg.ssm_state, j=1),))]))
    if len(kids) == 1:
        return Program(root=kids[0], memories=mems)
    return Program(root=Ctrl("model", Sched.FORKJOIN, children=kids),
                   memories=mems)


def joint_ticket(cfg: ArchConfig, max_len: int, page: int = 128,
                 readers: int = 8, *,
                 service: Optional[PlanService] = None,
                 budget: Optional[ResourceBudget] = None,
                 scorer=None, tenant: Optional[str] = None) -> JointTicket:
    """Submit the whole model's banking problems as ONE joint request;
    returns the :class:`~repro.core.service.JointTicket` immediately.

    The server starts on ``ticket.fallback()`` for every pool and
    promotes all of them to the jointly co-selected layouts atomically
    between decode ticks -- never a mixed generation.  ``budget`` caps
    the summed draw (banks / volume / LUT / FF / BRAM / DSP) across all
    of the model's memories.
    """
    from ..core.solver import SolverOptions
    svc = service if service is not None else default_service()
    return svc.submit_joint(
        model_memory_program(cfg, max_len, page=page, readers=readers),
        budget=budget,
        opts=SolverOptions(b_candidates=(page, 1), allow_multidim=False),
        scorer=scorer, tenant=tenant)


def page_ticket(cfg: ArchConfig, max_len: int, page: int = 128,
                readers: int = 8, *,
                service: Optional[PlanService] = None,
                scorer=None, tenant: Optional[str] = None) -> PlanTicket:
    """Submit the KV-pool banking problem (pages = banks); returns the
    :class:`PlanTicket` immediately.

    ``readers`` concurrent decode lanes must never contend on a page.
    The server starts on ``ticket.fallback()`` (one bank = one page, no
    solver work) and hot-swaps to ``ticket.artifact()`` between ticks
    when the solve lands; a warm plan store answers before the ticket is
    even returned.  ``scorer="measured"`` ranks candidates on the
    service's telemetry log (see ``PlanService.enable_telemetry``).
    ``tenant`` names this server on a shared multi-tenant service
    (QoS band, quotas, per-tenant stats -- see
    :mod:`repro.runtime.tenancy`).
    """
    from ..core.solver import SolverOptions
    svc = service if service is not None else default_service()
    return svc.submit(
        _page_program(max_len, page, readers), "kv_pool",
        opts=SolverOptions(b_candidates=(page, 1), allow_multidim=False),
        scorer=scorer, tenant=tenant)


def page_solution(cfg: ArchConfig, max_len: int, page: int = 128,
                  readers: int = 8) -> CompiledBankingPlan:
    """Blocking convenience: the *solved* compiled KV-pool artifact.

    ``page_ticket(...).artifact()`` -- tools and tests that want the final
    layout synchronously; the serving path itself uses the ticket.
    """
    return page_ticket(cfg, max_len, page=page, readers=readers).artifact()


class KVPagePool:
    """Page accounting over a compiled KV banking artifact's layout.

    Pages *are* the artifact's banks and the page size is its bank volume,
    read straight off ``artifact.layout`` -- no local page math.  The
    banking problem is posed per sequence (``dims = (max_len,)``), and the
    decode cache is a dense per-slot region, so every slot owns its own
    ``n_banks`` pages: admission succeeds iff the request's token budget
    fits one slot's pages.  Pages release when the sequence finishes.

    ``swap(artifact)`` re-derives the page geometry -- and every live
    slot's page count -- from a new artifact's layout, which is how the
    server promotes the fallback layout to the solved one mid-flight.
    """

    def __init__(self, artifact: CompiledBankingPlan, slots: int = 1):
        self.slots = slots
        self.owned: Dict[int, int] = {}    # slot -> allocated pages
        self.tokens: Dict[int, int] = {}   # slot -> admitted token budget
        self.swap(artifact)

    def swap(self, artifact: CompiledBankingPlan) -> None:
        """Adopt a new artifact's layout; re-page live allocations."""
        self.artifact = artifact
        self.layout = artifact.layout
        self.page_size = int(self.layout.bank_volume)
        self.pages_per_slot = int(self.layout.n_banks)
        self.owned = {slot: min(self.pages_for(tok), self.pages_per_slot)
                      for slot, tok in self.tokens.items()}

    @property
    def total_pages(self) -> int:
        return self.pages_per_slot * self.slots

    @property
    def used_pages(self) -> int:
        return sum(self.owned.values())

    def pages_for(self, n_tokens: int) -> int:
        return max(1, -(-int(n_tokens) // self.page_size))

    def fits(self, n_tokens: int) -> bool:
        """Can this token budget ever be admitted (into one slot)?"""
        return self.pages_for(n_tokens) <= self.pages_per_slot

    def try_alloc(self, slot: int, n_tokens: int) -> bool:
        need = self.pages_for(n_tokens)
        if need > self.pages_per_slot or slot in self.owned:
            return False
        self.owned[slot] = need
        self.tokens[slot] = int(n_tokens)
        return True

    def release(self, slot: int) -> None:
        self.owned.pop(slot, None)
        self.tokens.pop(slot, None)


class Server:
    """Continuous-batching decode server.

    ``kv_plan`` may be a solved ``CompiledBankingPlan`` (legacy), a
    ``PlanTicket``, or a ``JointTicket``: with a ticket the server
    builds its page pool and token-record table from the ticket's
    fallback -- serving its first tick without waiting on the solver --
    and atomically swaps in the solved artifact between ticks once the
    ticket resolves.  A joint ticket brings the whole model's pools
    (``kv_pool`` plus e.g. ``moe_dispatch`` / ``ssm_state``): ALL of
    them promote to the jointly co-selected layouts in one coherent
    generation between decode ticks, never a mixed one
    (``server.generations`` stays uniform by construction; asserted by
    ``coherent``).
    """

    def __init__(self, model: Model, max_batch: int = 4, max_len: int = 128,
                 kv_plan: Optional[Union[CompiledBankingPlan,
                                         PlanTicket, JointTicket]] = None):
        self.model = model
        self.cfg = model.cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}   # slot -> request
        self._decode = jax.jit(steps_mod.make_serve_step(model))
        self._params = model.init(jax.random.PRNGKey(0))
        self.cache = model.init_cache(max_batch, max_len)
        self._kv_ticket: Optional[PlanTicket] = None
        self._kv_art: Optional[CompiledBankingPlan] = None
        # the joint ticket graph and its satellite pools (every model
        # memory except kv_pool, which owns the record table below)
        self._joint: Optional[JointTicket] = None
        self.pools: Dict[str, KVPagePool] = {}
        self.generations: Dict[str, int] = {}
        self._joint_version = 0
        self._joint_adopted_final = False
        # demotion hot-swap: remember which service answered the KV plan
        # (and under which key) so _maybe_swap_kv can poll its telemetry
        # hub for a replacement ticket after the served plan is demoted
        self._kv_service = (kv_plan._service
                            if isinstance(kv_plan, (PlanTicket, JointTicket))
                            else None)
        self._kv_key = ((kv_plan.signature, kv_plan.scorer_name)
                        if isinstance(kv_plan, PlanTicket) else None)
        art: Optional[CompiledBankingPlan] = None
        if isinstance(kv_plan, JointTicket):
            self._joint = kv_plan
            arts = (kv_plan.artifacts() if kv_plan.done()
                    else kv_plan.fallback())
            if "kv_pool" not in arts:
                raise ValueError(
                    "joint ticket has no 'kv_pool' member; build the "
                    "program with model_memory_program()")
            art = arts["kv_pool"]
            for name, a in arts.items():
                if name != "kv_pool":
                    self.pools[name] = KVPagePool(a, slots=max_batch)
            self.generations = {name: 0 for name in arts}
            self._joint_adopted_final = kv_plan.done()
        elif isinstance(kv_plan, PlanTicket):
            # serve NOW: solved artifact when already done, else fallback.
            # Only drop the ticket once its solved artifact was actually
            # adopted -- a solve landing (or failing) between these calls
            # must still hot-swap (or keep serving the fallback) later.
            self._kv_ticket = kv_plan
            if kv_plan.done():
                try:
                    art = kv_plan.artifact()
                    self._kv_ticket = None
                except Exception:
                    art = None   # solve failed: fall back, like mid-serve
            if art is None:
                art = kv_plan.fallback()
        elif kv_plan is not None:
            art = kv_plan
        self.pager = (KVPagePool(art, slots=max_batch)
                      if art is not None else None)
        self.kv_records = None    # bank-major (banks, vol, max_batch) int32
        self._pending_records: List[tuple] = []   # (pos, slot, tok) queue
        self._gather_window = min(4, max_len)
        if art is not None:
            self._adopt_kv_artifact(art, records=None)
        self.swaps = 0
        self.promotions = 0       # best-so-far adoptions before the solve
        self.joint_swaps = 0      # coherent all-pool swaps (final plan)
        self.joint_promotions = 0  # coherent all-pool best-so-far adoptions
        self._kv_best_version = 0
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self.positions = np.zeros(max_batch, np.int64)  # next record slot
        self.ticks = 0
        # rolling serve trace (only when the answering service has
        # tracing on): gather/decode/scatter/promote spans accumulate
        # under one trace_id, finished + restarted every
        # _SERVE_TRACE_TICKS ticks so completed windows reach the
        # flight recorder instead of growing forever
        self._serve_trace: Optional[str] = None

    # -- banked token records ----------------------------------------------------
    def _adopt_kv_artifact(self, art: CompiledBankingPlan,
                           records) -> None:
        """(Re)build the bank-major record table for a (new) artifact;
        ``records`` carries logical rows across a swap."""
        self._kv_art = art
        if records is None:
            records = jnp.zeros((self.max_len, self.max_batch), jnp.int32)
        self.kv_records = art.pack(records)

    def _record(self, slot: int, tok: int) -> None:
        """Queue one token record at the slot's next position.  Records
        land in the bank-major table at the next flush, placed by the
        artifact's scatter kernel (same resolution circuit the gather
        reads through)."""
        pos = int(self.positions[slot])
        if self.kv_records is not None and pos < self.max_len:
            self._pending_records.append((pos, slot, int(tok)))
        self.positions[slot] = pos + 1

    def _flush_records(self) -> None:
        """Drain queued token records through ONE batched banked scatter
        -- the write-path twin of the tick's batched gather.  The
        artifact's BA/BO circuit places every row in the kernel's index
        map; no host-side bank arithmetic."""
        if not self._pending_records:
            return
        pend, self._pending_records = self._pending_records, []
        rows = np.asarray([p for p, _, _ in pend], np.int64)
        cols = np.asarray([s for _, s, _ in pend], np.int64)
        vals = np.asarray([t for _, _, t in pend], np.int32)
        self.kv_records = self._kv_art.scatter(self.kv_records, rows, vals,
                                               col=cols)

    def _gather_next_tokens(self) -> Dict[int, int]:
        """Each active slot's decode input, via ONE batched banked gather.

        Stacks every active slot's trailing ``W`` record positions into a
        ``(slots, W)`` index matrix -- a single ``pallas_call`` resolves
        all of them through the compiled BA/BO circuit.  The last column
        is the most recent record: the next decode input.
        """
        self._flush_records()     # queued writes land before any read
        slots = sorted(self.active)
        W = self._gather_window
        rows = np.zeros((len(slots), W), np.int32)
        for i, s in enumerate(slots):
            pos = min(int(self.positions[s]), self.max_len)
            rows[i] = np.clip(np.arange(pos - W, pos), 0, self.max_len - 1)
        got = self._kv_art.gather(self.kv_records, jnp.asarray(rows))
        got = np.asarray(got)                      # (slots, W, max_batch)
        out = {}
        for i, s in enumerate(slots):
            if int(self.positions[s]) <= self.max_len:
                out[s] = int(got[i, -1, s])
            else:  # records past max_len aren't stored; fall back
                out[s] = getattr(self.active[s], "_next", 1)
        return out

    # -- hot swap -----------------------------------------------------------------
    def _swap_to(self, art: CompiledBankingPlan) -> None:
        """Adopt a new layout atomically from the decode loop's point of
        view: the record table is unpacked from the old layout and
        repacked into the new one, the pager re-pages live slots, and
        the next tick's gather runs the new resolution circuit over
        identical logical records."""
        self._flush_records()     # pending writes belong to the old layout
        flat = self._kv_art.unpack(self.kv_records)   # logical rows survive
        self._adopt_kv_artifact(art, records=flat)
        self.pager.swap(art)

    def _maybe_swap_kv(self) -> None:
        """Between ticks: promote the page layout toward the solver.

        While the sharded search streams, the ticket's **best-so-far**
        scheme is adopted whenever it improves (the search never
        regresses, so each promotion strictly improves the layout); once
        the ticket resolves, the final solved artifact is swapped in --
        same winner the monolithic solver would have produced.

        With telemetry enabled on the answering service, a served layout
        the measurements demoted leaves a *replacement* re-solve ticket
        on the hub; adopting it here closes the self-correction loop --
        measure, demote, re-solve, hot-swap -- without the server ever
        blocking.
        """
        t = self._kv_ticket
        if t is None and self._kv_service is not None \
                and self._kv_key is not None:
            hub = getattr(self._kv_service, "telemetry", None)
            if hub is not None:
                t = hub.replacement(self._kv_key)
                if t is not None:
                    self._kv_ticket = t
                    self._kv_best_version = 0
        if t is None:
            return
        if t.done():
            self._kv_ticket = None
            try:
                art = t.artifact()
            except Exception:
                return  # solve failed: keep serving the current layout
            if art.layout == self._kv_art.layout:
                return  # a promotion already landed the winning layout
            self._swap_to(art)
            self.swaps += 1
            return
        version = t.best_version()
        if version == self._kv_best_version:
            return
        self._kv_best_version = version
        art = t.best_so_far_artifact()
        if art is None or art.layout == self._kv_art.layout:
            return
        self._swap_to(art)
        self.promotions += 1

    # -- coherent multi-pool swap ---------------------------------------------
    @property
    def coherent(self) -> bool:
        """True iff every pool serves the same joint generation -- the
        invariant the atomic all-pool swap maintains: a decode tick
        never sees a mixed generation."""
        return len(set(self.generations.values())) <= 1

    def _swap_all(self, arts: Dict[str, CompiledBankingPlan]) -> int:
        """Adopt a whole joint selection atomically between ticks: the
        KV record table repacks, every satellite pool re-pages, and ALL
        pool generations advance to one new value in the same swap --
        no tick ever reads pools from two generations.  Returns how
        many pools actually changed layout."""
        changed = 0
        kv = arts.get("kv_pool")
        if kv is not None and self._kv_art is not None \
                and kv.layout != self._kv_art.layout:
            self._swap_to(kv)
            changed += 1
        for name, pool in self.pools.items():
            a = arts.get(name)
            if a is not None and a.layout != pool.artifact.layout:
                pool.swap(a)
                changed += 1
        gen = max(self.generations.values(), default=0) + 1
        for name in self.generations:
            self.generations[name] = gen
        return changed

    def _maybe_swap_joint(self) -> None:
        """Between ticks: promote ALL pools toward the joint selection.

        While member solves stream, the joint ticket re-co-selects
        progressively; whenever the *joint* selection changes (its
        ``best_version`` bumps) every pool adopts its newly selected
        layout in one coherent swap.  Once the ticket resolves, the
        final certified selection lands the same way -- never a mixed
        generation."""
        jt = self._joint
        if jt is None:
            return
        if jt.done():
            if self._joint_adopted_final:
                return
            self._joint_adopted_final = True
            try:
                arts = jt.artifacts()
            except Exception:
                return   # selection failed: keep serving current layouts
            if self._swap_all(arts):
                self.joint_swaps += 1
            return
        version = jt.best_version()
        if version == self._joint_version:
            return
        self._joint_version = version
        try:
            arts = jt.artifacts()
        except Exception:
            return
        if self._swap_all(arts):
            self.joint_promotions += 1

    # -- admission -------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.max_batch):
            if slot in self.active or not self.queue:
                continue
            req = self.queue[0]
            if self.pager is not None:
                need_tokens = len(req.prompt) + req.max_new
                if not self.pager.fits(need_tokens):
                    # can never fit a slot: reject instead of deadlocking
                    self.queue.popleft()
                    req.done = True
                    continue
                self.pager.try_alloc(slot, need_tokens)
            self.queue.popleft()
            self.positions[slot] = 0
            # per-request prefill: run the prompt through decode one token at
            # a time into this slot (batch=1 prefill folded into the shared
            # cache; a production server runs a separate prefill graph)
            toks = req.prompt
            for t in toks:
                self.tokens = self.tokens.at[slot, 0].set(int(t))
                nxt, _, self.cache = self._decode(
                    self._params, self.cache, self.tokens)
                self._record(slot, int(t))
            nxt_tok = int(np.asarray(nxt)[slot, 0])
            req._next = nxt_tok
            self._record(slot, nxt_tok)   # the next tick's decode input
            self.active[slot] = req

    # -- decode tick -------------------------------------------------------------
    def tick(self):
        """One decode tick.  When the KV plan's service has telemetry
        enabled, ticks that decoded (active slots) are wall-timed and
        logged as ``op="tick"`` observations against the serving
        artifact -- end-to-end evidence alongside the per-call
        gather/scatter hooks."""
        hub = getattr(self._kv_service, "telemetry", None)
        art = self._kv_art
        if hub is None or art is None or not art.signature:
            self._tick()
            return
        before = self.ticks
        t0 = time.perf_counter()
        self._tick()
        if self.ticks > before:   # idle calls (nothing active) don't count
            hub.observe(art, "tick", (self.max_batch,),
                        time.perf_counter() - t0)

    _SERVE_TRACE_TICKS = 256   # ticks per rolling serve-trace window

    def _serve_tracer(self):
        """(tracer, serve trace_id) off the answering service, or
        (None, None) -- the serve loop traces only when the plan
        service does."""
        tr = getattr(self._kv_service, "tracer", None)
        if tr is None:
            return None, None
        tid = self._serve_trace
        if tid is None:
            from ..core.tracing import new_trace_id
            tid = self._serve_trace = new_trace_id()
            tr.label(tid, "serve loop")
        return tr, tid

    def _tick(self):
        tr, tid = self._serve_tracer()
        metrics = getattr(self._kv_service, "metrics", None)
        t_tick = time.perf_counter()
        swaps0 = self.swaps + self.promotions \
            + self.joint_swaps + self.joint_promotions
        if self._joint is not None:
            self._maybe_swap_joint()
        else:
            self._maybe_swap_kv()
        if tr is not None and self.swaps + self.promotions \
                + self.joint_swaps + self.joint_promotions > swaps0:
            tr.record(tid, "promote", t_tick, time.perf_counter(),
                      swaps=self.swaps, promotions=self.promotions,
                      joint_swaps=self.joint_swaps,
                      joint_promotions=self.joint_promotions)
        self._admit()
        if not self.active:
            return
        if self.kv_records is not None:
            t_g = time.perf_counter()
            nxt_in = self._gather_next_tokens()   # one batched banked gather
            t_g_end = time.perf_counter()
            if tr is not None:
                tr.record(tid, "gather", t_g, t_g_end,
                          slots=len(self.active))
            if metrics is not None:
                metrics.observe("serve_gather_ms", (t_g_end - t_g) * 1e3)
        else:
            nxt_in = {s: getattr(r, "_next", 1)
                      for s, r in self.active.items()}
        for slot in self.active:
            self.tokens = self.tokens.at[slot, 0].set(nxt_in[slot])
        t_d = time.perf_counter()
        nxt, _, self.cache = self._decode(self._params, self.cache,
                                          self.tokens)
        nxt = np.asarray(nxt)
        if tr is not None:
            tr.record(tid, "decode", t_d, time.perf_counter(),
                      slots=len(self.active))
        finished = []
        for slot, req in self.active.items():
            tok = int(nxt[slot, 0])
            req.out.append(tok)
            req._next = tok
            self._record(slot, tok)
            if len(req.out) >= req.max_new:
                req.done = True
                finished.append(slot)
        for slot in finished:
            del self.active[slot]
            if self.pager is not None:
                self.pager.release(slot)
        if self.kv_records is not None:
            t_s = time.perf_counter()
            self._flush_records()   # this tick's records land this tick
            t_s_end = time.perf_counter()
            if tr is not None:
                tr.record(tid, "scatter", t_s, t_s_end)
            if metrics is not None:
                metrics.observe("serve_scatter_ms",
                                (t_s_end - t_s) * 1e3)
        self.ticks += 1
        if metrics is not None:
            metrics.observe("serve_tick_ms",
                            (time.perf_counter() - t_tick) * 1e3)
        if tr is not None and self.ticks % self._SERVE_TRACE_TICKS == 0:
            # roll the window: the finished trace reaches the flight
            # recorder; the next tick starts a fresh trace_id
            tr.finish(tid, status="ok")
            self._serve_trace = None

    def run(self, max_ticks: int = 1000):
        while (self.queue or self.active) and self.ticks < max_ticks:
            self.tick()
        # flush a partial serve-trace window so short runs still land
        # their gather/decode/scatter/promote spans in the recorder
        if self._serve_trace is not None:
            tr = getattr(self._kv_service, "tracer", None)
            if tr is not None:
                tr.finish(self._serve_trace, status="ok")
            self._serve_trace = None
