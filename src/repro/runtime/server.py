"""Batched serving loop: continuous-batching decode with a paged KV cache.

Serving structure (vLLM-style, TPU-native):

* requests queue in; the scheduler packs up to ``max_batch`` active
  sequences into the fixed decode batch (slots);
* prefill runs per request (chunked attention), its KV written into the
  slot's region of the cache;
* one fused ``serve_step`` decodes a token for every active slot per tick;
* finished sequences (EOS or max_len) free their slot for the next queued
  request -- continuous batching.

The cache pages are banks from the banking planner (pages = banks, page
size = bank volume): ``page_solution()`` returns the **compiled** plan
artifact (a ``CompiledBankingPlan``), and the page accounting
(:class:`KVPagePool`) reads page count and page size off that artifact's
physical layout instead of re-deriving "pages = banks" arithmetic locally
-- the scheduler and the Pallas banked-gather kernel agree on the layout
by construction.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.artifact import CompiledBankingPlan
from ..core.controller import AccessDecl, Counter, Ctrl, Program, Sched
from ..core.planner import default_planner
from ..core.polytope import Affine, MemorySpec
from ..models import Model
from ..launch import steps as steps_mod


@dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


def page_solution(cfg: ArchConfig, max_len: int, page: int = 128,
                  readers: int = 8) -> CompiledBankingPlan:
    """Compiled banking artifact for the KV pool: pages = banks.

    ``readers`` concurrent decode lanes must never contend on a page.

    Every decode tick poses the structurally identical KV-pool problem, so
    this goes through the shared planner twice over: the first call solves
    and lowers, every later call is a signature-keyed cache hit for both
    the plan and its compiled artifact (zero solver or lowering work on
    the serving hot path).  The returned artifact owns the physical layout
    the pager and the banked-gather kernel share.
    """
    mem = MemorySpec("kv_pool", dims=(max_len,), word_bits=16, ports=1)
    prog = Program(
        root=Ctrl("decode", Sched.INNER,
                  counters=[Counter("r", 0, 1, readers, par=readers),
                            Counter("j", 0, 1, page)],
                  accesses=[AccessDecl("kv_pool", (Affine.of(r=page, j=1),))]),
        memories={"kv_pool": mem},
    )
    from ..core.solver import SolverOptions
    plan = default_planner().plan(
        prog, "kv_pool",
        opts=SolverOptions(b_candidates=(page, 1), allow_multidim=False))
    return plan.compile()


class KVPagePool:
    """Page accounting over a compiled KV banking artifact's layout.

    Pages *are* the artifact's banks and the page size is its bank volume,
    read straight off ``artifact.layout`` -- no local page math.  The
    banking problem is posed per sequence (``dims = (max_len,)``), and the
    decode cache is a dense per-slot region, so every slot owns its own
    ``n_banks`` pages: admission succeeds iff the request's token budget
    fits one slot's pages.  Pages release when the sequence finishes.
    """

    def __init__(self, artifact: CompiledBankingPlan, slots: int = 1):
        self.layout = artifact.layout
        self.page_size = int(self.layout.bank_volume)
        self.pages_per_slot = int(self.layout.n_banks)
        self.slots = slots
        self.owned: Dict[int, int] = {}   # slot -> allocated pages

    @property
    def total_pages(self) -> int:
        return self.pages_per_slot * self.slots

    @property
    def used_pages(self) -> int:
        return sum(self.owned.values())

    def pages_for(self, n_tokens: int) -> int:
        return max(1, -(-int(n_tokens) // self.page_size))

    def fits(self, n_tokens: int) -> bool:
        """Can this token budget ever be admitted (into one slot)?"""
        return self.pages_for(n_tokens) <= self.pages_per_slot

    def try_alloc(self, slot: int, n_tokens: int) -> bool:
        need = self.pages_for(n_tokens)
        if need > self.pages_per_slot or slot in self.owned:
            return False
        self.owned[slot] = need
        return True

    def release(self, slot: int) -> None:
        self.owned.pop(slot, None)


class Server:
    def __init__(self, model: Model, max_batch: int = 4, max_len: int = 128,
                 kv_plan: Optional[CompiledBankingPlan] = None):
        self.model = model
        self.cfg = model.cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}   # slot -> request
        self._decode = jax.jit(steps_mod.make_serve_step(model))
        self._params = model.init(jax.random.PRNGKey(0))
        self.cache = model.init_cache(max_batch, max_len)
        self.pager = (KVPagePool(kv_plan, slots=max_batch)
                      if kv_plan is not None else None)
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self.positions = np.zeros(max_batch, np.int64)
        self.ticks = 0

    # -- admission -------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.max_batch):
            if slot in self.active or not self.queue:
                continue
            req = self.queue[0]
            if self.pager is not None:
                need_tokens = len(req.prompt) + req.max_new
                if not self.pager.fits(need_tokens):
                    # can never fit a slot: reject instead of deadlocking
                    self.queue.popleft()
                    req.done = True
                    continue
                self.pager.try_alloc(slot, need_tokens)
            self.queue.popleft()
            # per-request prefill: run the prompt through decode one token at
            # a time into this slot (batch=1 prefill folded into the shared
            # cache; a production server runs a separate prefill graph)
            toks = req.prompt
            for t in toks:
                self.tokens = self.tokens.at[slot, 0].set(int(t))
                nxt, _, self.cache = self._decode(
                    self._params, self.cache, self.tokens)
            req._next = int(np.asarray(nxt)[slot, 0])
            self.active[slot] = req

    # -- decode tick -------------------------------------------------------------
    def tick(self):
        self._admit()
        if not self.active:
            return
        for slot, req in self.active.items():
            self.tokens = self.tokens.at[slot, 0].set(
                getattr(req, "_next", 1))
        nxt, _, self.cache = self._decode(self._params, self.cache,
                                          self.tokens)
        nxt = np.asarray(nxt)
        finished = []
        for slot, req in self.active.items():
            tok = int(nxt[slot, 0])
            req.out.append(tok)
            req._next = tok
            if len(req.out) >= req.max_new:
                req.done = True
                finished.append(slot)
        for slot in finished:
            del self.active[slot]
            if self.pager is not None:
                self.pager.release(slot)
        self.ticks += 1

    def run(self, max_ticks: int = 1000):
        while (self.queue or self.active) and self.ticks < max_ticks:
            self.tick()
