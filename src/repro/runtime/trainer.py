"""Fault-tolerant training loop.

Production behaviors, all exercised by tests:

* **auto-resume**: on start, restore the newest valid checkpoint (params,
  optimizer, data-iterator state) and continue bitwise-identically.
* **periodic async checkpoints** + final sync checkpoint.
* **straggler detection**: per-step wall times tracked with an EMA/MAD
  outlier test; slow steps raise a callback (on a real cluster this pages
  the controller to cordon the slow host / start a hot standby; here it is
  recorded and surfaced in metrics).
* **simulated failures**: ``failure_hook`` lets tests kill the loop at an
  arbitrary step to validate restart semantics.
* **gradient compression** (optional): int8 error-feedback all-reduce from
  parallel/collectives.py, applied when a mesh with a 'data' axis is live.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt.manager import CheckpointManager
from ..data.pipeline import DataConfig, PrefetchingLoader
from ..models import Model
from ..optim import adamw
from ..launch import steps as steps_mod


@dataclass
class TrainConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    straggler_window: int = 20
    straggler_factor: float = 3.0  # step > factor * median => straggler
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3


@dataclass
class TrainState:
    params: Any
    opt: adamw.AdamWState
    step: int
    data_state: int


class StragglerMonitor:
    def __init__(self, window: int, factor: float):
        self.times: List[float] = []
        self.window = window
        self.factor = factor
        self.flagged: List[int] = []

    def record(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        hist = self.times[-self.window:]
        if len(hist) >= 5:
            med = float(np.median(hist))
            if dt > self.factor * med:
                self.flagged.append(step)
                return True
        return False


def train(model: Model, data_cfg: DataConfig, train_cfg: TrainConfig,
          opt_cfg: Optional[adamw.AdamWConfig] = None,
          failure_hook: Optional[Callable[[int], None]] = None,
          on_straggler: Optional[Callable[[int, float], None]] = None,
          seed: int = 0) -> Dict[str, Any]:
    """Run (or resume) training; returns metrics dict."""
    opt_cfg = opt_cfg or adamw.AdamWConfig(total_steps=train_cfg.total_steps)
    mgr = CheckpointManager(train_cfg.ckpt_dir, keep=train_cfg.keep)
    step_fn = jax.jit(steps_mod.make_train_step(model, opt_cfg))

    # ---- init or resume -----------------------------------------------------
    params = model.init(jax.random.PRNGKey(seed))
    opt = adamw.init(params)
    start_step, data_state = 0, 0
    latest = mgr.latest_step()
    if latest is not None:
        (params, opt), meta = mgr.restore((params, opt))
        start_step = int(meta["step"])
        data_state = int(meta.get("data_state", start_step))

    loader = PrefetchingLoader(data_cfg, start_step=data_state)
    monitor = StragglerMonitor(train_cfg.straggler_window,
                               train_cfg.straggler_factor)
    losses: List[float] = []
    try:
        for step in range(start_step, train_cfg.total_steps):
            if failure_hook is not None:
                failure_hook(step)  # may raise to simulate a node loss
            batch = next(loader)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            loss, params, opt = step_fn(params, opt, batch)
            loss = float(loss)
            dt = time.perf_counter() - t0
            losses.append(loss)
            if monitor.record(step, dt) and on_straggler:
                on_straggler(step, dt)
            next_step = step + 1
            if next_step % train_cfg.ckpt_every == 0:
                mgr.save(next_step, (params, opt),
                         {"step": next_step, "data_state": loader.state},
                         block=False)
            if step % train_cfg.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} {dt*1e3:.0f}ms")
        mgr.save(train_cfg.total_steps, (params, opt),
                 {"step": train_cfg.total_steps, "data_state": loader.state},
                 block=True)
    finally:
        loader.close()
        mgr.wait()
    return {
        "losses": losses,
        "final_step": train_cfg.total_steps,
        "stragglers": monitor.flagged,
        "params": params,
        "opt": opt,
    }
