"""Multi-tenant planning plane: QoS classes, admission control, fair share.

One :class:`~repro.core.service.PlanService` (and one plan store, and one
solve fabric) is meant to serve MANY consumers -- every ``Server`` in a
fleet, every sharding bridge, every batch re-plan job.  The moment those
consumers share a solver, a noisy one can starve the rest: a batch tenant
flooding cold solves pushes an interactive tenant's ticket behind seconds
of queue.  This module gives the service a **tenant dimension**:

* :class:`QoSClass` -- a named service level.  ``priority`` is the ticket
  priority *band* the tenant's submits land in (lower bands drain first,
  strictly), ``weight`` is its fair-share weight *within* a band, and the
  quota knobs bound how much of the shared plane one tenant may hold:
  ``max_inflight`` (queued + solving cold solves), ``max_deferred``
  (admission backlog before shedding), ``shard_budget`` (per-solve
  fan-out cap) and ``fabric_lease_cap`` (concurrent remote leases per
  solve, so one tenant's solve can't occupy every worker's lease
  window).
* :class:`TenantRegistry` -- named tenants bound to QoS classes.  The
  ``"default"`` tenant always exists (permissive: no quotas, band 0,
  weight 1), so untagged submits behave exactly as before tenancy.
* :class:`AdmissionController` -- the gate on ``PlanService.submit``.
  An over-quota cold solve is **deferred**, not dropped: the ticket is
  honest about it (``status == "deferred"``, ``ticket.deferred``), its
  fallback artifact still serves immediately, and the solve queues
  automatically when one of the tenant's in-flight solves finishes.
  Past ``max_deferred`` the submit is **shed**: the ticket fails with a
  concrete :class:`AdmissionError` (``result()`` raises; the fallback
  still executes) -- never a silent drop.
* :class:`FairShareQueue` -- a drop-in for the service's
  ``queue.PriorityQueue`` over ``(priority, seq, payload, ticket)``
  items.  Bands are strict (an interactive-band entry always drains
  before a batch-band one); *within* a band, tenants drain by weighted
  stride scheduling (a weight-8 tenant gets ~8x the pops of a weight-1
  tenant under contention); within one tenant's band the order is
  **deterministic FIFO** (the monotone submit sequence number breaks
  every tie -- equal-priority submits solve in submit order).

``PlanService(tenants=TenantRegistry(...))`` wires all of it in;
``submit(..., tenant="name")`` tags a submit;
``service.stats.for_tenant("name")`` is the tenant's exact
:class:`~repro.core.service.ServiceStats` slice (every counter sums
across slices to the global value).  ``launch/serve_fleet.py`` runs the
whole story: three servers with different model configs, one shared
service, a deliberately noisy batch tenant, bounded interactive latency.

This module imports nothing from ``repro.core`` (the service imports
*it*), so it stays cycle-free and importable from worker processes.
"""

from __future__ import annotations

import heapq
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Tuple, Union

DEFAULT_TENANT = "default"

# One pop's stride is _STRIDE / weight: a tenant's accumulated "pass"
# advances slower the heavier its weight, so it wins more pops.
_STRIDE = 1024.0


class AdmissionError(RuntimeError):
    """A submit refused by admission control (tenant over quota with a
    full deferral backlog).  The ticket that carries it still serves its
    fallback artifact -- shedding is honest, never silent."""


@dataclass(frozen=True)
class QoSClass:
    """A named service level (see module docstring for the knobs)."""

    name: str
    priority: int = 0                       # ticket priority band offset
    weight: float = 1.0                     # fair share within the band
    max_inflight: Optional[int] = None      # queued+solving cold solves
    max_deferred: Optional[int] = None      # deferral backlog before shed
    shard_budget: Optional[int] = None      # per-solve fan-out cap
    fabric_lease_cap: Optional[int] = None  # concurrent remote leases


#: The stock classes.  ``interactive`` drains first and fans out freely;
#: ``batch`` sits a band behind with bounded fan-out; ``best_effort``
#: drains last, one shard per solve, two solves in flight.  ``default``
#: is the pre-tenancy behavior: band 0, no quotas.
QOS_CLASSES: Dict[str, QoSClass] = {
    "interactive": QoSClass("interactive", priority=0, weight=8.0),
    "batch": QoSClass("batch", priority=10, weight=2.0, max_inflight=8,
                      shard_budget=2, fabric_lease_cap=4),
    "best_effort": QoSClass("best_effort", priority=20, weight=1.0,
                            max_inflight=2, shard_budget=1,
                            fabric_lease_cap=2),
    DEFAULT_TENANT: QoSClass(DEFAULT_TENANT),
}


def resolve_qos(qos: Union[str, QoSClass]) -> QoSClass:
    if isinstance(qos, QoSClass):
        return qos
    try:
        return QOS_CLASSES[qos]
    except KeyError:
        raise ValueError(f"unknown QoS class {qos!r}; one of "
                         f"{sorted(QOS_CLASSES)} (or pass a QoSClass)")


class Tenant:
    """One registered consumer of the shared planning plane."""

    def __init__(self, name: str, qos: QoSClass):
        self.name = name
        self.qos = qos
        self.registered_at = time.time()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<Tenant {self.name} qos={self.qos.name}>"


class TenantRegistry:
    """Named tenants -> QoS classes.  The ``"default"`` tenant always
    exists; unknown tenant names resolve by auto-registering under
    ``default_qos`` so untagged or ad-hoc submits are never refused --
    they just get the permissive default treatment (and their own stats
    slice)."""

    def __init__(self, default_qos: Union[str, QoSClass] = DEFAULT_TENANT):
        self._lock = threading.Lock()
        self.default_qos = resolve_qos(default_qos)
        self._tenants: Dict[str, Tenant] = {
            DEFAULT_TENANT: Tenant(DEFAULT_TENANT, QOS_CLASSES[DEFAULT_TENANT]),
        }

    def register(self, name: str,
                 qos: Union[str, QoSClass] = "batch") -> Tenant:
        """Register (or re-class) a tenant; idempotent."""
        q = resolve_qos(qos)
        with self._lock:
            tenant = self._tenants.get(name)
            if tenant is None:
                tenant = Tenant(name, q)
                self._tenants[name] = tenant
            else:
                tenant.qos = q
            return tenant

    def get(self, name: str) -> Tenant:
        with self._lock:
            return self._tenants[name]

    def resolve(self, name: Optional[str]) -> Tenant:
        """The tenant for a submit's ``tenant=`` value (None = default,
        unknown names auto-register under ``default_qos``)."""
        if name is None:
            name = DEFAULT_TENANT
        with self._lock:
            tenant = self._tenants.get(name)
            if tenant is None:
                tenant = Tenant(name, self.default_qos)
                self._tenants[name] = tenant
            return tenant

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._tenants)

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._tenants


class AdmissionController:
    """Per-tenant in-flight quota with an ordered deferral backlog.

    ``try_acquire`` claims one in-flight slot (False when the tenant is
    at ``max_inflight``); ``defer`` parks the over-quota entry (False
    when the backlog is at ``max_deferred`` -- the caller sheds);
    ``release`` frees a slot and returns the deferred entries that can
    be queued NOW (oldest first, each with a freshly acquired slot).
    """

    def __init__(self, registry: TenantRegistry):
        self.registry = registry
        # a MetricsRegistry (PlanService.enable_tracing assigns it):
        # deferral-backlog depth lands as a gauge on every change
        self.metrics = None
        self._lock = threading.Lock()
        self._inflight: Dict[str, int] = {}
        self._deferred: Dict[str, Deque] = {}

    def _try_acquire_locked(self, name: str) -> bool:
        cap = self.registry.resolve(name).qos.max_inflight
        have = self._inflight.get(name, 0)
        if cap is not None and have >= cap:
            return False
        self._inflight[name] = have + 1
        return True

    def try_acquire(self, name: str) -> bool:
        with self._lock:
            return self._try_acquire_locked(name)

    def defer(self, name: str, entry) -> bool:
        cap = self.registry.resolve(name).qos.max_deferred
        with self._lock:
            backlog = self._deferred.setdefault(name, deque())
            if cap is not None and len(backlog) >= cap:
                return False
            backlog.append(entry)
            depth = len(backlog)
        if self.metrics is not None:
            self.metrics.set_gauge("deferred_backlog", depth, tenant=name)
        return True

    def release(self, name: str) -> List:
        """Free one of ``name``'s in-flight slots; promote as much of
        its deferral backlog as the freed capacity allows."""
        out: List = []
        with self._lock:
            self._inflight[name] = max(0, self._inflight.get(name, 0) - 1)
            backlog = self._deferred.get(name)
            while backlog and self._try_acquire_locked(name):
                out.append(backlog.popleft())
            depth = len(backlog) if backlog else 0
        if self.metrics is not None and out:
            self.metrics.set_gauge("deferred_backlog", depth, tenant=name)
        return out

    def inflight(self, name: str) -> int:
        with self._lock:
            return self._inflight.get(name, 0)

    def pending(self) -> int:
        """Total deferred entries across every tenant."""
        with self._lock:
            return sum(len(d) for d in self._deferred.values())

    def pending_for(self, name: str) -> int:
        with self._lock:
            return len(self._deferred.get(name, ()))


class FairShareQueue:
    """Priority-band + weighted-fair-share queue over the service's
    ``(priority, seq, payload, ticket)`` items (see module docstring).

    Drop-in for the subset of ``queue.PriorityQueue`` the service uses:
    ``put`` / blocking ``get`` / ``task_done`` / ``qsize`` /
    ``unfinished_tasks``.  The tenant of an item is read off its
    ticket's ``tenant`` attribute (items without one -- e.g. the
    shutdown sentinel -- drain under the default tenant).
    """

    def __init__(self, registry: Optional[TenantRegistry] = None):
        self._registry = registry
        # a MetricsRegistry (PlanService.enable_tracing assigns it):
        # queue depth as a gauge, pops as a per-tenant counter
        self.metrics = None
        self._cond = threading.Condition()
        self._heaps: Dict[str, List[Tuple]] = {}
        self._pass: Dict[str, float] = {}
        self._size = 0
        self._unfinished = 0

    @staticmethod
    def _tenant_of(item) -> str:
        ticket = item[3] if len(item) > 3 else None
        return getattr(ticket, "tenant", None) or DEFAULT_TENANT

    def _weight(self, name: str) -> float:
        if self._registry is None:
            return 1.0
        return max(1e-6, float(self._registry.resolve(name).qos.weight))

    def put(self, item) -> None:
        name = self._tenant_of(item)
        with self._cond:
            heap = self._heaps.setdefault(name, [])
            if not heap:
                # (re)activation: start at the active minimum pass so a
                # long-idle tenant can't monopolize the next N pops
                active = [self._pass[t] for t, h in self._heaps.items()
                          if h and t in self._pass]
                floor = min(active) if active else 0.0
                self._pass[name] = max(self._pass.get(name, 0.0), floor)
            heapq.heappush(heap, item)
            self._size += 1
            self._unfinished += 1
            self._cond.notify()
            depth = self._size
        if self.metrics is not None:
            self.metrics.set_gauge("queue_depth", depth)

    def get(self):
        with self._cond:
            while self._size == 0:
                self._cond.wait()
            heads = {t: h[0] for t, h in self._heaps.items() if h}
            band = min(head[0] for head in heads.values())
            contenders = [t for t, head in heads.items() if head[0] == band]
            # weighted stride within the band; pass ties break by the
            # head's submit seq -- fully deterministic drain order
            name = min(contenders,
                       key=lambda t: (self._pass.get(t, 0.0), heads[t][1]))
            item = heapq.heappop(self._heaps[name])
            self._pass[name] = (self._pass.get(name, 0.0)
                                + _STRIDE / self._weight(name))
            self._size -= 1
            depth = self._size
        if self.metrics is not None:
            self.metrics.set_gauge("queue_depth", depth)
            self.metrics.inc("queue_pops", tenant=name)
        return item

    def task_done(self) -> None:
        with self._cond:
            self._unfinished -= 1

    @property
    def unfinished_tasks(self) -> int:
        return self._unfinished

    def qsize(self) -> int:
        with self._cond:
            return self._size


__all__ = [
    "AdmissionController",
    "AdmissionError",
    "DEFAULT_TENANT",
    "FairShareQueue",
    "QOS_CLASSES",
    "QoSClass",
    "Tenant",
    "TenantRegistry",
    "resolve_qos",
]
