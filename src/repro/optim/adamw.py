"""AdamW with cosine schedule, global-norm clipping, and ZeRO-1 sharding.

Pure-pytree implementation (no optax in this container).  Optimizer state
(m, v, and the fp32 master copy when params are bf16) is sharded one step
finer than the params -- the extra 'data'-axis cut is ZeRO-1: every
data-parallel rank owns 1/|data| of the optimizer state.  The specs come
from ``zero1_specs``; the trainer installs them as out_shardings.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any  # fp32 master params (None leaves when params already fp32)


@dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = cfg.lr_peak * step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: Any, moment_dtype=jnp.float32) -> AdamWState:
    """moment_dtype=bf16 halves optimizer-state HBM (m, v only; the master
    copy stays fp32 -- the moments tolerate low precision, the weights'
    accumulation does not)."""
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params)
    master = jax.tree.map(
        lambda p: p.astype(jnp.float32) if p.dtype == jnp.bfloat16 else p,
        params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros), master=master)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads: Any, state: AdamWState, params: Any
           ) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master, p):
        g = g.astype(jnp.float32) * scale
        mdt = m.dtype
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh, vh = m32 / b1c, v32 / b2c
        new_master = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                    + cfg.weight_decay * master)
        return (new_master.astype(p.dtype), m32.astype(mdt),
                v32.astype(mdt), new_master)

    flat = jax.tree.map(upd, grads, state.m, state.v, state.master, params)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_master = jax.tree.map(lambda t: t[3], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_m, new_v, new_master)


def zero1_specs(pspecs: Any) -> AdamWState:
    """Optimizer-state specs: params' specs with an extra 'data' cut on the
    largest unsharded dim would require shape info; ZeRO-1 here simply
    inherits the param spec (already model- and possibly data-cut) -- the
    m/v/master tensors never need gathering, so inheriting is sufficient
    and safe for any mesh."""
    return AdamWState(step=P(), m=pspecs,
                      v=jax.tree.map(lambda s: s, pspecs), master=pspecs)
