"""Family dispatch: one uniform Model interface over all ten architectures.

``get_model(cfg)`` returns a ``Model`` with:

* ``init(key)``                          -> params
* ``loss(params, batch)``                -> scalar (train objective)
* ``prefill(params, batch, max_len)``    -> (logits, cache)
* ``decode(params, cache, tokens)``      -> (logits, cache)
* ``init_cache(batch, max_len)``         -> cache
* ``input_specs(shape_cfg)``             handled by launch/dryrun.py

``vlm`` (chameleon) is the dense transformer -- its VQ image tokens live in
the shared 65536 vocabulary, frontend stubbed to token ids.  ``audio``
(whisper) adds precomputed frame embeddings to the batch.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict


from ..configs.base import ArchConfig
from . import encdec, hybrid, moe, ssm, transformer as tfm

Params = Dict[str, Any]


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable
    loss: Callable                  # (params, batch) -> scalar
    prefill: Callable               # (params, batch, max_len) -> (logits, cache)
    decode: Callable                # (params, cache, tokens) -> (logits, cache)
    init_cache: Callable            # (batch, max_len) -> cache


def get_model(cfg: ArchConfig, moe_impl: str = "sorted") -> Model:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return Model(
            cfg=cfg,
            init=partial(tfm.init_dense_params, cfg),
            loss=partial(tfm.lm_loss, cfg),
            prefill=lambda p, batch, max_len: tfm.prefill(
                cfg, p, batch["tokens"], max_len),
            decode=partial(tfm.decode_step, cfg),
            init_cache=partial(tfm.init_cache, cfg),
        )
    if fam == "moe":
        return Model(
            cfg=cfg,
            init=partial(moe.init_moe_params, cfg),
            loss=partial(moe.lm_loss, cfg, impl=moe_impl),
            prefill=lambda p, batch, max_len: moe.prefill(
                cfg, p, batch["tokens"], max_len, impl=moe_impl),
            decode=partial(moe.decode_step, cfg, impl=moe_impl),
            init_cache=partial(tfm.init_cache, cfg),
        )
    if fam == "ssm":
        return Model(
            cfg=cfg,
            init=partial(ssm.init_params, cfg),
            loss=partial(ssm.lm_loss, cfg),
            prefill=lambda p, batch, max_len: ssm.prefill(cfg, p, batch["tokens"]),
            decode=partial(ssm.decode_step, cfg),
            init_cache=lambda batch, max_len: ssm.init_cache(cfg, batch),
        )
    if fam == "hybrid":
        return Model(
            cfg=cfg,
            init=partial(hybrid.init_params, cfg),
            loss=partial(hybrid.lm_loss, cfg),
            prefill=lambda p, batch, max_len: hybrid.prefill(
                cfg, p, batch["tokens"], max_len),
            decode=partial(hybrid.decode_step, cfg),
            init_cache=partial(hybrid.init_cache, cfg),
        )
    if fam in ("encdec", "audio"):
        return Model(
            cfg=cfg,
            init=partial(encdec.init_params, cfg),
            loss=partial(encdec.lm_loss, cfg),
            prefill=lambda p, batch, max_len: encdec.prefill(
                cfg, p, batch["frames"], batch["tokens"], max_len),
            decode=partial(encdec.decode_step, cfg),
            init_cache=None,  # cache comes from prefill (cross-KV needs frames)
        )
    raise ValueError(f"unknown family {fam}")
