"""Shared model layers: norms, RoPE, chunked-online-softmax attention, MLPs.

Attention is implemented as a ``lax.scan`` over KV blocks with online
softmax (flash-attention algorithm in pure JAX).  This never materializes
the (S, S) score matrix, lowers through the SPMD partitioner cleanly (unlike
``pallas_call``, which needs Mosaic), and supports causal + sliding-window
masks computed from iota per block.  The Pallas flash kernel
(repro.kernels.flash_attention) implements the same math for TPU execution
and is validated against the same reference; ``attn_impl`` selects it.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x: Array, w: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))).astype(dt)


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def gelu_mlp(x: Array, w_up: Array, b_up: Array, w_down: Array, b_down: Array) -> Array:
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_up) + b_up)
    return jnp.einsum("...f,fd->...d", h, w_down) + b_down


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, Dh); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked attention (online softmax over KV blocks)
# ---------------------------------------------------------------------------


NEG_INF = -1e30


def _block_mask(q_pos: Array, k_pos: Array, causal: bool, window: Array | int
                ) -> Array:
    """(Sq, Bk) mask from absolute positions; window <= 0 means unlimited."""
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        ok &= dk <= dq
    w = jnp.asarray(window)
    ok &= jnp.where(w > 0, dq - dk < w, True)
    return ok


def decode_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                     kv_len=None, scale=None) -> Array:
    """Sq<=4 fast path: one grouped einsum over the WHOLE KV buffer.

    No block scan => a sequence-sharded KV cache shards cleanly (partial
    softmax stats reduce with one small all-reduce); the score tensor is
    only (B, Sq, H, Sk) for a handful of q rows.
    """
    B, Sq, H, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    q5 = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, rep, Dh)
    s = jnp.einsum("bqhrd,bkhd->bqhrk", q5, k.astype(jnp.float32))
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Sk)
    mask = _block_mask(q_pos, k_pos, causal, window)
    if kv_len is not None:
        mask &= (k_pos < kv_len)[None, :]
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    out = jnp.einsum("bqhrk,bkhd->bqhrd", p, v.astype(jnp.float32))
    out = out / jnp.maximum(p.sum(-1)[..., None], 1e-30)
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def chunked_attention(
    q: Array,                # (B, Sq, H, Dh)
    k: Array,                # (B, Sk, Hkv, Dh)
    v: Array,                # (B, Sk, Hkv, Dh)
    *,
    causal: bool = True,
    window: Array | int = 0,         # sliding window (tokens); 0 = full
    q_offset: Array | int = 0,       # absolute position of q[0] (decode)
    kv_len: Optional[Array] = None,  # valid KV prefix length (decode cache)
    block_k: int = 1024,
    block_q: int = 512,
    scale: Optional[float] = None,
) -> Array:
    B, Sq, H, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    if Sq <= 4 and Sk > block_k:
        return decode_attention(q, k, v, causal=causal, window=window,
                                q_offset=q_offset, kv_len=kv_len, scale=scale)
    if Sq > block_q and Sq % block_q == 0:
        # outer q-blocking bounds the score working set to
        # (B, block_q, H, block_k) per step regardless of sequence length
        nqb = Sq // block_q
        qb = jnp.moveaxis(q.reshape(B, nqb, block_q, H, Dh), 1, 0)

        def one(args):
            qi, i = args
            return chunked_attention(
                qi, k, v, causal=causal, window=window,
                q_offset=q_offset + i * block_q, kv_len=kv_len,
                block_k=block_k, block_q=block_q, scale=scale)

        out = jax.lax.map(one, (qb, jnp.arange(nqb)))
        return jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, Dh)

    nblk = -(-Sk // block_k)
    pad = nblk * block_k - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, block_k, Hkv, Dh)
    vb = v.reshape(B, nblk, block_k, Hkv, Dh)

    # grouped-query layout: (B, Sq, Hkv, rep, Dh) so KV is never re-folded
    q5 = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, rep, Dh)
    q_pos = jnp.arange(Sq) + q_offset
    valid_k = jnp.asarray(Sk if kv_len is None else kv_len)

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, bidx = blk  # (B, Hkv, blk, Dh)
        k_pos = bidx * block_k + jnp.arange(block_k)
        s = jnp.einsum("bqhrd,bhkd->bqhrk", q5, kblk.astype(jnp.float32))
        mask = _block_mask(q_pos, k_pos, causal, window)
        mask &= (k_pos < valid_k)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhrk,bhkd->bqhrd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hkv, rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, rep), jnp.float32)
    acc0 = jnp.zeros((B, Sq, Hkv, rep, Dh), jnp.float32)
    kb_t = jnp.moveaxis(kb, 1, 0).transpose(0, 1, 3, 2, 4)  # (nblk, B, Hkv, blk, Dh)
    vb_t = jnp.moveaxis(vb, 1, 0).transpose(0, 1, 3, 2, 4)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (kb_t, vb_t, jnp.arange(nblk))
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def naive_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    kv_len=None, scale=None) -> Array:
    """Reference implementation (materializes scores) -- small shapes only."""
    B, Sq, H, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                   kr.astype(jnp.float32))
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Sk)
    mask = _block_mask(q_pos, k_pos, causal, window)
    if kv_len is not None:
        mask &= (k_pos < kv_len)[None, :]
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


ATTN_IMPLS = {"chunked": chunked_attention, "naive": naive_attention}


# ---------------------------------------------------------------------------
# Parameter init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))
