"""Whisper-style encoder-decoder (audio family).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, D) -- in real Whisper these come
from two strided Conv1d layers over an 80-bin mel spectrogram.  The
transformer backbone (6L enc + 6L dec, d=512, 8H, ff=2048, vocab 51865) is
implemented fully: bidirectional encoder, causal decoder with cross
attention, learned-sinusoid positions folded into RoPE for uniformity
(noted in DESIGN.md; Whisper itself uses absolute positions + LayerNorm --
structurally equivalent for sizing/dry-run purposes).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..parallel.hints import hint
from .layers import (apply_rope, chunked_attention, dense_init, gelu_mlp,
                     rms_norm, split_keys)
from . import transformer as tfm

Array = jax.Array
Params = Dict[str, Any]


def _attn_params(key, D, H, Hkv, Dh, dtype):
    ks = split_keys(key, 4)
    return {
        "wq": dense_init(ks[0], (D, H * Dh), dtype=dtype),
        "wk": dense_init(ks[1], (D, Hkv * Dh), dtype=dtype),
        "wv": dense_init(ks[2], (D, Hkv * Dh), dtype=dtype),
        "wo": dense_init(ks[3], (H * Dh, D), dtype=dtype),
    }


def _mlp_params(key, D, F, dtype):
    ks = split_keys(key, 2)
    return {
        "w_up": dense_init(ks[0], (D, F), dtype=dtype),
        "b_up": jnp.zeros((F,), dtype),
        "w_down": dense_init(ks[1], (F, D), dtype=dtype),
        "b_down": jnp.zeros((D,), dtype),
    }


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> Params:
    D, H, Hkv, Dh, F = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                        cfg.d_ff)
    Lenc = cfg.n_encoder_layers or cfg.n_layers
    Ldec = cfg.n_layers
    ks = split_keys(key, Lenc + 2 * Ldec + 4)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": jnp.zeros((D,), dtype), "ln2": jnp.zeros((D,), dtype),
                "attn": _attn_params(k1, D, H, Hkv, Dh, dtype),
                "mlp": _mlp_params(k2, D, F, dtype)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": jnp.zeros((D,), dtype), "ln2": jnp.zeros((D,), dtype),
                "ln3": jnp.zeros((D,), dtype),
                "self": _attn_params(k1, D, H, Hkv, Dh, dtype),
                "cross": _attn_params(k2, D, H, Hkv, Dh, dtype),
                "mlp": _mlp_params(k3, D, F, dtype)}

    enc = jax.tree.map(lambda *xs: jnp.stack(xs),
                       *[enc_layer(k) for k in ks[:Lenc]])
    dec = jax.tree.map(lambda *xs: jnp.stack(xs),
                       *[dec_layer(k) for k in ks[Lenc:Lenc + Ldec]])
    return {
        "embed": dense_init(ks[-1], (cfg.vocab, D), scale=0.02, dtype=dtype),
        "ln_enc": jnp.zeros((D,), dtype),
        "ln_f": jnp.zeros((D,), dtype),
        "enc": enc,
        "dec": dec,
    }


def _mha(cfg, ap, xq, xkv, *, causal, q_offset=0, kv_len=None, block_k=1024):
    B, Sq, D = xq.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (xq @ ap["wq"]).reshape(B, Sq, H, Dh)
    k = (xkv @ ap["wk"]).reshape(B, xkv.shape[1], Hkv, Dh)
    v = (xkv @ ap["wv"]).reshape(B, xkv.shape[1], Hkv, Dh)
    q = apply_rope(q, jnp.arange(Sq) + q_offset, cfg.rope_theta)
    k = apply_rope(k, jnp.arange(k.shape[1]), cfg.rope_theta)
    out = chunked_attention(q, k, v, causal=causal, q_offset=q_offset,
                            kv_len=kv_len, block_k=block_k)
    return out.reshape(B, Sq, H * Dh) @ ap["wo"], (k, v)


def encode(cfg: ArchConfig, params: Params, frames: Array,
           block_k: int = 1024) -> Array:
    """frames: precomputed embeddings (B, S_enc, D) -- frontend stub."""
    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, _ = _mha(cfg, lp["attn"], h, h, causal=False, block_k=block_k)
        x = x + a
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + gelu_mlp(h, lp["mlp"]["w_up"], lp["mlp"]["b_up"],
                         lp["mlp"]["w_down"], lp["mlp"]["b_down"])
        return hint(x, "residual"), None

    body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, frames, params["enc"])
    return rms_norm(x, params["ln_enc"], cfg.norm_eps)


def decode_train(cfg: ArchConfig, params: Params, enc_out: Array,
                 tokens: Array, block_k: int = 1024) -> Array:
    x = params["embed"].astype(jnp.bfloat16)[tokens]

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, _ = _mha(cfg, lp["self"], h, h, causal=True, block_k=block_k)
        x = x + a
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        a, _ = _mha(cfg, lp["cross"], h, enc_out, causal=False, block_k=block_k)
        x = x + a
        h = rms_norm(x, lp["ln3"], cfg.norm_eps)
        x = x + gelu_mlp(h, lp["mlp"]["w_up"], lp["mlp"]["b_up"],
                         lp["mlp"]["w_down"], lp["mlp"]["b_down"])
        return hint(x, "residual"), None

    body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec"])
    return rms_norm(x, params["ln_f"], cfg.norm_eps)


def lm_loss(cfg: ArchConfig, params: Params, batch: Dict[str, Array]) -> Array:
    enc_out = encode(cfg, params, batch["frames"].astype(jnp.bfloat16))
    h = decode_train(cfg, params, enc_out, batch["tokens"])
    return tfm.chunked_xent(cfg, params, h, batch["labels"])


class EncDecCache(NamedTuple):
    k_self: Array   # (L, B, Smax, Hkv, Dh)
    v_self: Array
    k_cross: Array  # (L, B, S_enc, Hkv, Dh) -- computed once at prefill
    v_cross: Array
    pos: Array


def prefill(cfg: ArchConfig, params: Params, frames: Array, tokens: Array,
            max_len: int, block_k: int = 1024) -> Tuple[Array, EncDecCache]:
    """Encode audio + run the decoder prompt; cache self+cross KV."""
    enc_out = encode(cfg, params, frames.astype(jnp.bfloat16), block_k)
    B, S = tokens.shape
    x = params["embed"].astype(jnp.bfloat16)[tokens]

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, (k_s, v_s) = _mha(cfg, lp["self"], h, h, causal=True,
                             block_k=block_k)
        x = x + a
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        a, (k_c, v_c) = _mha(cfg, lp["cross"], h, enc_out, causal=False,
                             block_k=block_k)
        x = x + a
        h = rms_norm(x, lp["ln3"], cfg.norm_eps)
        x = x + gelu_mlp(h, lp["mlp"]["w_up"], lp["mlp"]["b_up"],
                         lp["mlp"]["w_down"], lp["mlp"]["b_down"])
        pad = max_len - S
        k_s = jnp.pad(k_s, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_s = jnp.pad(v_s, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, (k_s, v_s, k_c, v_c)

    x, (ks, vs, kc, vc) = jax.lax.scan(body, x, params["dec"])
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = tfm.logits_fn(cfg, params, h[:, -1:])[:, 0]
    return logits, EncDecCache(ks, vs, kc, vc, jnp.asarray(S, jnp.int32))


def decode_step(cfg: ArchConfig, params: Params, cache: EncDecCache,
                tokens: Array, block_k: int = 1024
                ) -> Tuple[Array, EncDecCache]:
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    pos = cache.pos
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def body(x, xs):
        lp, k_s, v_s, k_c, v_c = xs
        B, S, D = x.shape
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        k_new = (h @ lp["self"]["wk"]).reshape(B, S, Hkv, Dh)
        v_new = (h @ lp["self"]["wv"]).reshape(B, S, Hkv, Dh)
        k_new = apply_rope(k_new, jnp.arange(S) + pos, cfg.rope_theta)
        k_s = jax.lax.dynamic_update_slice(k_s, k_new.astype(k_s.dtype),
                                           (0, pos, 0, 0))
        v_s = jax.lax.dynamic_update_slice(v_s, v_new.astype(v_s.dtype),
                                           (0, pos, 0, 0))
        q = (h @ lp["self"]["wq"]).reshape(B, S, H, Dh)
        q = apply_rope(q, jnp.arange(S) + pos, cfg.rope_theta)
        a = chunked_attention(q, k_s, v_s, causal=True, q_offset=pos,
                              kv_len=pos + 1, block_k=block_k)
        x = x + a.reshape(B, S, H * Dh) @ lp["self"]["wo"]
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        q = (h @ lp["cross"]["wq"]).reshape(B, S, H, Dh)
        q = apply_rope(q, jnp.arange(S) + pos, cfg.rope_theta)
        a = chunked_attention(q, k_c, v_c, causal=False, block_k=block_k)
        x = x + a.reshape(B, S, H * Dh) @ lp["cross"]["wo"]
        h = rms_norm(x, lp["ln3"], cfg.norm_eps)
        x = x + gelu_mlp(h, lp["mlp"]["w_up"], lp["mlp"]["b_up"],
                         lp["mlp"]["w_down"], lp["mlp"]["b_down"])
        return x, (k_s, v_s)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec"], cache.k_self, cache.v_self,
                  cache.k_cross, cache.v_cross))
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = tfm.logits_fn(cfg, params, h)[:, 0]
    return logits, EncDecCache(k_new, v_new, cache.k_cross, cache.v_cross,
                               pos + 1)
