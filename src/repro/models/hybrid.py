"""Zamba2-style hybrid: Mamba2 backbone + a shared attention block.

Zamba2 interleaves Mamba2 blocks with a *single shared* transformer block
re-applied at several depths (arXiv:2411.15242).  We scan over groups of
``hybrid_period`` SSM layers; after each group the shared attention block
(one parameter set, per-site KV cache) runs.  Sites = n_layers // period.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..parallel.hints import hint
from .layers import dense_init, rms_norm, split_keys
from . import ssm as ssm_mod
from . import transformer as tfm

Array = jax.Array
Params = Dict[str, Any]


def n_sites(cfg: ArchConfig) -> int:
    return max(1, cfg.n_layers // max(1, cfg.hybrid_period))


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> Params:
    L = cfg.n_layers
    G = n_sites(cfg)
    per = L // G
    ks = split_keys(key, L + 8)
    ssm_layers = jax.tree.map(
        lambda *xs: jnp.stack(xs).reshape((G, per) + xs[0].shape),
        *[ssm_mod.init_ssm_layer(cfg, k, dtype) for k in ks[:L]])
    D, H, Hkv, Dh, F = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                        cfg.d_ff)
    sk = split_keys(ks[L], 8)
    shared = {
        "ln1": jnp.zeros((D,), dtype), "ln2": jnp.zeros((D,), dtype),
        "wq": dense_init(sk[0], (D, H * Dh), dtype=dtype),
        "wk": dense_init(sk[1], (D, Hkv * Dh), dtype=dtype),
        "wv": dense_init(sk[2], (D, Hkv * Dh), dtype=dtype),
        "wo": dense_init(sk[3], (H * Dh, D), dtype=dtype),
        "w_gate": dense_init(sk[4], (D, F), dtype=dtype),
        "w_up": dense_init(sk[5], (D, F), dtype=dtype),
        "w_down": dense_init(sk[6], (F, D), dtype=dtype),
    }
    return {
        "embed": dense_init(ks[L + 1], (cfg.vocab, D), scale=0.02, dtype=dtype),
        "ln_f": jnp.zeros((D,), dtype),
        "ssm": ssm_layers,       # stacked (G, per, ...)
        "shared_attn": shared,   # single parameter set, reused at G sites
    }


class HybridCache(NamedTuple):
    conv: Array    # (G, per, B, W-1, conv_dim)
    state: Array   # (G, per, B, H, P, N)
    k: Array       # (G, B, Smax, Hkv, Dh) -- per-site KV for the shared block
    v: Array
    pos: Array


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> HybridCache:
    d_inner, H, P, N = ssm_mod.dims(cfg)
    conv_dim = d_inner + 2 * N
    G = n_sites(cfg)
    per = cfg.n_layers // G
    return HybridCache(
        jnp.zeros((G, per, batch, cfg.ssm_conv - 1, conv_dim), dtype),
        jnp.zeros((G, per, batch, H, P, N), jnp.float32),
        jnp.zeros((G, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        jnp.zeros((G, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        jnp.zeros((), jnp.int32),
    )


def _group_body(cfg: ArchConfig, shared, *, streaming: bool, block_k: int):
    """Returns the scan body over groups: per-group SSM stack + shared attn."""

    def body(carry, xs):
        x, pos = carry
        if streaming:
            lp_g, conv_g, ssm_g, kc, vc = xs
        else:
            lp_g, = xs
            conv_g = ssm_g = kc = vc = None

        def inner(xc, inner_xs):
            if streaming:
                lp, conv_c, ssm_c = inner_xs
                xc, (conv_c, ssm_c) = ssm_mod.ssm_block(
                    cfg, lp, xc, conv_state=conv_c, ssm_state=ssm_c,
                    streaming=True)
                return xc, (conv_c, ssm_c)
            lp, = inner_xs
            xc, _ = ssm_mod.ssm_block(cfg, lp, xc)
            return xc, None

        if streaming:
            x, (conv_new, ssm_new) = jax.lax.scan(inner, x, (lp_g, conv_g, ssm_g))
            x, (k_new, v_new) = tfm.dense_layer(
                cfg, shared, x, 0, cache_kv=(kc, vc), pos=pos, block_k=block_k)
            return (x, pos), (conv_new, ssm_new, k_new, v_new)
        x, _ = jax.lax.scan(inner, x, (lp_g,))
        x, (k, v) = tfm.dense_layer(cfg, shared, x, 0, block_k=block_k)
        return (hint(x, "residual"), pos), (k, v)

    return body


def forward(cfg: ArchConfig, params: Params, tokens: Array,
            block_k: int = 1024) -> Array:
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    body = jax.checkpoint(
        _group_body(cfg, params["shared_attn"], streaming=False,
                    block_k=block_k), prevent_cse=False)
    (x, _), _ = jax.lax.scan(body, (x, 0), (params["ssm"],))
    return rms_norm(x, params["ln_f"], cfg.norm_eps)


def lm_loss(cfg: ArchConfig, params: Params, batch: Dict[str, Array]) -> Array:
    h = forward(cfg, params, batch["tokens"])
    return tfm.chunked_xent(cfg, params, h, batch["labels"])


def decode_step(cfg: ArchConfig, params: Params, cache: HybridCache,
                tokens: Array, block_k: int = 1024
                ) -> Tuple[Array, HybridCache]:
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    body = _group_body(cfg, params["shared_attn"], streaming=True,
                       block_k=block_k)
    (x, _), (conv_new, ssm_new, k_new, v_new) = jax.lax.scan(
        body, (x, cache.pos),
        (params["ssm"], cache.conv, cache.state, cache.k, cache.v))
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = tfm.logits_fn(cfg, params, h)[:, 0]
    return logits, HybridCache(conv_new, ssm_new, k_new, v_new, cache.pos + 1)


def prefill(cfg: ArchConfig, params: Params, tokens: Array, max_len: int,
            block_k: int = 1024) -> Tuple[Array, HybridCache]:
    B, S = tokens.shape
    x = params["embed"].astype(jnp.bfloat16)[tokens]

    def body(carry, xs):
        x, pos = carry
        lp_g, = xs

        def inner(xc, lp):
            xc, (conv_c, ssm_c) = ssm_mod.ssm_block(cfg, lp, xc)
            return xc, (conv_c, ssm_c)

        x, (conv_new, ssm_new) = jax.lax.scan(inner, x, lp_g)
        x, (k, v) = tfm.dense_layer(cfg, params["shared_attn"], x, 0,
                                    block_k=block_k)
        pad = max_len - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return (x, pos), (conv_new, ssm_new, k, v)

    body = jax.checkpoint(body, prevent_cse=False)
    (x, _), (conv_new, ssm_new, ks, vs) = jax.lax.scan(
        body, (x, 0), (params["ssm"],))
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = tfm.logits_fn(cfg, params, h[:, -1:])[:, 0]
    return logits, HybridCache(conv_new, ssm_new, ks, vs,
                               jnp.asarray(S, jnp.int32))
