"""Decoder-only transformer LM (dense + GQA), scan-over-layers.

Covers gemma3 (5:1 local:global sliding window), deepseek/qwen2/internlm2
(plain GQA; qwen2 adds QKV bias), and chameleon (early-fusion VLM: the VQ
image tokens share the text vocabulary, frontend stubbed to token ids).

Layer parameters are stacked on a leading L axis and consumed by
``jax.lax.scan`` so compiled HLO size is O(1) in depth (95-layer deepseek
compiles like a 1-layer model).  Each scanned body is wrapped in
``jax.checkpoint`` (full remat) so training activation memory is the
residual stream only.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..parallel.hints import hint
from .layers import apply_rope, chunked_attention, dense_init, rms_norm, split_keys

Array = jax.Array
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def layer_windows(cfg: ArchConfig) -> np.ndarray:
    """Per-layer attention window (0 = global) for local:global patterns."""
    if cfg.sliding_window and cfg.local_global_ratio:
        period = cfg.local_global_ratio + 1
        return np.array(
            [0 if (i + 1) % period == 0 else cfg.sliding_window
             for i in range(cfg.n_layers)], dtype=np.int32)
    if cfg.sliding_window:
        return np.full(cfg.n_layers, cfg.sliding_window, dtype=np.int32)
    return np.zeros(cfg.n_layers, dtype=np.int32)


def init_dense_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> Params:
    L, D, F, V = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = split_keys(key, 10)
    lk = split_keys(ks[0], L)

    def stack(f):
        return jnp.stack([f(k) for k in lk])

    p: Params = {
        "embed": dense_init(ks[1], (V, D), scale=0.02, dtype=dtype),
        "ln_f": jnp.zeros((D,), dtype),
        "layers": {
            "ln1": jnp.zeros((L, D), dtype),
            "ln2": jnp.zeros((L, D), dtype),
            "wq": stack(lambda k: dense_init(k, (D, H * Dh), dtype=dtype)),
            "wk": stack(lambda k: dense_init(k, (D, Hkv * Dh), dtype=dtype)),
            "wv": stack(lambda k: dense_init(k, (D, Hkv * Dh), dtype=dtype)),
            "wo": stack(lambda k: dense_init(k, (H * Dh, D), dtype=dtype)),
            "w_gate": stack(lambda k: dense_init(k, (D, F), dtype=dtype)),
            "w_up": stack(lambda k: dense_init(k, (D, F), dtype=dtype)),
            "w_down": stack(lambda k: dense_init(k, (F, D), dtype=dtype)),
        },
    }
    if cfg.qkv_bias:
        p["layers"]["bq"] = jnp.zeros((L, H * Dh), dtype)
        p["layers"]["bk"] = jnp.zeros((L, Hkv * Dh), dtype)
        p["layers"]["bv"] = jnp.zeros((L, Hkv * Dh), dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], (V, D), scale=0.02, dtype=dtype)
    return p


# ---------------------------------------------------------------------------
# Attention block (shared by train / prefill / decode)
# ---------------------------------------------------------------------------


def _attn(cfg: ArchConfig, lp, x, *, k_full, v_full, window, q_offset,
          kv_len, block_k=1024):
    B, S, D = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, lp["wq"])
    if "bq" in lp:
        q = q + lp["bq"]
    q = q.reshape(B, S, H, Dh)
    q = apply_rope(q, jnp.arange(S) + q_offset, cfg.rope_theta)
    out = chunked_attention(
        q, k_full, v_full, causal=True, window=window,
        q_offset=q_offset, kv_len=kv_len, block_k=block_k)
    return out.reshape(B, S, H * Dh) @ lp["wo"]


def _project_kv(cfg, lp, x, q_offset):
    B, S, _ = x.shape
    Hkv, Dh = cfg.n_kv_heads, cfg.hd
    k = jnp.einsum("bsd,dh->bsh", x, lp["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, lp["wv"])
    if "bk" in lp:
        k, v = k + lp["bk"], v + lp["bv"]
    k = k.reshape(B, S, Hkv, Dh)
    v = v.reshape(B, S, Hkv, Dh)
    k = apply_rope(k, jnp.arange(S) + q_offset, cfg.rope_theta)
    return k, v


def dense_layer(cfg: ArchConfig, lp, x, window, *, cache_kv=None, pos=0,
                block_k=1024, ffn=None):
    """One transformer block.  cache_kv=(k,v) full-length buffers for decode;
    otherwise self-attention over the current sequence.  ``ffn(lp, h)``
    overrides the feed-forward (used by the MoE model)."""
    h = hint(rms_norm(x, lp["ln1"], cfg.norm_eps), "block_in")
    if cache_kv is None:
        k, v = _project_kv(cfg, lp, h, pos)
        attn = _attn(cfg, lp, h, k_full=k, v_full=v, window=window,
                     q_offset=pos, kv_len=None, block_k=block_k)
        new_kv = (k, v)
    else:
        k_new, v_new = _project_kv(cfg, lp, h, pos)
        k_buf, v_buf = cache_kv
        k_buf = jax.lax.dynamic_update_slice(k_buf, k_new.astype(k_buf.dtype),
                                             (0, pos, 0, 0))
        v_buf = jax.lax.dynamic_update_slice(v_buf, v_new.astype(v_buf.dtype),
                                             (0, pos, 0, 0))
        attn = _attn(cfg, lp, h, k_full=k_buf, v_full=v_buf, window=window,
                     q_offset=pos, kv_len=pos + x.shape[1], block_k=block_k)
        new_kv = (k_buf, v_buf)
    x = x + attn
    h = hint(rms_norm(x, lp["ln2"], cfg.norm_eps), "block_in")
    if ffn is None:
        from .layers import swiglu
        delta = swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
    else:
        delta = ffn(lp, h)
    x = x + delta
    return x, new_kv


# ---------------------------------------------------------------------------
# Full forward passes
# ---------------------------------------------------------------------------


def _scan_layers(cfg: ArchConfig, params: Params, x: Array, body):
    windows = jnp.asarray(layer_windows(cfg))
    lp = params["layers"]

    def wrapped(carry, xs):
        return body(carry, xs)

    wrapped = jax.checkpoint(wrapped, prevent_cse=False)
    carry, ys = jax.lax.scan(wrapped, x, (lp, windows))
    return carry, ys


def forward(cfg: ArchConfig, params: Params, tokens: Array,
            block_k: int = 1024) -> Array:
    """Training/prefill forward to final hidden states (B, S, D)."""
    x = hint(params["embed"].astype(jnp.bfloat16)[tokens], "residual")

    def body(x, xs):
        lp, window = xs
        x, _ = dense_layer(cfg, lp, x, window, block_k=block_k)
        return hint(x, "residual"), None

    x, _ = _scan_layers(cfg, params, x, body)
    return rms_norm(x, params["ln_f"], cfg.norm_eps)


def logits_fn(cfg: ArchConfig, params: Params, h: Array) -> Array:
    w = params.get("lm_head", params["embed"])
    return jnp.einsum("bsd,vd->bsv", h, w.astype(h.dtype))


def chunked_xent(cfg: ArchConfig, params: Params, h: Array, labels: Array,
                 chunk: int = 512) -> Array:
    """Cross-entropy without materializing (B, S, V) logits at once.

    The gold logit is extracted with a one-hot contraction (not
    take_along_axis) so a vocab-sharded lm_head reduces with one small
    all-reduce instead of gathering the logits chunk.
    """
    from ..parallel.hints import hint

    B, S, D = h.shape
    w = params.get("lm_head", params["embed"]).astype(jnp.float32)
    nchunks = max(1, S // chunk)
    hs = h.reshape(B, nchunks, S // nchunks, D)
    ls = labels.reshape(B, nchunks, S // nchunks)

    def one(args):
        hc, lc = args  # (B, c, D), (B, c)
        logits = jnp.einsum("bcd,vd->bcv", hc.astype(jnp.float32), w)
        logits = hint(logits, "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = (lc[..., None] ==
                  jnp.arange(logits.shape[-1])[None, None, :])
        gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        return lse - gold

    losses = jax.lax.map(one, (hs.transpose(1, 0, 2, 3), ls.transpose(1, 0, 2)))
    return losses.mean()


def lm_loss(cfg: ArchConfig, params: Params, batch: Dict[str, Array]) -> Array:
    h = forward(cfg, params, batch["tokens"])
    return chunked_xent(cfg, params, h, batch["labels"])


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with a fixed-capacity KV cache
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: Array   # (L, B, Smax, Hkv, Dh)
    v: Array
    pos: Array  # scalar int32: number of valid tokens


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> KVCache:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


def decode_step(cfg: ArchConfig, params: Params, cache: KVCache,
                tokens: Array, block_k: int = 1024
                ) -> Tuple[Array, KVCache]:
    """One decode step: tokens (B, 1) -> logits (B, V), updated cache."""
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    windows = jnp.asarray(layer_windows(cfg))
    lp = params["layers"]
    pos = cache.pos

    def body(x, xs):
        lp_l, window, kc, vc = xs
        x, (kc, vc) = dense_layer(cfg, lp_l, x, window, cache_kv=(kc, vc),
                                  pos=pos, block_k=block_k)
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(body, x, (lp, windows, cache.k, cache.v))
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = logits_fn(cfg, params, h)[:, 0]
    return logits, KVCache(k_new, v_new, pos + 1)


def prefill(cfg: ArchConfig, params: Params, tokens: Array, max_len: int,
            block_k: int = 1024) -> Tuple[Array, KVCache]:
    """Prefill the cache with a full prompt; returns last-token logits."""
    B, S = tokens.shape
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    windows = jnp.asarray(layer_windows(cfg))
    lp = params["layers"]

    def body(x, xs):
        lp_l, window = xs
        x, (k, v) = dense_layer(cfg, lp_l, x, window, block_k=block_k)
        x = hint(x, "residual")
        pad = max_len - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, (k, v)

    body = jax.checkpoint(body, prevent_cse=False)
    x, (ks, vs) = jax.lax.scan(body, x, (lp, windows))
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = logits_fn(cfg, params, h[:, -1:])[:, 0]
    return logits, KVCache(ks, vs, jnp.asarray(S, jnp.int32))


# ---------------------------------------------------------------------------
# Grouped decode for local:global architectures (gemma3) -- OPTIMIZED PATH
#
# Beyond-paper Perf iteration (EXPERIMENTS.md §Perf): local-attention layers
# keep only a ``window``-sized RING cache.  The ring slot index is
# ``pos mod window`` -- a hyperplane bank address (Eq. 1) with N = window,
# B = 1, and window a power of two, so the Sec-3.4 transform reduces the
# bank-resolution to a single AND mask.  Capacity and HBM traffic for the
# 5-of-6 local layers drop from O(S_ctx) to O(window).
# ---------------------------------------------------------------------------


class GroupedKVCache(NamedTuple):
    k_local: Array   # (G, R, B, W, Hkv, Dh) ring buffers (R local layers/group)
    v_local: Array
    k_global: Array  # (G, B, Smax, Hkv, Dh)
    v_global: Array
    pos: Array


def grouped_layout(cfg: ArchConfig) -> Tuple[int, int]:
    """(groups, locals_per_group); requires the 5:1-style layer pattern."""
    assert cfg.sliding_window and cfg.local_global_ratio
    period = cfg.local_global_ratio + 1
    assert cfg.n_layers % period == 0
    return cfg.n_layers // period, cfg.local_global_ratio


def init_grouped_cache(cfg: ArchConfig, batch: int, max_len: int,
                       dtype=jnp.bfloat16) -> GroupedKVCache:
    G, R = grouped_layout(cfg)
    W = cfg.sliding_window
    Hkv, Dh = cfg.n_kv_heads, cfg.hd
    return GroupedKVCache(
        jnp.zeros((G, R, batch, W, Hkv, Dh), dtype),
        jnp.zeros((G, R, batch, W, Hkv, Dh), dtype),
        jnp.zeros((G, batch, max_len, Hkv, Dh), dtype),
        jnp.zeros((G, batch, max_len, Hkv, Dh), dtype),
        jnp.zeros((), jnp.int32),
    )


def _grouped_params(cfg: ArchConfig, params: Params):
    """Restack (L, ...) layer params into local (G, R, ...) + global (G, ...)."""
    G, R = grouped_layout(cfg)
    period = R + 1
    lp = params["layers"]

    def split(x):
        xg = x.reshape((G, period) + x.shape[1:])
        return xg[:, :R], xg[:, R]

    local, glob = {}, {}
    for k, v in lp.items():
        l, g = split(v)
        local[k], glob[k] = l, g
    return local, glob


def grouped_decode_step(cfg: ArchConfig, params: Params,
                        cache: GroupedKVCache, tokens: Array,
                        block_k: int = 1024) -> Tuple[Array, GroupedKVCache]:
    """One decode step with ring-buffered local layers."""
    from ..parallel.hints import hint
    W = cfg.sliding_window
    Hkv, Dh, H = cfg.n_kv_heads, cfg.hd, cfg.n_heads
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    pos = cache.pos
    slot = jax.lax.rem(pos, W)  # ring bank address: pos & (W-1) once lowered
    local_p, global_p = _grouped_params(cfg, params)

    def local_layer(x, lp, kc, vc):
        B, S, D = x.shape
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        k_new, v_new = _project_kv(cfg, lp, h, pos)
        kc = jax.lax.dynamic_update_slice(kc, k_new.astype(kc.dtype),
                                          (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v_new.astype(vc.dtype),
                                          (0, slot, 0, 0))
        # ring holds the last W tokens; absolute position of ring row r is
        # recovered from the bank equation -- attention over W rows, masked
        # by true recency.  kv_len = min(pos+1, W): all rows valid once full.
        q = jnp.einsum("bsd,dh->bsh", h, lp["wq"])
        if "bq" in lp:
            q = q + lp["bq"]
        q = q.reshape(B, S, H, Dh)
        q = apply_rope(q, jnp.arange(S) + pos, cfg.rope_theta)
        # positions of ring rows: row r came from pos' = r + W*floor(...) --
        # reconstruct: rows (slot-W, slot] hold positions (pos-W, pos]
        row = jnp.arange(W)
        age = jax.lax.rem(slot - row + W, W)          # 0 = newest
        k_pos = pos - age
        valid = (k_pos >= 0) & (k_pos > pos - W)
        from .layers import NEG_INF
        q5 = (q.astype(jnp.float32) / (Dh ** 0.5)).reshape(
            B, S, Hkv, H // Hkv, Dh)
        s = jnp.einsum("bqhrd,bkhd->bqhrk", q5, kc.astype(jnp.float32))
        s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
        p = jnp.exp(s - s.max(-1, keepdims=True))
        o = jnp.einsum("bqhrk,bkhd->bqhrd", p, vc.astype(jnp.float32))
        o = (o / jnp.maximum(p.sum(-1)[..., None], 1e-30)).reshape(B, S, H * Dh)
        x = x + o.astype(x.dtype) @ lp["wo"]
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        from .layers import swiglu
        return x + swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"]), kc, vc

    def group_body(x, xs):
        lpl, lpg, klg, vlg, kgg, vgg = xs

        def inner(x, ys):
            lp_i, kc, vc = ys
            x, kc, vc = local_layer(x, lp_i, kc, vc)
            return x, (kc, vc)

        x, (kl_new, vl_new) = jax.lax.scan(inner, x, (lpl, klg, vlg))
        x, (kg_new, vg_new) = dense_layer(cfg, lpg, x, 0,
                                          cache_kv=(kgg, vgg), pos=pos,
                                          block_k=block_k)
        return x, (kl_new, vl_new, kg_new, vg_new)

    x, (kl, vl, kg, vg) = jax.lax.scan(
        group_body, x,
        (local_p, global_p, cache.k_local, cache.v_local,
         cache.k_global, cache.v_global))
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = logits_fn(cfg, params, h)[:, 0]
    return logits, GroupedKVCache(kl, vl, kg, vg, pos + 1)


# ---------------------------------------------------------------------------
# int8-quantized KV cache (beyond-paper Perf iteration)
#
# Banking view: the cache word width is the solver's ``word_bits`` -- halving
# it halves both bank capacity and the bytes every decode step must stream.
# Per-(token, head) max-abs scales keep the attention error ~0.5%.
# ---------------------------------------------------------------------------


class QuantKVCache(NamedTuple):
    k_q: Array    # (L, B, Smax, Hkv, Dh) int8
    v_q: Array
    k_s: Array    # (L, B, Smax, Hkv) f32 scales
    v_s: Array
    pos: Array


def init_quant_cache(cfg: ArchConfig, batch: int, max_len: int
                     ) -> QuantKVCache:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return QuantKVCache(
        jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
        jnp.zeros(shape[:-1], jnp.float32), jnp.zeros(shape[:-1], jnp.float32),
        jnp.zeros((), jnp.int32))


def _quant_rows(x: Array):
    """x (B, S, Hkv, Dh) -> int8 rows + per-(token, head) scales."""
    s = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]), -127, 127)
    return q.astype(jnp.int8), s


def decode_step_quant(cfg: ArchConfig, params: Params, cache: QuantKVCache,
                      tokens: Array, block_k: int = 1024
                      ) -> Tuple[Array, QuantKVCache]:
    """decode_step against an int8 cache: new rows quantized on write, the
    whole buffer dequantized lazily on read (XLA streams int8 from HBM and
    fuses the scale multiply into the attention contraction)."""
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    windows = jnp.asarray(layer_windows(cfg))
    lp = params["layers"]
    pos = cache.pos

    def body(x, xs):
        lp_l, window, kq, vq, ks, vs = xs
        h = hint(rms_norm(x, lp_l["ln1"], cfg.norm_eps), "block_in")
        k_new, v_new = _project_kv(cfg, lp_l, h, pos)
        knq, kns = _quant_rows(k_new)
        vnq, vns = _quant_rows(v_new)
        kq = jax.lax.dynamic_update_slice(kq, knq, (0, pos, 0, 0))
        vq = jax.lax.dynamic_update_slice(vq, vnq, (0, pos, 0, 0))
        ks = jax.lax.dynamic_update_slice(ks, kns, (0, pos, 0))
        vs = jax.lax.dynamic_update_slice(vs, vns, (0, pos, 0))
        k_deq = kq.astype(jnp.bfloat16) * ks[..., None].astype(jnp.bfloat16)
        v_deq = vq.astype(jnp.bfloat16) * vs[..., None].astype(jnp.bfloat16)
        attn = _attn(cfg, lp_l, h, k_full=k_deq, v_full=v_deq, window=window,
                     q_offset=pos, kv_len=pos + x.shape[1], block_k=block_k)
        x = x + attn
        h = hint(rms_norm(x, lp_l["ln2"], cfg.norm_eps), "block_in")
        from .layers import swiglu
        x = x + swiglu(h, lp_l["w_gate"], lp_l["w_up"], lp_l["w_down"])
        return x, (kq, vq, ks, vs)

    x, (kq, vq, ks, vs) = jax.lax.scan(
        body, x, (lp, windows, cache.k_q, cache.v_q, cache.k_s, cache.v_s))
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = logits_fn(cfg, params, h)[:, 0]
    return logits, QuantKVCache(kq, vq, ks, vs, pos + 1)
