"""Mamba2 (state-space duality / SSD) language model.

Implements the chunked SSD algorithm (Dao & Gu, arXiv:2405.21060): within a
chunk of length Q the recurrence is computed in matrix form (MXU-friendly
matmuls); across chunks a ``lax.scan`` carries the (B, H, P, N) state.  The
Pallas kernel ``repro.kernels.ssd_chunk`` implements the same chunk math
with VMEM tiling; this file is the pure-jnp model (and the kernel's oracle).

The paper's banking technique applies to the *state tensors*, not attention
(mamba2 is attention-free -- see DESIGN.md Arch-applicability): the solver
banks the (H, P, N) state across the model axis.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..parallel.hints import hint
from .layers import dense_init, rms_norm, split_keys
from . import transformer as tfm

Array = jax.Array
Params = Dict[str, Any]


def dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    H = d_inner // P
    N = cfg.ssm_state
    return d_inner, H, P, N


def init_ssm_layer(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> Params:
    D = cfg.d_model
    d_inner, H, P, N = dims(cfg)
    conv_dim = d_inner + 2 * N  # conv over [x, B, C]
    ks = split_keys(key, 6)
    return {
        "ln": jnp.zeros((D,), dtype),
        "in_proj": dense_init(ks[0], (D, 2 * d_inner + 2 * N + H), dtype=dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_dim), scale=0.2, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gate_ln": jnp.zeros((d_inner,), dtype),
        "out_proj": dense_init(ks[2], (d_inner, D), dtype=dtype),
    }


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> Params:
    L = cfg.n_layers
    ks = split_keys(key, L + 2)
    layers = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[init_ssm_layer(cfg, k, dtype) for k in ks[:L]])
    return {
        "embed": dense_init(ks[L], (cfg.vocab, cfg.d_model), scale=0.02, dtype=dtype),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
        "layers": layers,
    }


# ---------------------------------------------------------------------------
# Chunked SSD core
# ---------------------------------------------------------------------------


def _causal_conv(x: Array, w: Array, b: Array, state: Array = None):
    """Depthwise causal conv, window ssm_conv.  x (B, S, C); w (W, C).
    ``state`` (B, W-1, C) carries the tail for streaming decode."""
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else None
    return jax.nn.silu(out + b), new_state


def ssd_chunked(x: Array, dt: Array, A: Array, Bm: Array, Cm: Array,
                chunk: int, init_state: Array = None
                ) -> Tuple[Array, Array]:
    """SSD scan.  x (B,S,H,P), dt (B,S,H) (post-softplus), A (H,) negative,
    Bm/Cm (B,S,N).  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        # zero-pad the tail: dt=0 rows have decay exp(0)=1 and add nothing
        # to the state; their y rows are dropped before returning.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S_pad = S + pad
    nc = S_pad // Q

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    dA = dtc * A  # (B, nc, Q, H), negative
    cum = jnp.cumsum(dA, axis=2)  # running log-decay within chunk

    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(state, inp):
        xq, dtq, bq, cq, dAq, cumq = inp  # per-chunk slices
        # decay matrix L[i,j] = exp(cum_i - cum_j) for i >= j (per head)
        rel = cumq[:, :, None, :] - cumq[:, None, :, :]   # (B, Q, Q, H)
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        # mask BEFORE exp: masked entries have rel > 0 and would overflow,
        # poisoning the backward (inf * 0 = nan in the where-grad)
        rel = jnp.where(causal[None, :, :, None], rel, -jnp.inf)
        Lmat = jnp.exp(rel)
        scores = jnp.einsum("bin,bjn->bij", cq, bq)        # (B, Q, Q)
        W = scores[..., None] * Lmat                       # (B, Q, Q, H)
        xdt = xq * dtq[..., None]                          # dt-weighted input
        y_intra = jnp.einsum("bijh,bjhp->bihp", W, xdt)
        # contribution of carried-in state
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", cq, state, jnp.exp(cumq))
        # state update
        decay_to_end = jnp.exp(cumq[:, -1:, :] - cumq)     # (B, Q, H)
        s_add = jnp.einsum("bjn,bjhp,bjh->bhpn", bq, xdt, decay_to_end)
        state = state * jnp.exp(cumq[:, -1])[:, :, None, None] + s_add
        return state, y_intra + y_inter

    xs = (
        jnp.moveaxis(xc, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dtc, 1, 0).astype(jnp.float32),
        jnp.moveaxis(Bc, 1, 0).astype(jnp.float32),
        jnp.moveaxis(Cc, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dA, 1, 0),
        jnp.moveaxis(cum, 1, 0),
    )
    state, ys = jax.lax.scan(step, init_state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S_pad, H, P)[:, :S]
    return y, state


def ssm_block(cfg: ArchConfig, lp, x: Array, *, conv_state=None,
              ssm_state=None, streaming=False):
    """One Mamba2 block.  x (B, S, D).  Streaming mode threads conv/ssm
    states (decode); otherwise states start at zero (train/prefill)."""
    Bsz, S, D = x.shape
    d_inner, H, P, N = dims(cfg)
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    proj = h @ lp["in_proj"]  # (B, S, 2*d_inner + 2N + H)
    z, xin, bc, dt_raw = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xin, bc], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, lp["conv_w"], lp["conv_b"],
                                      conv_state)
    xin, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"])
    xh = xin.reshape(Bsz, S, H, P)
    if streaming and S == 1:
        # O(1) recurrence for single-token decode
        dA = jnp.exp(dt[:, 0] * A)  # (B, H)
        xdt = xh[:, 0] * dt[:, 0, :, None]
        s_add = jnp.einsum("bn,bhp->bhpn", Bm[:, 0].astype(jnp.float32),
                           xdt.astype(jnp.float32))
        state = ssm_state * dA[..., None, None] + s_add
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), state)
        y = y[:, None]  # (B, 1, H, P)
        new_state = state
    else:
        y, new_state = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk, ssm_state)
    y = y + lp["D_skip"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), lp["gate_ln"], cfg.norm_eps)
    out = y @ lp["out_proj"]
    return x + out, (new_conv, new_state)


# ---------------------------------------------------------------------------
# LM wrappers
# ---------------------------------------------------------------------------


class SSMCache(NamedTuple):
    conv: Array   # (L, B, W-1, conv_dim)
    state: Array  # (L, B, H, P, N)
    pos: Array


def init_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> SSMCache:
    d_inner, H, P, N = dims(cfg)
    conv_dim = d_inner + 2 * N
    L = cfg.n_layers
    return SSMCache(
        jnp.zeros((L, batch, cfg.ssm_conv - 1, conv_dim), dtype),
        jnp.zeros((L, batch, H, P, N), jnp.float32),
        jnp.zeros((), jnp.int32),
    )


def forward(cfg: ArchConfig, params: Params, tokens: Array) -> Array:
    x = params["embed"].astype(jnp.bfloat16)[tokens]

    def body(x, lp):
        x, _ = ssm_block(cfg, lp, x)
        return hint(x, "residual"), None

    body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return rms_norm(x, params["ln_f"], cfg.norm_eps)


def lm_loss(cfg: ArchConfig, params: Params, batch: Dict[str, Array]) -> Array:
    h = forward(cfg, params, batch["tokens"])
    return tfm.chunked_xent(cfg, params, h, batch["labels"])


def decode_step(cfg: ArchConfig, params: Params, cache: SSMCache,
                tokens: Array) -> Tuple[Array, SSMCache]:
    x = params["embed"].astype(jnp.bfloat16)[tokens]

    def body(x, xs):
        lp, conv_c, ssm_c = xs
        x, (conv_c, ssm_c) = ssm_block(cfg, lp, x, conv_state=conv_c,
                                       ssm_state=ssm_c, streaming=True)
        return x, (conv_c, ssm_c)

    x, (conv_new, state_new) = jax.lax.scan(
        body, x, (params["layers"], cache.conv, cache.state))
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = tfm.logits_fn(cfg, params, h)[:, 0]
    return logits, SSMCache(conv_new, state_new, cache.pos + 1)


def prefill(cfg: ArchConfig, params: Params, tokens: Array
            ) -> Tuple[Array, SSMCache]:
    B, S = tokens.shape
    x = params["embed"].astype(jnp.bfloat16)[tokens]

    def body(x, lp):
        x, (conv_c, ssm_c) = ssm_block(cfg, lp, x)
        return x, (conv_c, ssm_c)

    x, (conv_new, state_new) = jax.lax.scan(body, x, params["layers"])
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = tfm.logits_fn(cfg, params, h[:, -1:])[:, 0]
    return logits, SSMCache(conv_new, state_new, jnp.asarray(S, jnp.int32))
